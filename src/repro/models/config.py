"""Model configuration: one dataclass covering all assigned families
(dense GQA / MLA / MoE / SSM / hybrid / VLM backbone / enc-dec audio)."""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # attention flavour
    attention: str = "gqa"           # gqa | mla | none
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_type: str = "rope"          # rope | mrope
    mrope_sections: tuple = ()       # e.g. (16, 24, 24) halves of head_dim
    sliding_window: int = 0          # 0 = full causal attention

    # MLA (multi-head latent attention)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (Mamba-1)
    ssm_state: int = 0
    ssm_d_inner: int = 0
    ssm_conv: int = 4
    ssm_dt_rank: int = 0

    # encoder-decoder (audio family)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # modality frontend stub: "none" | "vision" | "audio"
    frontend: str = "none"

    # numerics
    act: str = "silu"
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"          # forward compute/param dtype
    tie_embeddings: bool = False

    # distribution knobs (consumed by repro.distributed.sharding)
    expert_sharding: str = "ffn"     # "ffn" (TP over d_ff) | "expert" (EP over E)
    remat: str = "full"              # none | block | full
    scan_layers: bool = True
    # inner-scan tile sizes; 0 = unrolled/full (used by the dry-run flop
    # calibration probes, where while-loop bodies are cost-counted once)
    attn_chunk: int = 512
    ssm_block: int = 256
    unroll_inner: bool = False       # python-loop inner chunks (probes)
    # beyond-paper perf knobs (see EXPERIMENTS.md §Perf):
    # shard the residual stream's seq dim over `model` at block boundaries
    # (Megatron-style sequence parallelism: 16x smaller remat stacks for
    # an all-gather + reduce-scatter per layer)
    seq_sharded_residual: bool = False
    # shard attention queries/outputs over seq when heads don't divide the
    # model axis (avoids replicating (B,S,H*hd) activations)
    seq_sharded_attention: bool = False
    # run the selective-scan decay/state intermediates in bf16 (the Pallas
    # kernel's VMEM-resident state makes this moot on TPU; in the jnp path
    # it halves the dominant (B,blk,di,N) HBM traffic at ~1e-2 rel error)
    ssm_bf16: bool = False

    def __post_init__(self):
        if self.attention == "gqa" and self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)
        if self.family in ("ssm", "hybrid") and not self.ssm_d_inner:
            object.__setattr__(self, "ssm_d_inner", 2 * self.d_model)
        if self.family in ("ssm", "hybrid") and not self.ssm_dt_rank:
            object.__setattr__(self, "ssm_dt_rank",
                               math.ceil(self.d_model / 16))

    # -- derived sizes -------------------------------------------------------

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 256 (Megatron-style) so the vocab axis shards
        evenly over `model`; the loss masks the padding columns."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_head_dim(self) -> int:
        if self.attention == "mla":
            return self.qk_nope_head_dim + self.qk_rope_head_dim
        return self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def has_attention(self) -> bool:
        return self.attention != "none"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def sub_quadratic(self) -> bool:
        """Whether the arch can run the long_500k cell (see DESIGN.md)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for roofline MODEL_FLOPS) ------------------------

    def param_count(self) -> tuple[int, int]:
        """Returns (total_params, active_params) — active differs for MoE."""
        D, L = self.d_model, self.num_layers
        emb = self.vocab_size * D
        total = active = 0

        def attn_params() -> int:
            if self.attention == "mla":
                p = 0
                if self.q_lora_rank:
                    p += D * self.q_lora_rank + self.q_lora_rank  # down + norm
                    p += self.q_lora_rank * self.num_heads * self.q_head_dim
                else:
                    p += D * self.num_heads * self.q_head_dim
                p += D * (self.kv_lora_rank + self.qk_rope_head_dim)
                p += self.kv_lora_rank
                p += self.kv_lora_rank * self.num_heads * (
                    self.qk_nope_head_dim + self.v_head_dim)
                p += self.num_heads * self.v_head_dim * D
                return p
            if self.attention == "none":
                return 0
            hd = self.head_dim
            p = D * self.num_heads * hd + 2 * D * self.num_kv_heads * hd \
                + self.num_heads * hd * D
            if self.qkv_bias:
                p += (self.num_heads + 2 * self.num_kv_heads) * hd
            if self.qk_norm:
                p += 2 * hd
            return p

        def mlp_params() -> tuple[int, int]:
            if self.is_moe:
                per = 3 * D * self.d_ff
                tot = self.num_experts * per + D * self.num_experts
                act = self.num_experts_per_tok * per + D * self.num_experts
                return tot, act
            if self.d_ff == 0:
                return 0, 0
            return 3 * D * self.d_ff, 3 * D * self.d_ff

        def ssm_params() -> int:
            if not self.has_ssm:
                return 0
            di, st, dr = self.ssm_d_inner, self.ssm_state, self.ssm_dt_rank
            return (D * 2 * di + di * self.ssm_conv
                    + di * (dr + 2 * st) + dr * di + di
                    + di * st + di + di * D)

        a, (mt, ma), s = attn_params(), mlp_params(), ssm_params()
        norms = 2 * D
        layer_total = a + mt + s + norms
        layer_active = a + ma + s + norms
        total = L * layer_total + emb + D
        active = L * layer_active + emb + D
        if self.is_encoder_decoder:
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            enc = self.num_encoder_layers * (a + mt + norms)
            total += enc + L * a          # cross-attn per decoder layer
            active += enc + L * a
        if not self.tie_embeddings:
            total += emb
            active += emb
        return total, active


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # configs are registered by importing repro.configs
    import repro.configs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
