"""Attention variants: GQA (+ qk-norm / QKV-bias / sliding-window / M-RoPE)
and MLA (multi-head latent attention, compressed KV cache + absorbed decode).

All sequence-level attention uses a memory-bounded chunked online-softmax
("flash-style") implementation in pure jnp — the TPU Pallas kernel in
``repro.kernels.flash_attention`` is numerically validated against the same
math and is swapped in on real hardware via ``use_pallas``.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed import context as ctx

from .config import ModelConfig
from .layers import ParamDef, apply_mrope, apply_rope, rms_norm

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Dense KV cache (GQA): k/v (B, S_max, KV, hd); index = #valid tokens."""
    k: jax.Array
    v: jax.Array


class MLACache(NamedTuple):
    """Compressed cache (MLA): latent (B, S_max, kv_lora), rope key
    (B, S_max, qk_rope) — the point of MLA is that this is ~10x smaller."""
    latent: jax.Array
    k_rope: jax.Array


# ==========================================================================
# GQA
# ==========================================================================

def gqa_table(cfg: ModelConfig) -> dict[str, ParamDef]:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    t = {
        "wq": ParamDef((D, H * hd), ("embed", "heads")),
        "wk": ParamDef((D, KV * hd), ("embed", "kv_heads")),
        "wv": ParamDef((D, KV * hd), ("embed", "kv_heads")),
        "wo": ParamDef((H * hd, D), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        t["bq"] = ParamDef((H * hd,), ("heads",), init="zeros")
        t["bk"] = ParamDef((KV * hd,), ("kv_heads",), init="zeros")
        t["bv"] = ParamDef((KV * hd,), ("kv_heads",), init="zeros")
    if cfg.qk_norm:
        t["q_norm"] = ParamDef((hd,), (None,), init="ones")
        t["k_norm"] = ParamDef((hd,), (None,), init="ones")
    return t


def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions):
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_type == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      chunk: int = 512, kv_valid: Optional[jax.Array] = None,
                      unroll: bool = False) -> jax.Array:
    """Online-softmax attention, scanned over KV chunks.

    q: (B, Sq, H, hd);  k, v: (B, Sk, KV, hd) with H % KV == 0.
    ``causal`` masks j > i (+ Sk - Sq offset); ``window`` > 0 additionally
    masks j <= i - window (sliding window).  ``kv_valid``: (B,) number of
    valid kv positions (for padded caches).  Returns (B, Sq, H, vd).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, vd = v.shape
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scale = hd ** -0.5
    nchunks = -(-Sk // chunk)
    pad = nchunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunks, chunk, KV, hd)
    vc = v.reshape(B, nchunks, chunk, KV, vd)
    q_pos = jnp.arange(Sq) + (Sk - Sq)        # absolute position of queries

    def step(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp                        # kj: (B, C, KV, hd)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg.astype(jnp.float32),
                       kj.astype(jnp.float32)) * scale
        kv_pos = j * chunk + jnp.arange(chunk)           # (C,)
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        mask &= (kv_pos < Sk)[None, :]
        if kv_valid is not None:
            bmask = kv_pos[None, :] < kv_valid[:, None]   # (B, C)
            s = jnp.where(bmask[:, None, None, None, :], s, NEG_INF)
        s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p_ = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p_.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p_, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, vd), jnp.float32)
    if unroll:
        # python loop: identical math, loop body visible to cost_analysis
        carry = (m0, l0, a0)
        for j in range(nchunks):
            carry, _ = step(carry, (jnp.int32(j), kc[:, j], vc[:, j]))
        m, l, acc = carry
    else:
        # checkpoint the chunk body: without this the backward pass stores
        # every chunk's (blk_q x blk_k) score tile in f32 — O(S^2) memory,
        # exactly what flash attention exists to avoid.  With it, backward
        # recomputes scores per chunk from q/k/v (the flash backward).
        step_ckpt = jax.checkpoint(
            step, policy=jax.checkpoint_policies.nothing_saveable)
        (m, l, acc), _ = jax.lax.scan(
            step_ckpt, (m0, l0, a0),
            (jnp.arange(nchunks), jnp.moveaxis(kc, 1, 0),
             jnp.moveaxis(vc, 1, 0)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, vd)   # b k g q d -> b q (kg) d
    return out.astype(q.dtype)


def gqa_forward(cfg: ModelConfig, p: dict, x: jax.Array,
                positions: jax.Array, causal: bool = True,
                ) -> tuple[jax.Array, KVCache]:
    """Full-sequence (train / prefill). Returns output and the KV to cache."""
    q, k, v = _project_qkv(cfg, p, x, positions)
    if cfg.seq_sharded_attention:
        # queries/outputs seq-sharded over `model`; K/V replicated across
        # the model axis instead of the (B,S,H*hd) activations
        q = ctx.constrain(q, ctx.dp(), "model", None, None)
    chunk = cfg.attn_chunk if cfg.attn_chunk > 0 else k.shape[1]
    out = chunked_attention(q, k, v, causal=causal,
                            window=cfg.sliding_window, chunk=chunk,
                            unroll=cfg.unroll_inner)
    if cfg.seq_sharded_attention:
        out = ctx.constrain(out, ctx.dp(), "model", None, None)
    B, S, H, hd = q.shape
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd),
                     p["wo"].astype(x.dtype))
    return out, KVCache(k, v)


def gqa_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: KVCache,
               index: jax.Array) -> tuple[jax.Array, KVCache]:
    """One-token decode. x: (B, 1, D); cache k/v: (B, S_max, KV, hd);
    index: scalar int32 — number of tokens already in the cache."""
    B = x.shape[0]
    positions = jnp.full((B, 1), index, jnp.int32)
    if cfg.rope_type == "mrope":       # text-only decode: t=h=w=index
        positions = jnp.full((B, 1, 3), index, jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, positions)
    S_max = cache.k.shape[1]
    ring = bool(cfg.sliding_window) and S_max <= cfg.sliding_window
    write_at = jnp.mod(index, S_max) if ring else index
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache.k, k, write_at, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache.v, v, write_at, axis=1)
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * hd ** -0.5
    kv_pos = jnp.arange(S_max)
    if ring:
        # ring buffer holds exactly the last S_max(=window) positions; the
        # only invalid slots are the not-yet-written ones before wraparound
        valid = kv_pos <= index
    else:
        valid = kv_pos <= index
        if cfg.sliding_window:
            valid &= kv_pos > index - cfg.sliding_window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", w, v_cache.astype(jnp.float32))
    out = jnp.moveaxis(out, 3, 1).reshape(B, 1, H * hd).astype(x.dtype)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
    return out, KVCache(k_cache, v_cache)


def gqa_empty_cache(cfg: ModelConfig, batch: int, s_max: int,
                    dtype) -> KVCache:
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    if cfg.sliding_window:
        # ring buffer: exactly `window` slots (see gqa_decode)
        s_max = min(s_max, cfg.sliding_window)
    return KVCache(jnp.zeros((batch, s_max, KV, hd), dtype),
                   jnp.zeros((batch, s_max, KV, hd), dtype))


# ==========================================================================
# MLA
# ==========================================================================

def mla_table(cfg: ModelConfig) -> dict[str, ParamDef]:
    D, H = cfg.d_model, cfg.num_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qlr, kvlr = cfg.q_lora_rank, cfg.kv_lora_rank
    t = {
        "kv_down": ParamDef((D, kvlr + rope_d), ("embed", "latent")),
        "kv_norm": ParamDef((kvlr,), (None,), init="ones"),
        "kv_up_k": ParamDef((kvlr, H * nope), ("latent", "heads")),
        "kv_up_v": ParamDef((kvlr, H * vd), ("latent", "heads")),
        "wo": ParamDef((H * vd, D), ("heads", "embed")),
    }
    if qlr:
        t["q_down"] = ParamDef((D, qlr), ("embed", "latent"))
        t["q_norm"] = ParamDef((qlr,), (None,), init="ones")
        t["q_up"] = ParamDef((qlr, H * (nope + rope_d)), ("latent", "heads"))
    else:
        t["wq"] = ParamDef((D, H * (nope + rope_d)), ("embed", "heads"))
    return t


def _mla_q(cfg: ModelConfig, p: dict, x: jax.Array, positions):
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["q_down"].astype(x.dtype)),
                      p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rh->bsh", cq, p["q_up"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    q = q.reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(cfg: ModelConfig, p: dict, x: jax.Array, positions):
    kvlr = cfg.kv_lora_rank
    ckv = jnp.einsum("bsd,dr->bsr", x, p["kv_down"].astype(x.dtype))
    latent, k_rope = ckv[..., :kvlr], ckv[..., kvlr:]
    latent = rms_norm(latent, p["kv_norm"], cfg.norm_eps)
    # single shared rope key "head"
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return latent, k_rope


def mla_forward(cfg: ModelConfig, p: dict, x: jax.Array,
                positions: jax.Array) -> tuple[jax.Array, MLACache]:
    """Full-sequence MLA (non-absorbed: expand latent, run chunked attn)."""
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    latent, k_rope = _mla_latent(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rh->bsh", latent,
                        p["kv_up_k"].astype(x.dtype)).reshape(B, S, H, nope)
    v = jnp.einsum("bsr,rh->bsh", latent,
                   p["kv_up_v"].astype(x.dtype)).reshape(B, S, H, vd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rope_d))],
        axis=-1)
    chunk = cfg.attn_chunk if cfg.attn_chunk > 0 else S
    out = chunked_attention(q, k, v, causal=True, chunk=chunk,
                            unroll=cfg.unroll_inner)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * vd),
                     p["wo"].astype(x.dtype))
    return out, MLACache(latent, k_rope)


def mla_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: MLACache,
               index: jax.Array) -> tuple[jax.Array, MLACache]:
    """Absorbed one-token decode: queries are mapped into latent space, so
    attention runs against the *compressed* cache directly — the MLA trick
    that makes the 500k-class caches feasible memory-wise."""
    B = x.shape[0]
    H = cfg.num_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvlr = cfg.kv_lora_rank
    positions = jnp.full((B, 1), index, jnp.int32)
    q_nope, q_rope = _mla_q(cfg, p, x, positions)       # (B,1,H,·)
    latent_t, k_rope_t = _mla_latent(cfg, p, x, positions)
    latent = jax.lax.dynamic_update_slice_in_dim(
        cache.latent, latent_t.astype(cache.latent.dtype), index, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache.k_rope, k_rope_t.astype(cache.k_rope.dtype), index, axis=1)
    # absorb kv_up_k into q:  (B,1,H,nope) @ (kvlr,H,nope) -> (B,1,H,kvlr)
    up_k = p["kv_up_k"].astype(x.dtype).reshape(kvlr, H, nope)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, up_k)
    s = (jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(jnp.float32),
                    latent.astype(jnp.float32))
         + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                      k_rope.astype(jnp.float32))) * (nope + rope_d) ** -0.5
    S_max = latent.shape[1]
    valid = jnp.arange(S_max) <= index
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out_lat = jnp.einsum("bhqs,bsr->bqhr", w,
                         latent.astype(jnp.float32)).astype(x.dtype)
    up_v = p["kv_up_v"].astype(x.dtype).reshape(kvlr, H, vd)
    out = jnp.einsum("bqhr,rhv->bqhv", out_lat, up_v)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, 1, H * vd),
                     p["wo"].astype(x.dtype))
    return out, MLACache(latent, k_rope)


def mla_empty_cache(cfg: ModelConfig, batch: int, s_max: int,
                    dtype) -> MLACache:
    return MLACache(
        jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
        jnp.zeros((batch, s_max, cfg.qk_rope_head_dim), dtype))
