"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

Pure-functional: ``init_params`` builds a pytree (layers stacked along a
leading L axis), ``forward``/``prefill``/``decode_step`` are jit-able, and
``param_specs`` returns the logical-axis pytree the sharding layer consumes.
Layers run under ``jax.lax.scan`` (bounded HLO at 512 devices) with optional
per-block remat.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from repro.distributed import context as ctx

from .config import ModelConfig
from .layers import (ParamDef, embed_table, embed_tokens, init_table,
                     lm_logits, mlp_forward, mlp_table, rms_norm, table_specs)


# --------------------------------------------------------------------------
# block structure
# --------------------------------------------------------------------------

@jax.custom_vjp
def _remat_barrier(x: jax.Array) -> jax.Array:
    """``optimization_barrier`` with an explicit VJP: identity-with-barrier
    on both passes.  Some jax versions ship no differentiation rule for the
    barrier primitive, which would make every ``scan_layers`` grad step
    raise ``NotImplementedError`` — the custom rule keeps the memory-pinning
    barrier in the forward *and* backward HLO without relying on one."""
    return jax.lax.optimization_barrier(x)


def _remat_barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _remat_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_remat_barrier.defvjp(_remat_barrier_fwd, _remat_barrier_bwd)



def block_tables(cfg: ModelConfig) -> dict[str, dict[str, ParamDef]]:
    D = cfg.d_model
    t: dict[str, dict[str, ParamDef]] = {}
    if cfg.has_attention:
        t["attn"] = (attn.mla_table(cfg) if cfg.attention == "mla"
                     else attn.gqa_table(cfg))
        t["norm_attn"] = {"scale": ParamDef((D,), ("embed",), init="ones")}
    if cfg.has_ssm:
        t["ssm"] = ssm_mod.ssm_table(cfg)
        if not cfg.has_attention:
            t["norm_ssm"] = {"scale": ParamDef((D,), ("embed",), init="ones")}
    if cfg.d_ff > 0:
        t["mlp"] = (moe_mod.moe_table(cfg) if cfg.is_moe
                    else mlp_table(D, cfg.d_ff))
        t["norm_mlp"] = {"scale": ParamDef((D,), ("embed",), init="ones")}
    return t


def init_block(cfg: ModelConfig, key: jax.Array, dtype) -> dict:
    tables = block_tables(cfg)
    keys = jax.random.split(key, len(tables))
    return {name: init_table(k, tbl, dtype)
            for (name, tbl), k in zip(sorted(tables.items()), keys)}


def block_specs(cfg: ModelConfig, stacked: bool) -> dict:
    lead = ("layers",) if stacked else ()
    return {name: {pname: lead + tuple(ax)
                   for pname, ax in table_specs(tbl).items()}
            for name, tbl in block_tables(cfg).items()}


# --------------------------------------------------------------------------
# block forward
# --------------------------------------------------------------------------

def _mix_forward(cfg: ModelConfig, p: dict, x: jax.Array, positions):
    """Sequence-mixing sublayer (attn / ssm / both).  Returns (out, caches)."""
    caches: dict[str, Any] = {}
    if cfg.has_attention and cfg.has_ssm:          # hybrid (hymba)
        h = rms_norm(x, p["norm_attn"]["scale"], cfg.norm_eps)
        a_out, kv = attn.gqa_forward(cfg, p["attn"], h, positions)
        s_out, st = ssm_mod.ssm_forward(cfg, p["ssm"], h)
        caches["kv"], caches["ssm"] = kv, st
        return 0.5 * (a_out + s_out), caches
    if cfg.has_attention:
        h = rms_norm(x, p["norm_attn"]["scale"], cfg.norm_eps)
        if cfg.attention == "mla":
            out, kv = attn.mla_forward(cfg, p["attn"], h, positions)
        else:
            out, kv = attn.gqa_forward(cfg, p["attn"], h, positions)
        caches["kv"] = kv
        return out, caches
    h = rms_norm(x, p["norm_ssm"]["scale"], cfg.norm_eps)
    out, st = ssm_mod.ssm_forward(cfg, p["ssm"], h)
    caches["ssm"] = st
    return out, caches


def _mix_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
                index) -> tuple[jax.Array, dict]:
    new_cache: dict[str, Any] = {}
    if cfg.has_attention and cfg.has_ssm:
        h = rms_norm(x, p["norm_attn"]["scale"], cfg.norm_eps)
        a_out, kv = attn.gqa_decode(cfg, p["attn"], h, cache["kv"], index)
        s_out, st = ssm_mod.ssm_decode(cfg, p["ssm"], h, cache["ssm"])
        new_cache["kv"], new_cache["ssm"] = kv, st
        return 0.5 * (a_out + s_out), new_cache
    if cfg.has_attention:
        h = rms_norm(x, p["norm_attn"]["scale"], cfg.norm_eps)
        if cfg.attention == "mla":
            out, kv = attn.mla_decode(cfg, p["attn"], h, cache["kv"], index)
        else:
            out, kv = attn.gqa_decode(cfg, p["attn"], h, cache["kv"], index)
        new_cache["kv"] = kv
        return out, new_cache
    h = rms_norm(x, p["norm_ssm"]["scale"], cfg.norm_eps)
    out, st = ssm_mod.ssm_decode(cfg, p["ssm"], h, cache["ssm"])
    new_cache["ssm"] = st
    return out, new_cache


def _ffn_forward(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.d_ff == 0:
        return jnp.zeros_like(x)
    h = rms_norm(x, p["norm_mlp"]["scale"], cfg.norm_eps)
    if cfg.is_moe:
        return moe_mod.moe_forward(cfg, p["mlp"], h)
    return mlp_forward(p["mlp"], h, cfg.act)


def block_forward(cfg: ModelConfig, p: dict, x: jax.Array, positions,
                  ) -> tuple[jax.Array, dict]:
    # keep the scan-carried activation batch-sharded: without this, GSPMD
    # sometimes replicates while-loop carries and the whole layer stack
    # (and everything downstream) runs with batch unsharded.  With
    # seq_sharded_residual the carry (and thus the remat-saved stack) is
    # additionally sharded over `model` on the seq dim; the mix/ffn
    # sublayers gather it back (Megatron sequence parallelism).
    if cfg.seq_sharded_residual:
        x = ctx.constrain(x, ctx.dp(), "model", None)
    else:
        x = ctx.constrain(x, ctx.dp(), None, None)
    # pin the remat-saved layer input to bf16: without the barrier XLA
    # hoists the norm's f32 upcast into the saved stack (3x the memory)
    x = _remat_barrier(x)
    mix, caches = _mix_forward(cfg, p, x, positions)
    x = x + mix
    if cfg.d_ff > 0:
        x = x + _ffn_forward(cfg, p, x)
    return x, caches


def block_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
                 index) -> tuple[jax.Array, dict]:
    mix, new_cache = _mix_decode(cfg, p, x, cache, index)
    x = x + mix
    if cfg.d_ff > 0:
        x = x + _ffn_forward(cfg, p, x)
    return x, new_cache


# --------------------------------------------------------------------------
# model init / specs
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array,
                dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    k_emb, k_layers = jax.random.split(key)
    params = {"embed": init_table(
        k_emb, embed_table(cfg.padded_vocab, cfg.d_model,
                           cfg.tie_embeddings), dtype)}
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    if cfg.scan_layers:
        params["layers"] = jax.vmap(
            lambda k: init_block(cfg, k, dtype))(layer_keys)
    else:
        params["layers"] = [init_block(cfg, k, dtype) for k in layer_keys]
    return params


def param_specs(cfg: ModelConfig) -> dict:
    blocks = block_specs(cfg, cfg.scan_layers)
    return {
        "embed": table_specs(
            embed_table(cfg.padded_vocab, cfg.d_model, cfg.tie_embeddings)),
        "layers": (blocks if cfg.scan_layers
                   else [blocks for _ in range(cfg.num_layers)]),
    }


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------

def _default_positions(cfg: ModelConfig, B: int, S: int,
                       offset: int = 0) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.rope_type == "mrope":
        return jnp.broadcast_to(pos[..., None], (B, S, 3))
    return pos


def _embed_inputs(cfg: ModelConfig, params, batch: dict) -> tuple:
    dtype = jnp.dtype(cfg.dtype)
    if "embeds" in batch:            # vlm/audio stub frontends feed embeddings
        x = batch["embeds"].astype(dtype)
    else:
        x = embed_tokens(params["embed"], batch["tokens"], dtype)
    B, S = x.shape[:2]
    x = ctx.constrain(x, ctx.dp(), None, None)
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, B, S)
    return x, positions


def _run_layers(cfg: ModelConfig, params, x, positions,
                collect_caches: bool = False):
    block = functools.partial(block_forward, cfg)
    if cfg.remat != "none":
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable
            if cfg.remat == "full" else
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if cfg.scan_layers:
        def body(h, lp):
            h2, caches = block(lp, h, positions)
            return h2, (caches if collect_caches else None)
        x, caches = jax.lax.scan(body, x, params["layers"])
    else:
        caches = []
        for lp in params["layers"]:
            x, c = block(lp, x, positions)
            caches.append(c)
    return x, caches


def forward(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    """Full-sequence forward -> logits (B, S, V)."""
    x, positions = _embed_inputs(cfg, params, batch)
    x, _ = _run_layers(cfg, params, x, positions)
    x = ctx.constrain(x, ctx.dp(), None, None)
    x = rms_norm(x, params["embed"]["final_norm"], cfg.norm_eps)
    return lm_logits(params["embed"], x, cfg.tie_embeddings)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    logits = forward(cfg, params, batch)
    return cross_entropy(logits, batch["labels"], batch.get("loss_mask"),
                         real_vocab=cfg.vocab_size)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask=None, real_vocab: int = 0) -> jax.Array:
    """Vocab-shard-friendly cross entropy: the label log-prob is picked with
    a one-hot einsum (NOT take_along_axis — gathering along a `model`-sharded
    vocab axis makes GSPMD replicate the full f32 logits; the einsum lowers
    to a partial reduction + tiny all-reduce instead)."""
    from repro.distributed import context as ctx
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    logits = ctx.constrain(logits.astype(jnp.float32),
                           ctx.dp(), None, "model")
    if real_vocab and real_vocab < logits.shape[-1]:
        # vocab is padded to shard evenly; padding columns must not leak
        # probability mass into the partition function
        pad_mask = jnp.arange(logits.shape[-1]) < real_vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - m
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    ll = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = (logz - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------

class DecodeState(NamedTuple):
    cache: Any          # per-layer cache pytree, leaves stacked over L
    index: jax.Array    # scalar int32: #tokens written
    last_logits: jax.Array


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            s_max: int) -> DecodeState:
    """Run the prompt, building caches padded out to ``s_max``."""
    x, positions = _embed_inputs(cfg, params, batch)
    B, S = x.shape[:2]
    x, caches = _run_layers(cfg, params, x, positions, collect_caches=True)
    x = rms_norm(x, params["embed"]["final_norm"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x[:, -1:], cfg.tie_embeddings)

    # pad prefill KV out to s_max; works for stacked (L, B, S, ...) and
    # per-layer (B, S, ...) caches via negative seq axis.
    def pad_kv(c: attn.KVCache) -> attn.KVCache:
        cap = s_max
        if cfg.sliding_window:
            cap = min(cap, cfg.sliding_window)   # decode ring buffer size
        def pad(a):   # (..., S, KV, hd) -> (..., cap, KV, hd)
            ax = a.ndim - 3
            Sp = a.shape[ax]
            if Sp >= cap:
                # keep the last `cap` positions and rotate them into ring
                # layout: position p lives at slot p % cap
                sl = [slice(None)] * a.ndim
                sl[ax] = slice(Sp - cap, None)
                return jnp.roll(a[tuple(sl)], Sp % cap, axis=ax)
            padw = [(0, 0)] * a.ndim
            padw[ax] = (0, cap - Sp)
            return jnp.pad(a, padw)
        return attn.KVCache(pad(c.k), pad(c.v))

    def pad_mla(c: attn.MLACache) -> attn.MLACache:
        def pad(a):   # (..., S, R)
            padw = [(0, 0)] * a.ndim
            padw[a.ndim - 2] = (0, s_max - a.shape[a.ndim - 2])
            return jnp.pad(a, padw)
        return attn.MLACache(pad(c.latent), pad(c.k_rope))

    def pad_one(caches_dict):
        out = {}
        if "kv" in caches_dict:
            out["kv"] = (pad_mla(caches_dict["kv"])
                         if cfg.attention == "mla"
                         else pad_kv(caches_dict["kv"]))
        if "ssm" in caches_dict:
            out["ssm"] = caches_dict["ssm"]
        return out

    if isinstance(caches, dict):
        new_caches = pad_one(caches)
    else:                               # unrolled: list of per-layer dicts
        new_caches = [pad_one(c) for c in caches]
    return DecodeState(new_caches, jnp.int32(S), logits)


def init_decode_state(cfg: ModelConfig, batch: int, s_max: int,
                      index: int = 0) -> DecodeState:
    """Empty caches at full length — the decode-only benchmark entrypoint
    (the decode_32k / long_500k cells lower THIS, with index = seq_len)."""
    dtype = jnp.dtype(cfg.dtype)
    L = cfg.num_layers

    def layer_cache() -> dict:
        c: dict[str, Any] = {}
        if cfg.has_attention:
            c["kv"] = (attn.mla_empty_cache(cfg, batch, s_max, dtype)
                       if cfg.attention == "mla"
                       else attn.gqa_empty_cache(cfg, batch, s_max, dtype))
        if cfg.has_ssm:
            c["ssm"] = ssm_mod.ssm_empty_cache(cfg, batch, dtype)
        return c

    if cfg.scan_layers:
        one = layer_cache()
        cache: Any = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), one)
    else:
        cache = [layer_cache() for _ in range(L)]
    logits = jnp.zeros((batch, 1, cfg.padded_vocab), jnp.float32)
    return DecodeState(cache, jnp.int32(index), logits)


def decode_step(cfg: ModelConfig, params: dict, state: DecodeState,
                tokens: jax.Array) -> DecodeState:
    """One token for every sequence. tokens: (B, 1) int32."""
    dtype = jnp.dtype(cfg.dtype)
    x = embed_tokens(params["embed"], tokens, dtype)
    index = state.index

    def body(h, lp_cache):
        lp, cache = lp_cache
        h2, new_cache = block_decode(cfg, lp, h, cache, index)
        return h2, new_cache

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, (params["layers"], state.cache))
    else:
        new_list = []
        for lp, c in zip(params["layers"], state.cache):
            x, nc = block_decode(cfg, lp, x, c, index)
            new_list.append(nc)
        new_caches = new_list
    x = rms_norm(x, params["embed"]["final_norm"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x, cfg.tie_embeddings)
    return DecodeState(new_caches, index + 1, logits)
