"""Mamba-1 selective SSM (falcon-mamba-7b; the SSM half of hymba).

Sequence path uses a *chunked* associative scan: an outer ``lax.scan`` over
time blocks carries the (B, d_inner, N) state, an inner
``lax.associative_scan`` parallelises within the block.  This bounds
activation memory to O(block) instead of O(S) — required for the
prefill_32k / long_500k cells — while keeping the parallel-scan depth the
TPU likes.  The Pallas kernel in ``repro.kernels.selective_scan`` implements
the same block recurrence with VMEM-resident state.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed import context as ctx

from .config import ModelConfig
from .layers import ParamDef


class SSMCache(NamedTuple):
    conv: jax.Array    # (B, K-1, d_inner) — last K-1 pre-conv inputs
    state: jax.Array   # (B, d_inner, N) — SSM hidden state


def ssm_table(cfg: ModelConfig) -> dict[str, ParamDef]:
    D, di = cfg.d_model, cfg.ssm_d_inner
    N, K, R = cfg.ssm_state, cfg.ssm_conv, cfg.ssm_dt_rank
    return {
        "in_proj": ParamDef((D, 2 * di), ("embed", "ssm_inner")),
        "conv_w": ParamDef((K, di), (None, "ssm_inner"), scale=0.5),
        "conv_b": ParamDef((di,), ("ssm_inner",), init="zeros"),
        "x_proj": ParamDef((di, R + 2 * N), ("ssm_inner", None)),
        "dt_proj": ParamDef((R, di), (None, "ssm_inner")),
        "dt_bias": ParamDef((di,), ("ssm_inner",), init="zeros"),
        "A_log": ParamDef((di, N), ("ssm_inner", None), init="ones"),
        "D": ParamDef((di,), ("ssm_inner",), init="ones"),
        "out_proj": ParamDef((di, D), ("ssm_inner", "embed")),
    }


def _ssm_coeffs(cfg: ModelConfig, p: dict, xc: jax.Array):
    """xc: (B, S, di) post-conv activations -> dt, B_t, C_t (f32)."""
    R, N = cfg.ssm_dt_rank, cfg.ssm_state
    proj = jnp.einsum("bsd,dr->bsr", xc, p["x_proj"].astype(xc.dtype))
    dt, Bt, Ct = jnp.split(proj.astype(jnp.float32), [R, R + N], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt, p["dt_proj"].astype(jnp.float32))
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(jnp.float32))
    return dt, Bt, Ct


def _causal_conv(cfg: ModelConfig, p: dict, x: jax.Array,
                 left_ctx: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv1d. x: (B, S, di). left_ctx: (B, K-1, di)."""
    K = cfg.ssm_conv
    if left_ctx is None:
        left_ctx = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([left_ctx, x], axis=1)          # (B, S+K-1, di)
    w = p["conv_w"].astype(x.dtype)                      # (K, di)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + p["conv_b"].astype(x.dtype)


def ssm_forward(cfg: ModelConfig, p: dict, x: jax.Array,
                block: int = 0) -> tuple[jax.Array, SSMCache]:
    """Full-sequence selective scan. x: (B, S, D) -> (B, S, D).

    Returns the final SSMCache so prefill can hand off to decode.
    """
    B, S, D = x.shape
    if block <= 0:
        block = cfg.ssm_block if cfg.ssm_block > 0 else S
        block = min(block, S)
    di, N, K = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_conv
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    xin, z = jnp.split(xz, 2, axis=-1)                    # (B, S, di) each
    # d_inner-sharded activations (matches the ssm_inner weight sharding):
    # the (B, blk, di, N) scan intermediates are 16*N x the residual size,
    # so leaving di unsharded melts HBM at the 32k/500k cells
    xin = ctx.constrain(xin, ctx.dp(), None, "model")
    z = ctx.constrain(z, ctx.dp(), None, "model")
    xc = jax.nn.silu(_causal_conv(cfg, p, xin))
    xc = ctx.constrain(xc, ctx.dp(), None, "model")
    dt, Bt, Ct = _ssm_coeffs(cfg, p, xc)
    dt = ctx.constrain(dt, ctx.dp(), None, "model")
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (di, N)

    nb = -(-S // block)
    pad = nb * block - S
    if pad:
        padded = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        xc_, dt_, Bt_, Ct_ = map(padded, (xc, dt, Bt, Ct))
    else:
        xc_, dt_, Bt_, Ct_ = xc, dt, Bt, Ct

    def blockify(a):
        return jnp.moveaxis(a.reshape(B, nb, block, -1), 1, 0)

    xb, dtb, Btb, Ctb = map(blockify, (xc_, dt_, Bt_, Ct_))

    scan_dt = jnp.bfloat16 if cfg.ssm_bf16 else jnp.float32

    def block_step(h, inp):
        xj, dtj, Bj, Cj = inp                             # (B, blk, ·)
        # a_t = exp(dt_t A): (B, blk, di, N); b_t = dt_t * B_t * x_t
        a = jnp.exp(dtj[..., None] * A).astype(scan_dt)   # (B, blk, di, N)
        a = ctx.constrain(a, ctx.dp(), None, "model", None)
        b = ((dtj * xj.astype(jnp.float32))[..., None]
             * Bj[:, :, None, :]).astype(scan_dt)
        b = ctx.constrain(b, ctx.dp(), None, "model", None)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        hs = a_cum.astype(jnp.float32) * h[:, None] \
            + b_cum.astype(jnp.float32)                   # (B, blk, di, N)
        y = jnp.einsum("bsdn,bsn->bsd", hs.astype(scan_dt),
                       Cj.astype(scan_dt)).astype(jnp.float32)
        return hs[:, -1], y

    h0 = jnp.zeros((B, di, N), jnp.float32)
    if cfg.unroll_inner:
        h = h0
        ys = []
        for j in range(nb):
            h, yj = block_step(h, (xb[j], dtb[j], Btb[j], Ctb[j]))
            ys.append(yj)
        h_last, yb = h, jnp.stack(ys)
    else:
        h_last, yb = jax.lax.scan(block_step, h0, (xb, dtb, Btb, Ctb))
    y = jnp.moveaxis(yb, 0, 1).reshape(B, nb * block, di)[:, :S]
    y = ctx.constrain(y, ctx.dp(), None, "model")
    y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(x.dtype))
    conv_tail = jnp.concatenate(
        [jnp.zeros((B, K - 1, di), x.dtype), xin], axis=1)[:, -(K - 1):]
    return out, SSMCache(conv_tail, h_last)


def ssm_decode(cfg: ModelConfig, p: dict, x: jax.Array,
               cache: SSMCache) -> tuple[jax.Array, SSMCache]:
    """One-token recurrent step. x: (B, 1, D)."""
    B = x.shape[0]
    di, N, K = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_conv
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    xin, z = jnp.split(xz, 2, axis=-1)                    # (B, 1, di)
    window = jnp.concatenate([cache.conv, xin], axis=1)   # (B, K, di)
    w = p["conv_w"].astype(x.dtype)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, w)
                     + p["conv_b"].astype(x.dtype))[:, None, :]
    dt, Bt, Ct = _ssm_coeffs(cfg, p, xc)                  # (B, 1, ·)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[0 if False else ...][..., None] * A)[:, 0]   # (B, di, N)
    b = ((dt * xc.astype(jnp.float32))[..., None]
         * Bt[:, :, None, :])[:, 0]                       # (B, di, N)
    h = cache.state * a + b
    y = jnp.einsum("bdn,bn->bd", h, Ct[:, 0])
    y = y + xc[:, 0].astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z[:, 0]))[:, None, :]
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(x.dtype))
    return out, SSMCache(window[:, 1:], h)


def ssm_empty_cache(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    return SSMCache(
        jnp.zeros((batch, cfg.ssm_conv - 1, cfg.ssm_d_inner), dtype),
        jnp.zeros((batch, cfg.ssm_d_inner, cfg.ssm_state), jnp.float32))
