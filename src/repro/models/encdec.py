"""Encoder–decoder transformer (seamless-m4t-large-v2 backbone).

The speech frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, S_enc, D).  The encoder is a bidirectional
transformer over frames; the decoder is a causal LM with cross-attention.
Decode shapes exercise the decoder with cached encoder output + self KV.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed import context as ctx

from . import attention as attn
from .config import ModelConfig
from .layers import (ParamDef, embed_table, embed_tokens, init_table,
                     lm_logits, mlp_forward, mlp_table, rms_norm, table_specs)


def cross_table(cfg: ModelConfig) -> dict[str, ParamDef]:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": ParamDef((D, H * hd), ("embed", "heads")),
        "wk": ParamDef((D, KV * hd), ("embed", "kv_heads")),
        "wv": ParamDef((D, KV * hd), ("embed", "kv_heads")),
        "wo": ParamDef((H * hd, D), ("heads", "embed")),
    }


def _cross_kv(cfg, p, enc_out):
    B, Se, _ = enc_out.shape
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,dh->bsh", enc_out,
                   p["wk"].astype(enc_out.dtype)).reshape(B, Se, KV, hd)
    v = jnp.einsum("bsd,dh->bsh", enc_out,
                   p["wv"].astype(enc_out.dtype)).reshape(B, Se, KV, hd)
    return k, v


def cross_forward(cfg: ModelConfig, p: dict, x: jax.Array, k, v) -> jax.Array:
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x,
                   p["wq"].astype(x.dtype)).reshape(B, S, H, hd)
    chunk = cfg.attn_chunk if cfg.attn_chunk > 0 else k.shape[1]
    out = attn.chunked_attention(q, k, v, causal=False, chunk=chunk,
                                 unroll=cfg.unroll_inner)
    return jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd),
                      p["wo"].astype(x.dtype))


# -- layer tables -------------------------------------------------------------

def enc_block_tables(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    return {
        "attn": attn.gqa_table(cfg),
        "norm_attn": {"scale": ParamDef((D,), ("embed",), init="ones")},
        "mlp": mlp_table(D, cfg.d_ff),
        "norm_mlp": {"scale": ParamDef((D,), ("embed",), init="ones")},
    }


def dec_block_tables(cfg: ModelConfig) -> dict:
    t = enc_block_tables(cfg)
    t["cross"] = cross_table(cfg)
    t["norm_cross"] = {"scale": ParamDef((cfg.d_model,), ("embed",),
                                         init="ones")}
    return t


def _init_block(tables: dict, key, dtype) -> dict:
    keys = jax.random.split(key, len(tables))
    return {name: init_table(k, tbl, dtype)
            for (name, tbl), k in zip(sorted(tables.items()), keys)}


def init_params(cfg: ModelConfig, key: jax.Array, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    k_emb, k_enc, k_dec = jax.random.split(key, 3)
    params = {
        "embed": init_table(
            k_emb,
            embed_table(cfg.padded_vocab, cfg.d_model, cfg.tie_embeddings),
            dtype),
        "enc_norm": {"scale": jnp.ones((cfg.d_model,), dtype)},
    }
    enc_keys = jax.random.split(k_enc, cfg.num_encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    if cfg.scan_layers:
        params["enc_layers"] = jax.vmap(
            lambda k: _init_block(enc_block_tables(cfg), k, dtype))(enc_keys)
        params["dec_layers"] = jax.vmap(
            lambda k: _init_block(dec_block_tables(cfg), k, dtype))(dec_keys)
    else:
        params["enc_layers"] = [_init_block(enc_block_tables(cfg), k, dtype)
                                for k in enc_keys]
        params["dec_layers"] = [_init_block(dec_block_tables(cfg), k, dtype)
                                for k in dec_keys]
    return params


def param_specs(cfg: ModelConfig) -> dict:
    lead = ("layers",) if cfg.scan_layers else ()

    def specs(tables):
        one = {name: {pn: lead + tuple(ax)
                      for pn, ax in table_specs(tbl).items()}
               for name, tbl in tables.items()}
        return one
    enc = specs(enc_block_tables(cfg))
    dec = specs(dec_block_tables(cfg))
    return {
        "embed": table_specs(
            embed_table(cfg.padded_vocab, cfg.d_model, cfg.tie_embeddings)),
        "enc_norm": {"scale": ("embed",)},
        "enc_layers": enc if cfg.scan_layers
        else [enc for _ in range(cfg.num_encoder_layers)],
        "dec_layers": dec if cfg.scan_layers
        else [dec for _ in range(cfg.num_layers)],
    }


# -- forward ------------------------------------------------------------------

def _enc_block(cfg, p, x, positions):
    x = ctx.constrain(x, ctx.dp(), None, None)
    h = rms_norm(x, p["norm_attn"]["scale"], cfg.norm_eps)
    a, _ = attn.gqa_forward(cfg, p["attn"], h, positions, causal=False)
    x = x + a
    h = rms_norm(x, p["norm_mlp"]["scale"], cfg.norm_eps)
    return x + mlp_forward(p["mlp"], h, cfg.act)


def _dec_block(cfg, p, x, positions, enc_out):
    x = ctx.constrain(x, ctx.dp(), None, None)
    h = rms_norm(x, p["norm_attn"]["scale"], cfg.norm_eps)
    a, kv = attn.gqa_forward(cfg, p["attn"], h, positions, causal=True)
    x = x + a
    h = rms_norm(x, p["norm_cross"]["scale"], cfg.norm_eps)
    ck, cv = _cross_kv(cfg, p["cross"], enc_out)
    x = x + cross_forward(cfg, p["cross"], h, ck, cv)
    h = rms_norm(x, p["norm_mlp"]["scale"], cfg.norm_eps)
    return x + mlp_forward(p["mlp"], h, cfg.act), kv


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    block = functools.partial(_enc_block, cfg)
    if cfg.remat != "none":
        block = jax.checkpoint(block)

    x = frames.astype(jnp.dtype(cfg.dtype))
    if cfg.scan_layers:
        def body(h, lp):
            return block(lp, h, positions), None
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    else:
        for lp in params["enc_layers"]:
            x = block(lp, x, positions)
    return rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)


def forward(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    """batch: {"frames": (B,Se,D), "tokens": (B,Sd)} -> logits (B,Sd,V)."""
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    B, Sd = tokens.shape
    x = embed_tokens(params["embed"], tokens, jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(Sd, dtype=jnp.int32)[None],
                                 (B, Sd))
    block = functools.partial(_dec_block, cfg)
    if cfg.remat != "none":
        block = jax.checkpoint(block)

    if cfg.scan_layers:
        def body(h, lp):
            h2, _ = block(lp, h, positions, enc_out)
            return h2, None
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
    else:
        for lp in params["dec_layers"]:
            x, _ = block(lp, x, positions, enc_out)
    x = rms_norm(x, params["embed"]["final_norm"], cfg.norm_eps)
    return lm_logits(params["embed"], x, cfg.tie_embeddings)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    from .transformer import cross_entropy
    logits = forward(cfg, params, batch)
    return cross_entropy(logits, batch["labels"], batch.get("loss_mask"),
                         real_vocab=cfg.vocab_size)


# -- serving -----------------------------------------------------------------

class EncDecState(NamedTuple):
    self_kv: Any          # (L, B, s_max, KV, hd) x2
    cross_k: jax.Array    # (L, B, Se, KV, hd)
    cross_v: jax.Array
    index: jax.Array
    last_logits: jax.Array


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            s_max: int) -> EncDecState:
    enc_out = encode(cfg, params, batch["frames"])

    if cfg.scan_layers:
        def kv_body(_, lp):
            return None, _cross_kv(cfg, lp["cross"], enc_out)
        _, (ck, cv) = jax.lax.scan(kv_body, None, params["dec_layers"])
    else:
        pairs = [_cross_kv(cfg, lp["cross"], enc_out)
                 for lp in params["dec_layers"]]
        ck = [c for c, _ in pairs]
        cv = [v for _, v in pairs]
    B = enc_out.shape[0]
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    L = cfg.num_layers
    dt = jnp.dtype(cfg.dtype)
    if cfg.scan_layers:
        kv = attn.KVCache(jnp.zeros((L, B, s_max, KV, hd), dt),
                          jnp.zeros((L, B, s_max, KV, hd), dt))
    else:
        kv = [attn.KVCache(jnp.zeros((B, s_max, KV, hd), dt),
                           jnp.zeros((B, s_max, KV, hd), dt))
              for _ in range(L)]
    logits = jnp.zeros((B, 1, cfg.padded_vocab), jnp.float32)
    return EncDecState(kv, ck, cv, jnp.int32(0), logits)


def init_decode_state(cfg: ModelConfig, batch: int, s_enc: int, s_max: int,
                      index: int = 0) -> EncDecState:
    """Decode-only entry (benchmark cells): encoder output already cached."""
    dtype = jnp.dtype(cfg.dtype)
    KV, hd, L = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    if cfg.scan_layers:
        kv = attn.KVCache(jnp.zeros((L, batch, s_max, KV, hd), dtype),
                          jnp.zeros((L, batch, s_max, KV, hd), dtype))
        ck = jnp.zeros((L, batch, s_enc, KV, hd), dtype)
        cv = jnp.zeros((L, batch, s_enc, KV, hd), dtype)
    else:
        kv = [attn.KVCache(jnp.zeros((batch, s_max, KV, hd), dtype),
                           jnp.zeros((batch, s_max, KV, hd), dtype))
              for _ in range(L)]
        ck = [jnp.zeros((batch, s_enc, KV, hd), dtype) for _ in range(L)]
        cv = [jnp.zeros((batch, s_enc, KV, hd), dtype) for _ in range(L)]
    logits = jnp.zeros((batch, 1, cfg.padded_vocab), jnp.float32)
    return EncDecState(kv, ck, cv, jnp.int32(index), logits)


def decode_step(cfg: ModelConfig, params: dict, state: EncDecState,
                tokens: jax.Array) -> EncDecState:
    dtype = jnp.dtype(cfg.dtype)
    x = embed_tokens(params["embed"], tokens, dtype)
    index = state.index
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def body(h, lp_cache):
        lp, kv, ck, cv = lp_cache
        hh = rms_norm(h, lp["norm_attn"]["scale"], cfg.norm_eps)
        a, new_kv = attn.gqa_decode(cfg, lp["attn"], hh, kv, index)
        h = h + a
        hh = rms_norm(h, lp["norm_cross"]["scale"], cfg.norm_eps)
        q = jnp.einsum("bsd,dh->bsh", hh,
                       lp["cross"]["wq"].astype(dtype)).reshape(B, 1, H, hd)
        co = attn.chunked_attention(q, ck, cv, causal=False)
        h = h + jnp.einsum("bsh,hd->bsd", co.reshape(B, 1, H * hd),
                           lp["cross"]["wo"].astype(dtype))
        hh = rms_norm(h, lp["norm_mlp"]["scale"], cfg.norm_eps)
        h = h + mlp_forward(lp["mlp"], hh, cfg.act)
        return h, new_kv

    if cfg.scan_layers:
        x, new_kv = jax.lax.scan(
            body, x, (params["dec_layers"], state.self_kv,
                      state.cross_k, state.cross_v))
    else:
        new_kv = []
        for lp, kv, ck, cv in zip(params["dec_layers"], state.self_kv,
                                  state.cross_k, state.cross_v):
            x, nk = body(x, (lp, kv, ck, cv))
            new_kv.append(nk)
    x = rms_norm(x, params["embed"]["final_norm"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x, cfg.tie_embeddings)
    return EncDecState(new_kv, state.cross_k, state.cross_v,
                       index + 1, logits)
