"""Model definitions: config registry + unified functional API."""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

from . import encdec, transformer
from .config import ModelConfig, get_config, list_configs, register


class ModelApi(NamedTuple):
    cfg: ModelConfig
    init_params: Callable
    param_specs: Callable
    forward: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    init_decode_state: Callable


def get_model(cfg: ModelConfig) -> ModelApi:
    mod = encdec if cfg.is_encoder_decoder else transformer
    return ModelApi(
        cfg=cfg,
        init_params=lambda key, dtype=None: mod.init_params(cfg, key, dtype),
        param_specs=lambda: mod.param_specs(cfg),
        forward=lambda params, batch: mod.forward(cfg, params, batch),
        loss_fn=lambda params, batch: mod.loss_fn(cfg, params, batch),
        prefill=lambda params, batch, s_max: mod.prefill(
            cfg, params, batch, s_max),
        decode_step=lambda params, state, tokens: mod.decode_step(
            cfg, params, state, tokens),
        init_decode_state=lambda *a, **kw: mod.init_decode_state(
            cfg, *a, **kw),
    )


__all__ = ["ModelConfig", "ModelApi", "get_model", "get_config",
           "list_configs", "register"]
