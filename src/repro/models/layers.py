"""Shared layers and the declarative parameter-table mechanism.

Every block declares its parameters once as ``name -> ParamDef(shape,
logical_axes, init)``; both ``init_params`` (values) and ``param_specs``
(logical sharding axes, consumed by repro.distributed.sharding) derive from
the same table, so they cannot drift.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple                       # logical axis names, len == len(shape)
    init: str = "normal"              # normal | zeros | ones | small_normal
    scale: Optional[float] = None     # stddev override


def init_table(key: jax.Array, table: dict[str, ParamDef],
               dtype=jnp.float32) -> dict[str, jax.Array]:
    keys = jax.random.split(key, len(table))
    out = {}
    for (name, pd), k in zip(sorted(table.items()), keys):
        if pd.init == "zeros":
            out[name] = jnp.zeros(pd.shape, dtype)
        elif pd.init == "ones":
            out[name] = jnp.ones(pd.shape, dtype)
        else:
            fan_in = pd.shape[0] if len(pd.shape) >= 2 else pd.shape[-1]
            if len(pd.shape) == 3:    # stacked expert weights: (E, in, out)
                fan_in = pd.shape[1]
            std = pd.scale if pd.scale is not None else 1.0 / math.sqrt(fan_in)
            out[name] = (jax.random.normal(k, pd.shape, jnp.float32)
                         * std).astype(dtype)
    return out


def table_specs(table: dict[str, ParamDef]) -> dict[str, tuple]:
    return {name: pd.axes for name, pd in table.items()}


# --------------------------------------------------------------------------
# normalisation
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# rotary embeddings (RoPE + M-RoPE)
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                            # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., S, hd/2)
    ang = ang[..., None, :]                                # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: tuple) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, hd);  positions3: (B, S, 3) — (temporal, height, width)
    position ids.  ``sections`` partitions the hd/2 frequency slots among the
    three axes (e.g. (16, 24, 24) for hd=128).
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    inv = rope_freqs(hd, theta)                            # (hd/2,)
    # pick which position axis drives each frequency slot
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=hd // 2)       # (hd/2,)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                    # (B, S, 3)
        jnp.broadcast_to(sec_id, positions3.shape[:-1] + (hd // 2,)).astype(
            jnp.int32),
        axis=-1)                                           # (B, S, hd/2)
    ang = (pos * inv)[..., None, :]                        # (B, S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def mlp_table(d_model: int, d_ff: int) -> dict[str, ParamDef]:
    return {
        "w_gate": ParamDef((d_model, d_ff), ("embed", "mlp")),
        "w_up": ParamDef((d_model, d_ff), ("embed", "mlp")),
        "w_down": ParamDef((d_ff, d_model), ("mlp", "embed")),
    }


def mlp_forward(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = _activate(h, act) * u
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


def _activate(x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x)
    if act == "relu":
        return jax.nn.relu(x)
    raise ValueError(act)


# --------------------------------------------------------------------------
# embedding / unembedding
# --------------------------------------------------------------------------

def embed_table(vocab: int, d_model: int, tie: bool) -> dict[str, ParamDef]:
    t = {
        "embedding": ParamDef((vocab, d_model), ("vocab", "embed"),
                              scale=1.0),
        "final_norm": ParamDef((d_model,), ("embed",), init="ones"),
    }
    if not tie:
        t["lm_head"] = ParamDef((d_model, vocab), ("embed", "vocab"))
    return t


def embed_tokens(p: dict, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0).astype(dtype)


def lm_logits(p: dict, x: jax.Array, tie: bool) -> jax.Array:
    if tie:
        w = p["embedding"].astype(x.dtype)
        return jnp.einsum("bsd,vd->bsv", x, w)
    return jnp.einsum("bsd,dv->bsv", x, p["lm_head"].astype(x.dtype))
