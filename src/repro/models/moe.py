"""Mixture-of-Experts with capacity-bounded scatter dispatch.

Routing is top-k softmax.  Token slot positions inside each expert's
capacity buffer are computed by *stable-argsort ranking* (memory O(B·S·k)
int32 — NOT the O(B·S·k·E) one-hot cumsum of textbook GShard, which is the
difference between 2 GB and 67 GB per chip at the 32k-seq cells).  Tokens
are scattered into (B, E, C, D) buffers with ``C = ceil(k*S/E * cf)`` per
batch row, experts run as one batched einsum, results are gathered back and
gate-combined.  Compute is proportional to *active* experts, matching the
roofline MODEL_FLOPS = 6·N_active·D accounting.  Overflow tokens are
dropped (standard GShard semantics; the residual path carries them).

Sharding (see repro.distributed.sharding):
  expert_sharding="expert": expert dim over `model` (true EP) — the buffers
      are constrained to P(dp, "model", ...) so GSPMD materialises the
      token all-to-all at the dispatch/return boundaries.
  expert_sharding="ffn":    expert weights split over d_ff on `model`
      (TP inside every expert; no all-to-all; right for few-huge-expert
      models like grok-1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import context as ctx

from .config import ModelConfig
from .layers import ParamDef, _activate


def moe_table(cfg: ModelConfig) -> dict[str, ParamDef]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ParamDef((D, E), ("embed", "expert")),
        "w_gate": ParamDef((E, D, F), ("expert", "embed", "mlp")),
        "w_up": ParamDef((E, D, F), ("expert", "embed", "mlp")),
        "w_down": ParamDef((E, F, D), ("expert", "mlp", "embed")),
    }


def capacity(cfg: ModelConfig, seq_len: int) -> int:
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    c = int(k * seq_len * cfg.moe_capacity_factor / E) + 1
    return max(8, -(-c // 8) * 8)        # pad to a multiple of 8


def _route(cfg: ModelConfig, p: dict, x: jax.Array):
    """top-k gates + capacity positions via argsort ranking."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)       # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    T = S * k
    e_flat = expert_idx.reshape(B, T)                     # (B, T) int32
    e_flat = ctx.constrain(e_flat, ctx.dp(), None)
    order = jnp.argsort(e_flat, axis=1, stable=True)      # (B, T)
    rank = jnp.argsort(order, axis=1)                     # inverse perm
    counts = jax.vmap(lambda e: jnp.zeros((E,), jnp.int32).at[e].add(1))(
        e_flat)                                           # (B, E)
    starts = jnp.cumsum(counts, axis=1) - counts          # exclusive
    pos = rank - jnp.take_along_axis(starts, e_flat, axis=1)  # (B, T)
    return gate_vals, expert_idx, e_flat, pos.reshape(B, S, k)


def moe_forward(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    C = capacity(cfg, S)
    T = S * k
    ep = cfg.expert_sharding == "expert"
    e_shard = "model" if ep else None

    gate_vals, expert_idx, e_flat, pos = _route(cfg, p, x)
    keep = pos < C                                        # (B, S, k)
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # scatter tokens into (B, E, C, D); dropped tokens add zeros to slot 0
    slot = jnp.where(keep, expert_idx * C + pos, 0)       # (B, S, k)
    tok = jnp.broadcast_to(x[:, :, None, :], (B, S, k, D)).reshape(B, T, D)
    tok = tok * keep.reshape(B, T, 1).astype(x.dtype)
    tok = ctx.constrain(tok, ctx.dp(), None, None)
    buf = jnp.zeros((B, E * C, D), x.dtype)
    buf = jax.vmap(lambda b, s_, t: b.at[s_].add(t))(
        buf, slot.reshape(B, T), tok)
    xe = buf.reshape(B, E, C, D)
    xe = ctx.constrain(xe, ctx.dp(), e_shard, None, None)

    # expert computation (batched over E; weights sharded EP or TP)
    h = jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(x.dtype))
    y = _activate(h, cfg.act) * u
    ye = jnp.einsum("becf,efd->becd", y, p["w_down"].astype(x.dtype))
    ye = ctx.constrain(ye, ctx.dp(), e_shard, None, None)

    # gather back and combine with gates
    yflat = ye.reshape(B, E * C, D)
    ytok = jnp.take_along_axis(yflat, slot.reshape(B, T, 1), axis=1)
    ytok = ctx.constrain(ytok, ctx.dp(), None, None)
    ytok = ytok.reshape(B, S, k, D) * gate_vals[..., None].astype(x.dtype)
    return ytok.sum(axis=2)


def moe_forward_dense_reference(cfg: ModelConfig, p: dict,
                                x: jax.Array) -> jax.Array:
    """Oracle: run EVERY expert on every token, combine with the same top-k
    gates, no capacity dropping.  Used by tests to validate dispatch."""
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)
    h = jnp.einsum("bsd,edf->bsef", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,edf->bsef", x, p["w_up"].astype(x.dtype))
    y = _activate(h, cfg.act) * u
    ye = jnp.einsum("bsef,efd->bsed", y, p["w_down"].astype(x.dtype))
    out = jnp.zeros_like(x)
    for slot_i in range(k):
        w = gate_vals[..., slot_i][..., None].astype(x.dtype)
        sel = jnp.take_along_axis(
            ye, expert_idx[..., slot_i][..., None, None].astype(jnp.int32),
            axis=2)[:, :, 0, :]
        out = out + w * sel
    return out
