"""Fault-tolerant checkpointing: atomic on-disk layout, async save thread,
elastic restore (re-shard onto whatever mesh the restarted job has).

Layout (one directory per step):
    <dir>/step_000120/
        manifest.json        # tree structure, shapes, dtypes, leaf -> file
        leaf_00000.npy ...   # one file per pytree leaf
        COMMIT               # written last; restore ignores dirs without it

Atomicity = write into step_xxx.tmp, fsync, rename, then COMMIT marker.
Restore takes an optional ``shardings`` pytree and ``device_put``s each leaf
straight to its (possibly different) target sharding — that is the elastic
path: a 512-chip job's checkpoint restores onto 256 chips (or 1 CPU) by
construction, because leaves are stored unsharded.

At real pod scale you would store per-shard files (à la Orbax/TensorStore);
the manifest format already records shardings to make that swap local.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Blocking save. Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten_with_paths(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "extra": extra or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr, allow_pickle=False)
        manifest["leaves"].append(
            {"file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # commit: rename + marker
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    with open(os.path.join(final, "COMMIT"), "w") as f:
        f.write("ok")
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, name, "COMMIT")):
            try:
                steps.append(int(name[5:]))
            except ValueError:
                pass
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: Optional[int],
                       target_tree: Any,
                       shardings: Any = None) -> tuple[Any, int, dict]:
    """Restore into the structure of ``target_tree``; if ``shardings`` is
    given (a matching pytree of Sharding), each leaf is device_put to it —
    this is how a checkpoint moves between mesh shapes (elastic restart)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(target_tree)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, target has "
            f"{len(leaves)} — structure mismatch")
    sh_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                 else [None] * len(leaves))
    out = []
    for i, (meta, tgt, sh) in enumerate(
            zip(manifest["leaves"], leaves, sh_leaves)):
        arr = np.load(os.path.join(path, meta["file"]), allow_pickle=False)
        want = tuple(np.shape(tgt))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != target {want}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return treedef.unflatten(out), step, manifest.get("extra", {})


class CheckpointManager:
    """Async, bounded-retention checkpoint manager for the training loop."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             blocking: bool = False) -> None:
        # snapshot to host BEFORE returning control (the training loop will
        # donate/overwrite the device buffers)
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                 tree)
        self.wait()

        def work():
            save_checkpoint(self.directory, step, host_tree, extra)
            self.last_saved = step
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(n[5:]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.directory, n, "COMMIT")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, target_tree: Any, shardings: Any = None):
        return restore_checkpoint(self.directory, None, target_tree,
                                  shardings)
