"""Result aggregation: the verdict layer of the platform.

The sibling cloud-platform paper makes the aggregation stage — not raw
replay — the product of the pipeline: "massive functional and performance
tests" only matter once merged, compared and scored.  This module turns
per-partition/per-shard output bag images into exactly that:

    partition images --merge_bags--> one time-ordered output Bag
        --metrics--> per-topic TopicMetrics (counts, gaps, checksums)
        --golden compare--> list[Diff]
        --> Verdict (PASS / PASS-vacuous / FAIL)

Metric reductions run over the same fixed-layout arrays batched replay
uses (:func:`repro.data.pipeline.assemble_message_batch`): payload
checksums are a wrapping-uint32 reduction of *per-record digests*, so the
hot path stays on-device and amortises like the decode stage.  Checksums
are *order-free across records* but position- and timestamp-sensitive
within a record — the same fleet produces the same checksum regardless of
shard/partition/batch split, while any payload or timestamp perturbation
flips it.

Since ISSUE 3 the metric stage is **single-pass and off-driver**:

* per-record digests come pre-reduced — either from the fused Pallas
  kernel (:func:`repro.kernels.sensor_decode.sensor_decode_metrics`,
  which emits them in the same grid sweep that decodes the payload) or
  from the jitted ``record_digest`` reduction over one time-ordered scan,
* :class:`TopicMetrics` carries its (sorted) per-topic timestamps and is
  a *mergeable partial*: :meth:`TopicMetrics.merge` combines partials
  from different shards/partitions associatively and exactly (counts,
  bytes and checksums add; time bounds extend; gap percentiles are
  recomputed from the merged timestamp multiset), so workers ship
  KB-sized digests instead of the driver re-reading MB-sized payloads.

``Aggregator`` is the pipeline stage ``ScenarioSuite.run`` schedules per
scenario; it can also be used standalone against recorded bags for
offline triage.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Iterable, Optional, Sequence

import numpy as np

from .bag import (Bag, BagSource, Message, _open_source, iter_time_ordered,
                  merge_bags)

_U32 = np.uint64(0xFFFFFFFF)        # digests live in wrapping uint32 space

# Lazily-built jitted reductions (importing jax at module import would tax
# every core/ consumer that never aggregates).
_JITTED: dict[str, Any] = {}


def _jitted():
    if not _JITTED:
        import jax
        import jax.numpy as jnp

        def _record_digest(payload, lengths, ts_low):
            """Per-record wrapping-uint32 digests of one assembled batch.

            payload: (R, Nb) uint8, lengths: (R,) i32, ts_low: (R,) u32
            (timestamps mod 2**32).  Per-record digest = position-weighted
            byte sum mixed with the timestamp; the value depends only on a
            record's own (bytes, length, timestamp), never on batch
            composition.  The fused Pallas kernel
            (:func:`repro.kernels.sensor_decode.sensor_decode_metrics`)
            computes the same reduction op-for-op in the decode sweep.
            """
            p = payload.astype(jnp.uint32)
            col = jnp.arange(payload.shape[1], dtype=jnp.uint32)
            mask = col[None, :] < lengths.astype(jnp.uint32)[:, None]
            w = col * jnp.uint32(2246822519) + jnp.uint32(0x9E3779B9)
            rec = jnp.sum(jnp.where(mask, p * w[None, :], 0), axis=1,
                          dtype=jnp.uint32)
            rec = (rec ^ ts_low.astype(jnp.uint32)) * jnp.uint32(2654435761)
            return rec + lengths.astype(jnp.uint32) * jnp.uint32(40503)

        @jax.jit
        def record_digest(payload, lengths, ts_low):
            return _record_digest(payload, lengths, ts_low)

        @jax.jit
        def digest(payload, lengths, ts_low):
            """Batch total: wrapping sum of the per-record digests, so it
            is invariant to record order and batch split."""
            return jnp.sum(_record_digest(payload, lengths, ts_low),
                           dtype=jnp.uint32)

        @jax.jit
        def max_abs_diff(a, a_len, b, b_len):
            """Max per-byte |a - b| over the valid prefix of each record
            pair (padding excluded); (R, Nb) uint8 x2 -> scalar i32."""
            col = jnp.arange(a.shape[1], dtype=jnp.int32)
            valid = col[None, :] < jnp.minimum(a_len, b_len)[:, None]
            d = jnp.abs(a.astype(jnp.int32) - b.astype(jnp.int32))
            return jnp.max(jnp.where(valid, d, 0))

        _JITTED["record_digest"] = record_digest
        _JITTED["digest"] = digest
        _JITTED["max_abs_diff"] = max_abs_diff
    return _JITTED


def record_digests_np(payload: np.ndarray, lengths: np.ndarray,
                      ts_low: np.ndarray) -> np.ndarray:
    """Pure-numpy per-record digests, bit-identical to the jitted
    ``record_digest`` reduction and the fused Pallas kernel (wrapping
    uint32 arithmetic is the same in all three).

    This is the **fork-safe engine**: process-backend workers compute
    partial metrics with it, because initialising jax inside a forked
    worker of a jax-multithreaded driver can deadlock, and a per-process
    jit warm-up would tax every worker.  Device contexts use the Pallas
    kernel (metrics ride the decode sweep) or the jitted reduction.
    """
    p = payload.astype(np.uint32)
    col = np.arange(payload.shape[1], dtype=np.uint32)
    mask = col[None, :] < lengths.astype(np.uint32)[:, None]
    w = col * np.uint32(2246822519) + np.uint32(0x9E3779B9)
    rec = np.where(mask, p * w[None, :], np.uint32(0)).sum(
        axis=1, dtype=np.uint32)
    rec = (rec ^ ts_low.astype(np.uint32)) * np.uint32(2654435761)
    return rec + lengths.astype(np.uint32) * np.uint32(40503)


def _max_abs_diff_np(a: np.ndarray, a_len: np.ndarray,
                     b: np.ndarray, b_len: np.ndarray) -> int:
    """Numpy twin of the jitted ``max_abs_diff`` tolerance reduction."""
    col = np.arange(a.shape[1], dtype=np.int32)
    valid = col[None, :] < np.minimum(a_len, b_len)[:, None]
    d = np.abs(a.astype(np.int32) - b.astype(np.int32))
    return int(np.where(valid, d, 0).max(initial=0))


def combine_digests(record_digests: "np.ndarray | Sequence[int]") -> int:
    """Wrapping-uint32 sum of pre-reduced per-record digests — how the
    fused kernel's ``record_digests`` output becomes a topic checksum."""
    arr = np.asarray(record_digests, dtype=np.uint64)
    return int(arr.sum(dtype=np.uint64) & _U32)


# -- timestamp sketch (KMV) ---------------------------------------------------

def _ts_hash64(ts: np.ndarray) -> np.ndarray:
    """Deterministic 64-bit mix (splitmix64 finalizer) of int64 timestamps.

    Deterministic is the point: the sketch keeps a timestamp iff its hash
    clears a threshold, so which sample survives is a pure function of the
    timestamp *multiset* — never of arrival order, batch split, or any
    RNG state — which is what makes sketched partials merge exactly
    associatively (see :meth:`TopicMetrics.merge`).
    """
    with np.errstate(over="ignore"):
        z = ts.astype(np.int64).view(np.uint64) \
            + np.uint64(0x9E3779B97F4A7C15)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return z ^ (z >> np.uint64(31))


def _kmv_compact(ts: np.ndarray, k: int,
                 theta: Optional[int]) -> tuple[np.ndarray, Optional[int]]:
    """One KMV (k-minimum-values) compaction step.

    Keeps timestamps whose hash is strictly below ``theta`` (``None`` =
    keep all), then — if more than ``k`` remain — tightens ``theta`` to
    the (k+1)-th smallest hash and refilters.  The kept sample is always
    exactly ``{t : hash(t) < theta}`` of the full multiset, which is the
    invariant the merge associativity proof leans on: min-ing thresholds
    and refiltering reproduces, bit for bit, the sketch a single pass over
    the union would have produced.  Preserves the input's relative order.
    """
    ts = np.asarray(ts, dtype=np.int64)
    h = _ts_hash64(ts)
    if theta is not None:
        keep = h < np.uint64(theta)
        ts, h = ts[keep], h[keep]
    if len(ts) > k:
        theta = int(np.partition(h, k)[k])
        ts = ts[h < np.uint64(theta)]
    return ts, theta


@dataclass(frozen=True)
class TopicMetrics:
    """Per-topic slice of a merged output bag — also the *mergeable
    partial* workers ship.

    ``timestamps`` (sorted int64, excluded from equality/repr) is the
    state :meth:`merge` needs to recompute gap percentiles over a combined
    multiset; it weighs 8 bytes per message — KBs where the payloads it
    summarises weigh MBs.

    **Sketch mode** (``sketch=k``) bounds that state for long-running
    suites: the timestamp multiset is compacted to a deterministic KMV
    sample of at most ``k`` values (``theta`` is the hash threshold that
    defines it).  Counts, byte totals, checksums, and ``t_min``/``t_max``
    stay *exact*; only the gap percentiles become estimates.  Exact mode
    (``sketch=None``) remains the default everywhere.

    Gap-percentile error budget in sketch mode: sampling timestamps makes
    each observed gap the sum of the true gaps it spans, so sample gaps
    are rescaled by ``(m-1)/(n-1)`` (m = sample size, n = true count) —
    an unbiased estimate of the *mean* gap.  For near-uniform arrival the
    quantile error is O(1/sqrt(m)) relative; for heavy-tailed gap
    distributions the summing biases high quantiles toward the mean (a
    sampled gap can absorb several small gaps around a large one), so
    p99 degrades first — size ``k`` generously if tail latency is the
    metric under test.  Exact when ``n <= k``.
    """
    topic: str
    count: int
    bytes_total: int
    t_min: Optional[int]
    t_max: Optional[int]
    gap_p50_ns: float            # inter-arrival gap percentiles (latency)
    gap_p90_ns: float
    gap_p99_ns: float
    checksum: int                # order-free wrapping-u32 payload digest
    timestamps: Optional[np.ndarray] = field(default=None, repr=False,
                                             compare=False)
    sketch: Optional[int] = field(default=None, compare=False)
    theta: Optional[int] = field(default=None, repr=False, compare=False)

    @classmethod
    def from_state(cls, topic: str, bytes_total: int, checksum: int,
                   timestamps: np.ndarray, *, sketch: Optional[int] = None,
                   count: Optional[int] = None, t_min: Optional[int] = None,
                   t_max: Optional[int] = None,
                   theta: Optional[int] = None) -> "TopicMetrics":
        """Build finalized metrics from reduced state: a sorted int64
        timestamp array plus pre-combined byte and checksum totals.

        ``sketch=k`` compacts the timestamp multiset to its KMV sample
        before computing gap percentiles.  ``count``/``t_min``/``t_max``
        carry the exact values when ``timestamps`` is already a sample
        (merging sketched partials) rather than the full multiset;
        ``theta`` is the sample's existing hash threshold.
        """
        ts = np.asarray(timestamps, dtype=np.int64)
        n = len(ts) if count is None else int(count)
        lo = (int(ts[0]) if len(ts) else None) if t_min is None else int(t_min)
        hi = (int(ts[-1]) if len(ts) else None) if t_max is None \
            else int(t_max)
        if theta is not None or (sketch is not None and len(ts) > sketch):
            ts, theta = _kmv_compact(ts, sketch if sketch is not None
                                     else len(ts), theta)
            ts = np.sort(ts)
        m = len(ts)
        gaps = np.diff(ts) if m > 1 else np.zeros(1, np.int64)
        p50, p90, p99 = np.percentile(gaps, [50, 90, 99])
        if 1 < m < n:
            # rescale sampled gaps to the true gap scale (see class doc)
            f = (m - 1) / (n - 1)
            p50, p90, p99 = p50 * f, p90 * f, p99 * f
        return cls(topic=topic, count=n, bytes_total=int(bytes_total),
                   t_min=lo, t_max=hi,
                   gap_p50_ns=float(p50), gap_p90_ns=float(p90),
                   gap_p99_ns=float(p99), checksum=int(checksum) & 0xFFFFFFFF,
                   timestamps=ts, sketch=sketch, theta=theta)

    def merge(self, other: "TopicMetrics") -> "TopicMetrics":
        """Pure associative combine of two partials of the same topic.

        Counts/bytes add, checksums add in wrapping uint32 space, time
        bounds extend, and gap percentiles are recomputed from the merged
        timestamp multiset — so merging per-partition partials is *exactly*
        ``compute_metrics`` over the merged bag, in any association order.

        Sketched partials stay exactly associative: thresholds min,
        samples refilter against the tighter threshold, and the result is
        bit-identical to sketching the union directly — the KMV sample is
        a deterministic function of the timestamp multiset, so association
        order cannot move even the estimated percentiles.
        """
        if self.topic != other.topic:
            raise ValueError(f"cannot merge metrics of {self.topic!r} "
                             f"with {other.topic!r}")
        if other.count == 0:
            return self
        if self.count == 0:
            return other
        if self.timestamps is None or other.timestamps is None:
            raise ValueError(
                f"topic {self.topic!r}: merging requires timestamp-carrying "
                "partials (metrics loaded without their timestamps cannot "
                "be combined exactly)")
        sketches = [s for s in (self.sketch, other.sketch) if s is not None]
        thetas = [t for t in (self.theta, other.theta) if t is not None]
        ts = np.sort(np.concatenate([self.timestamps, other.timestamps]))
        return TopicMetrics.from_state(
            self.topic, self.bytes_total + other.bytes_total,
            (np.uint64(self.checksum) + np.uint64(other.checksum)) & _U32,
            ts, sketch=min(sketches) if sketches else None,
            theta=min(thetas) if thetas else None,
            count=self.count + other.count,
            t_min=min(self.t_min, other.t_min),
            t_max=max(self.t_max, other.t_max))


def combine_metrics(partials: Iterable[dict[str, TopicMetrics]],
                    ) -> dict[str, TopicMetrics]:
    """Fold per-shard/partition metric dicts into fleet-level metrics with
    :meth:`TopicMetrics.merge` — no payload bytes touched."""
    out: dict[str, TopicMetrics] = {}
    for part in partials:
        for topic, m in part.items():
            prev = out.get(topic)
            out[topic] = m if prev is None else prev.merge(m)
    return {t: out[t] for t in sorted(out)}


def accumulate_topic_state(state: dict[str, list], batch: Sequence[Message],
                           arrays: dict, digests: np.ndarray) -> None:
    """Scatter one assembled batch's per-record digests into per-topic
    reduction state (``topic -> [bytes_total, wrapping-u32 checksum,
    timestamp chunks]``).  The single source of truth for the combine
    shape — shared by :meth:`Aggregator.compute_metrics` and the
    fused-kernel consumers in ``benchmarks/aggregation.py``, so the
    bit-parity they assert can't drift apart."""
    digests = digests.astype(np.uint64)
    topics = np.asarray([m.topic for m in batch])
    for topic in dict.fromkeys(m.topic for m in batch):
        sel = topics == topic
        st = state.setdefault(topic, [0, np.uint64(0), []])
        st[0] += int(arrays["lengths"][sel].sum())
        st[1] = (st[1] + digests[sel].sum(dtype=np.uint64)) & _U32
        st[2].append(arrays["timestamps"][sel])


def accumulate_topic_state_arrays(state: dict[str, list], batch: dict,
                                  digests: np.ndarray) -> None:
    """Zero-copy twin of :func:`accumulate_topic_state`: scatter per-record
    digests into the same per-topic reduction state straight from a
    columnar batch — one carrying the ``topics``/``topic_idx`` routing
    columns of :func:`repro.data.pipeline.batch_from_columns` /
    :func:`repro.net.wire.frame_to_batch` — so the metric fold over a wire
    stream never materialises ``Message`` objects.  Checksums are order-
    free, so the two accumulators are bit-interchangeable over equivalent
    streams."""
    digests = digests.astype(np.uint64)
    idx = np.asarray(batch["topic_idx"])
    lengths = batch["lengths"]
    ts = batch["timestamps"]
    for j, topic in enumerate(batch["topics"]):
        sel = idx == j
        if not sel.any():
            continue
        st = state.setdefault(topic, [0, np.uint64(0), []])
        st[0] += int(lengths[sel].sum())
        st[1] = (st[1] + digests[sel].sum(dtype=np.uint64)) & _U32
        st[2].append(np.asarray(ts)[sel])


def finalize_topic_state(state: dict[str, list], sort: bool = False,
                         sketch: Optional[int] = None,
                         ) -> dict[str, TopicMetrics]:
    """Turn accumulated per-topic state into finalized (mergeable)
    :class:`TopicMetrics`, topics sorted.  ``sort=True`` sorts each topic's
    timestamp multiset first — required when the state was accumulated from
    a stream that is not globally time-ordered (e.g. a live output tap
    whose user logic emits arbitrary timestamps); sorting never changes
    checksums (order-free) and makes gap percentiles exact.  ``sketch=k``
    finalizes each topic in KMV sketch mode (see :class:`TopicMetrics`)."""
    return {topic: TopicMetrics.from_state(
                topic, st[0], st[1],
                np.sort(np.concatenate(st[2])) if sort
                else np.concatenate(st[2]), sketch=sketch)
            for topic, st in sorted(state.items())}


class MetricsTap:
    """Streaming per-topic metric partials over a live output stream — the
    metrics face of the staged replay pipeline's sink stage.

    Subscribed next to the recorder (``on_message`` per-message /
    ``on_batch`` batched), it buffers output messages into metric batches
    and reduces them to per-record digests as they stream past, so the
    partition's :class:`TopicMetrics` partials are ready the moment replay
    drains — the end-of-task re-sweep of the output image (re-open,
    re-assemble, re-digest) is gone.  ``finalize`` sorts each topic's
    timestamp multiset, so the result is bit-identical to
    ``Aggregator.compute_metrics`` over the recorded bag regardless of the
    logic's output timestamp order.

    ``engine`` picks the digest reduction:

    * ``"numpy"`` — fork-safe vectorized host path (process workers,
      per-message replay),
    * ``"jax"``   — the jitted ``record_digest`` reduction,
    * ``"fused"`` — the Pallas consume step
      (:func:`repro.kernels.sensor_decode.batch_record_digests`): one
      fused sweep decodes the batch *and* emits the digests — the stock
      shape for batched in-process scenarios.  Today the tap keeps only
      the digest plane; the decoded features become free the moment a
      downstream consumer (dashboard, scoring model) is attached to the
      same sweep, which is the device-context plan this shape exists for.

    All three are bit-identical, so engine choice never moves a checksum
    or a verdict.

    ``ts_sketch=k`` caps the tap's memory on unbounded streams: each
    topic's timestamp multiset is compacted incrementally to its KMV
    sample (at most ``k`` values) while exact count / bounds / checksum
    accumulate alongside, so the finalized :class:`TopicMetrics` are
    sketch-mode partials — verdict-identical to exact mode (golden
    compares read only the exact fields), approximate only in the gap
    percentiles.
    """

    def __init__(self, engine: str = "numpy", metric_batch: int = 256,
                 exclude_topics: Sequence[str] = (),
                 ts_sketch: Optional[int] = None):
        if engine not in ("numpy", "jax", "fused"):
            raise ValueError(f"unknown digest engine {engine!r}")
        if ts_sketch is not None and ts_sketch < 1:
            raise ValueError("ts_sketch must be >= 1")
        self.engine = engine
        self.metric_batch = metric_batch
        self.ts_sketch = ts_sketch
        self._exclude = set(exclude_topics)
        self._buffer: list[Message] = []
        self._state: dict[str, list] = {}
        # topic -> [exact count, exact t_min, exact t_max, theta] once the
        # timestamp chunks have been compacted at least once
        self._exact: dict[str, list] = {}
        self._finalized: Optional[dict[str, TopicMetrics]] = None

    def on_message(self, msg: Message) -> None:
        if msg.topic in self._exclude:
            return
        self._buffer.append(msg)
        if len(self._buffer) >= self.metric_batch:
            self._flush()

    def on_batch(self, msgs: Sequence[Message]) -> None:
        self._buffer.extend(m for m in msgs if m.topic not in self._exclude)
        if len(self._buffer) >= self.metric_batch:
            self._flush()

    def _digests(self, arrays: dict) -> np.ndarray:
        if self.engine == "fused":
            from repro.kernels.sensor_decode import batch_record_digests
            return batch_record_digests(arrays)   # derives ts_low itself
        ts_low = (arrays["timestamps"].astype(np.uint64)
                  & _U32).astype(np.uint32)
        if self.engine == "jax":
            return np.asarray(_jitted()["record_digest"](
                arrays["payload"], arrays["lengths"], ts_low))
        return record_digests_np(arrays["payload"], arrays["lengths"],
                                 ts_low)

    def _flush(self) -> None:
        from repro.data.pipeline import assemble_message_batch
        if not self._buffer:
            return
        batch, self._buffer = self._buffer, []
        arrays = assemble_message_batch(batch)
        accumulate_topic_state(self._state, batch, arrays,
                               self._digests(arrays))
        if self.ts_sketch is not None:
            self._compact()

    def _compact(self) -> None:
        """Fold each topic's fresh timestamp chunks into its KMV sample,
        banking the exact count/bounds first — the step that keeps tap
        memory at O(k) per topic regardless of stream length."""
        for topic, st in self._state.items():
            ex = self._exact.get(topic)
            if ex is None:
                sample, raw = np.empty(0, np.int64), st[2]
                ex = self._exact[topic] = [0, None, None, None]
            else:
                sample, raw = st[2][0], st[2][1:]
            if not raw:
                continue
            fresh = np.concatenate(raw)
            ex[0] += len(fresh)
            lo, hi = int(fresh.min()), int(fresh.max())
            ex[1] = lo if ex[1] is None else min(ex[1], lo)
            ex[2] = hi if ex[2] is None else max(ex[2], hi)
            merged = np.concatenate([sample, fresh])
            sample, ex[3] = _kmv_compact(merged, self.ts_sketch, ex[3])
            st[2][:] = [sample]

    def finalize(self) -> dict[str, TopicMetrics]:
        """Flush the tail batch and return the mergeable per-topic
        partials.  Idempotent — safe to call from cleanup paths."""
        if self._finalized is None:
            self._flush()
            if self.ts_sketch is None:
                self._finalized = finalize_topic_state(self._state,
                                                       sort=True)
            else:
                self._compact()
                self._finalized = {
                    topic: TopicMetrics.from_state(
                        topic, st[0], st[1], np.sort(st[2][0]),
                        sketch=self.ts_sketch, count=self._exact[topic][0],
                        t_min=self._exact[topic][1],
                        t_max=self._exact[topic][2],
                        theta=self._exact[topic][3])
                    for topic, st in sorted(self._state.items())}
            self._state = {}
            self._exact = {}
        return self._finalized


@dataclass(frozen=True)
class Diff:
    """One golden-comparison mismatch."""
    topic: str
    field: str        # count | checksum | t_min | t_max | timestamp | payload
    expected: Any
    actual: Any
    detail: str = ""

    def __str__(self) -> str:
        extra = f" ({self.detail})" if self.detail else ""
        return (f"{self.topic}: {self.field} expected {self.expected!r} "
                f"got {self.actual!r}{extra}")


@dataclass
class Verdict:
    """Per-scenario pass/fail — what a regression suite actually returns.

    ``vacuous`` marks a PASS earned by an empty selection (zero input
    messages and nothing the golden bag demanded) rather than by matching
    outputs.  ``report`` carries the full :class:`SimulationReport` when
    the verdict came out of ``ScenarioSuite.run``.  ``cache`` is the
    result-cache provenance when the suite ran with one
    (``"hit"`` — rehydrated without replay — or ``"miss"``; ``None``
    when no cache was configured): it rides into the verdict JSONL so
    trend tooling can tell a metadata read from a real replay.

    ``error`` turns the verdict into an **ERROR**: the scenario never
    produced comparable outputs (its partition perma-failed, or an
    upstream export provider it imports from did), so neither PASS nor
    FAIL is honest — the string carries the cause lineage.  ERROR
    verdicts are falsy like FAIL, but report tooling keeps them out of
    checksum/walltime trending: there is nothing real to trend.

    ``transport`` is export-carrier provenance for scenarios that feed
    the routing DAG: ``"shm"`` (same-host shared-memory ring),
    ``"wire"`` (TCP LaneTransport) or ``"inline"`` (rides task
    results); ``None`` for scenarios that export nothing or
    rehydrated from the result cache.  Verdicts are bit-identical
    across carriers — this records which one actually ran, so a report
    can flag a carrier shift between runs.
    """
    scenario: str
    passed: bool
    vacuous: bool = False
    diffs: list[Diff] = field(default_factory=list)
    metrics: dict[str, TopicMetrics] = field(default_factory=dict)
    golden_path: Optional[str] = None
    report: Optional[Any] = None        # SimulationReport (layer above)
    cache: Optional[str] = None         # "hit" | "miss" | None (no cache)
    error: Optional[str] = None         # cause lineage; makes status ERROR
    transport: Optional[str] = None     # "shm" | "wire" | "inline" | None

    @property
    def status(self) -> str:
        if self.error is not None:
            return "ERROR"
        if not self.passed:
            return "FAIL"
        return "PASS(vacuous)" if self.vacuous else "PASS"

    def __bool__(self) -> bool:
        return self.passed

    def summary(self) -> str:
        head = f"{self.scenario}: {self.status}"
        if self.diffs:
            head += "".join(f"\n  - {d}" for d in self.diffs)
        return head


class Aggregator:
    """The aggregation pipeline stage: merge -> metrics -> compare -> verdict.

    ``tolerance`` selects the golden-matching mode: ``0`` (default) is
    exact — per-topic counts, time bounds and payload checksums must match
    bit-for-bit; ``> 0`` allows per-byte payload deviation up to
    ``tolerance`` (in byte units) between time-aligned message pairs,
    for scenarios whose user logic is numerically jittery.
    ``metric_batch`` sizes the assembled batches the digest reductions
    consume (the aggregation analogue of replay ``batch_size``).

    ``engine`` selects the digest reduction: ``"numpy"`` (default) is the
    fork-safe vectorized path worker pools use; ``"jax"`` the jitted
    device path.  Both are bit-identical (and identical to the fused
    Pallas kernel), so the choice never moves a checksum or a verdict.
    """

    def __init__(self, tolerance: int = 0, metric_batch: int = 256,
                 engine: str = "numpy"):
        if tolerance < 0:
            raise ValueError("tolerance must be >= 0")
        if engine not in ("numpy", "jax"):
            raise ValueError(f"unknown digest engine {engine!r}")
        self.tolerance = tolerance
        self.metric_batch = metric_batch
        self.engine = engine

    def _record_digests(self, payload: np.ndarray, lengths: np.ndarray,
                        ts_low: np.ndarray) -> np.ndarray:
        if self.engine == "jax":
            return np.asarray(_jitted()["record_digest"](
                payload, lengths, ts_low))
        return record_digests_np(payload, lengths, ts_low)

    # -- merge --------------------------------------------------------------

    def merge(self, sources: Iterable[BagSource],
              path: Optional[str] = None) -> Bag:
        """Timestamp-ordered k-way merge (see :func:`merge_bags`)."""
        return merge_bags(sources, path=path)

    # -- metrics ------------------------------------------------------------

    def _topic_checksum(self, messages: Sequence[Message]) -> int:
        """Order-free wrapping-u32 checksum of a message sequence (one
        topic's worth) — a reduction over pre-reduced per-record digests."""
        from repro.data.pipeline import (assemble_message_batch,
                                         iter_message_batches)
        total = np.uint64(0)
        for batch in iter_message_batches(messages, self.metric_batch):
            arrays = assemble_message_batch(batch)
            ts_low = (arrays["timestamps"].astype(np.uint64)
                      & _U32).astype(np.uint32)
            digests = self._record_digests(arrays["payload"],
                                           arrays["lengths"], ts_low)
            total = (total + digests.astype(np.uint64).sum()) & _U32
        return int(total)

    def compute_metrics(self, source: "Bag | Iterable[Message]",
                        ) -> dict[str, TopicMetrics]:
        """Per-topic metrics over a (merged) output bag or message stream.

        **Single pass**: the time-ordered stream is consumed once in
        mixed-topic batches; per-record digests come from one reduction
        per batch and are scattered to topic accumulators, so no
        per-topic re-grouping or payload re-sweep happens.  The result
        dicts are the mergeable partials workers ship
        (:meth:`TopicMetrics.merge`).

        A message-iterator source must be timestamp-ordered (what
        :func:`iter_time_ordered` or a merged bag yields); disorder would
        silently corrupt time bounds and gap percentiles, so it raises
        ``ValueError`` instead — same contract as :func:`merge_bags`.
        """
        from repro.data.pipeline import (assemble_message_batch,
                                         iter_message_batches)
        stream = iter_time_ordered(source) if isinstance(source, Bag) \
            else iter(source)
        state: dict[str, list] = {}
        last = None
        for batch in iter_message_batches(stream, self.metric_batch):
            arrays = assemble_message_batch(batch)
            ts = arrays["timestamps"]
            if ((last is not None and ts[0] < last)
                    or (len(ts) > 1 and np.any(np.diff(ts) < 0))):
                raise ValueError(
                    "compute_metrics stream is out of timestamp order; "
                    "feed it a merged bag or a time-ordered iterator")
            last = int(ts[-1])
            ts_low = (ts.astype(np.uint64) & _U32).astype(np.uint32)
            digests = self._record_digests(arrays["payload"],
                                           arrays["lengths"], ts_low)
            accumulate_topic_state(state, batch, arrays, digests)
        return finalize_topic_state(state)

    # -- golden comparison --------------------------------------------------

    def compare(self, actual: Bag, golden: Bag,
                actual_metrics: Optional[dict[str, TopicMetrics]] = None,
                ) -> list[Diff]:
        """Diff a merged output bag against a golden bag.

        Exact mode (``tolerance == 0``) compares the per-topic metric
        summaries — counts, time bounds, checksums — without pairing
        individual messages.  Tolerance mode time-aligns message pairs per
        topic and bounds the per-byte payload deviation with a jitted
        reduction; counts and timestamps must still match exactly.
        """
        if actual_metrics is None:
            actual_metrics = self.compute_metrics(actual)
        golden_metrics = self.compute_metrics(golden)
        diffs: list[Diff] = []
        for topic in sorted(set(actual_metrics) | set(golden_metrics)):
            a = actual_metrics.get(topic)
            g = golden_metrics.get(topic)
            if g is None:
                diffs.append(Diff(topic, "count", 0, a.count,
                                  "topic absent from golden"))
                continue
            if a is None:
                diffs.append(Diff(topic, "count", g.count, 0,
                                  "topic missing from output"))
                continue
            if a.count != g.count:
                diffs.append(Diff(topic, "count", g.count, a.count))
                continue        # aligned compare is meaningless off-count
            for fld in ("t_min", "t_max"):
                if getattr(a, fld) != getattr(g, fld):
                    diffs.append(Diff(topic, fld, getattr(g, fld),
                                      getattr(a, fld)))
            if self.tolerance == 0:
                if a.checksum != g.checksum:
                    diffs.append(Diff(
                        topic, "checksum", g.checksum, a.checksum,
                        "payload or timestamp mismatch"))
            else:
                diffs.extend(self._compare_payloads(topic, actual, golden))
        return diffs

    def _compare_payloads(self, topic: str, actual: Bag,
                          golden: Bag) -> list[Diff]:
        from repro.data.pipeline import assemble_message_batch
        if self.engine == "jax":
            jit_mad = _jitted()["max_abs_diff"]
            max_abs_diff = lambda *a: int(jit_mad(*a))   # noqa: E731
        else:
            max_abs_diff = _max_abs_diff_np
        a_msgs = list(iter_time_ordered(actual, topics=[topic]))
        g_msgs = list(iter_time_ordered(golden, topics=[topic]))
        diffs: list[Diff] = []
        worst = 0
        for lo in range(0, len(a_msgs), self.metric_batch):
            a_batch = a_msgs[lo:lo + self.metric_batch]
            g_batch = g_msgs[lo:lo + self.metric_batch]
            for a, g in zip(a_batch, g_batch):
                if a.timestamp != g.timestamp:
                    diffs.append(Diff(topic, "timestamp", g.timestamp,
                                      a.timestamp, "pairwise time mismatch"))
                    return diffs
                if len(a.data) != len(g.data):
                    diffs.append(Diff(topic, "payload", len(g.data),
                                      len(a.data),
                                      f"length mismatch at t={a.timestamp}"))
                    return diffs
            aa = assemble_message_batch(a_batch)
            gg = assemble_message_batch(g_batch)
            nb = max(aa["payload"].shape[1], gg["payload"].shape[1])
            ap = np.zeros((len(a_batch), nb), np.uint8)
            gp = np.zeros((len(g_batch), nb), np.uint8)
            ap[:, :aa["payload"].shape[1]] = aa["payload"]
            gp[:, :gg["payload"].shape[1]] = gg["payload"]
            worst = max(worst, int(max_abs_diff(ap, aa["lengths"],
                                                gp, gg["lengths"])))
        if worst > self.tolerance:
            diffs.append(Diff(topic, "payload",
                              f"<= {self.tolerance}/byte", worst,
                              "max abs byte deviation over tolerance"))
        return diffs

    # -- the full stage -----------------------------------------------------

    def aggregate(self, scenario: str, sources: Iterable[BagSource],
                  golden: Optional[BagSource] = None,
                  messages_in: Optional[int] = None,
                  partials: Optional[
                      Sequence[dict[str, TopicMetrics]]] = None,
                  ) -> tuple[Bag, Verdict]:
        """Merge shard/partition outputs and score them.

        Returns ``(merged bag, verdict)``.  With no golden source the
        verdict passes by construction (metrics-only aggregation); a zero
        input selection is a *vacuous* pass unless the golden bag demanded
        output.  ``messages_in`` (when known from the replay report) feeds
        the vacuous-pass determination.

        ``partials`` — per-source metric dicts the workers computed next
        to replay — short-circuits the metric stage to a pure
        :meth:`TopicMetrics.merge` fold: the merged payload matrix is
        never re-swept (zero-extra-pass metrics).  Callers must pass one
        partial per source, covering exactly the merged messages.
        """
        merged = self.merge(sources)
        metrics = (combine_metrics(partials) if partials is not None
                   else self.compute_metrics(merged))
        golden_path = golden if isinstance(golden, str) else None
        diffs: list[Diff] = []
        if golden is not None:
            gbag, owned = _open_source(golden)
            try:
                diffs = self.compare(merged, gbag, actual_metrics=metrics)
            finally:
                if owned:
                    gbag.close()
        vacuous = (merged.num_messages == 0 and not diffs
                   and (messages_in in (None, 0)))
        verdict = Verdict(scenario=scenario, passed=not diffs,
                          vacuous=vacuous, diffs=diffs, metrics=metrics,
                          golden_path=golden_path)
        return merged, verdict
