"""Result aggregation: the verdict layer of the platform.

The sibling cloud-platform paper makes the aggregation stage — not raw
replay — the product of the pipeline: "massive functional and performance
tests" only matter once merged, compared and scored.  This module turns
per-partition/per-shard output bag images into exactly that:

    partition images --merge_bags--> one time-ordered output Bag
        --metrics--> per-topic TopicMetrics (counts, gaps, checksums)
        --golden compare--> list[Diff]
        --> Verdict (PASS / PASS-vacuous / FAIL)

Metric reductions run over the same fixed-layout arrays batched replay
uses (:func:`repro.data.pipeline.assemble_message_batch`): payload
checksums are a jitted uint32 reduction over the (R, Nb) payload matrix,
so the hot path stays on-device and amortises like the decode stage.
Checksums are *order-free across records* (a wrapping sum of per-record
digests) but position- and timestamp-sensitive within a record — the same
fleet produces the same checksum regardless of shard/partition/batch
split, while any payload or timestamp perturbation flips it.

``Aggregator`` is the pipeline stage ``ScenarioSuite.run`` finishes with;
it can also be used standalone against recorded bags for offline triage.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Iterable, Optional, Sequence

import numpy as np

from .bag import (Bag, BagSource, Message, _open_source, iter_time_ordered,
                  merge_bags)

_U32 = np.uint64(0xFFFFFFFF)        # digests live in wrapping uint32 space

# Lazily-built jitted reductions (importing jax at module import would tax
# every core/ consumer that never aggregates).
_JITTED: dict[str, Any] = {}


def _jitted():
    if not _JITTED:
        import jax
        import jax.numpy as jnp

        @jax.jit
        def digest(payload, lengths, ts_low):
            """Wrapping-uint32 digest of one assembled batch.

            payload: (R, Nb) uint8, lengths: (R,) i32, ts_low: (R,) u32
            (timestamps mod 2**32).  Per-record digest = position-weighted
            byte sum mixed with the timestamp; records combine by wrapping
            sum, so the total is invariant to record order and batch split.
            """
            p = payload.astype(jnp.uint32)
            col = jnp.arange(payload.shape[1], dtype=jnp.uint32)
            mask = col[None, :] < lengths.astype(jnp.uint32)[:, None]
            w = col * jnp.uint32(2246822519) + jnp.uint32(0x9E3779B9)
            rec = jnp.sum(jnp.where(mask, p * w[None, :], 0), axis=1,
                          dtype=jnp.uint32)
            rec = (rec ^ ts_low.astype(jnp.uint32)) * jnp.uint32(2654435761)
            rec = rec + lengths.astype(jnp.uint32) * jnp.uint32(40503)
            return jnp.sum(rec, dtype=jnp.uint32)

        @jax.jit
        def max_abs_diff(a, a_len, b, b_len):
            """Max per-byte |a - b| over the valid prefix of each record
            pair (padding excluded); (R, Nb) uint8 x2 -> scalar i32."""
            col = jnp.arange(a.shape[1], dtype=jnp.int32)
            valid = col[None, :] < jnp.minimum(a_len, b_len)[:, None]
            d = jnp.abs(a.astype(jnp.int32) - b.astype(jnp.int32))
            return jnp.max(jnp.where(valid, d, 0))

        _JITTED["digest"] = digest
        _JITTED["max_abs_diff"] = max_abs_diff
    return _JITTED


@dataclass(frozen=True)
class TopicMetrics:
    """Per-topic slice of a merged output bag."""
    topic: str
    count: int
    bytes_total: int
    t_min: Optional[int]
    t_max: Optional[int]
    gap_p50_ns: float            # inter-arrival gap percentiles (latency)
    gap_p90_ns: float
    gap_p99_ns: float
    checksum: int                # order-free wrapping-u32 payload digest


@dataclass(frozen=True)
class Diff:
    """One golden-comparison mismatch."""
    topic: str
    field: str        # count | checksum | t_min | t_max | timestamp | payload
    expected: Any
    actual: Any
    detail: str = ""

    def __str__(self) -> str:
        extra = f" ({self.detail})" if self.detail else ""
        return (f"{self.topic}: {self.field} expected {self.expected!r} "
                f"got {self.actual!r}{extra}")


@dataclass
class Verdict:
    """Per-scenario pass/fail — what a regression suite actually returns.

    ``vacuous`` marks a PASS earned by an empty selection (zero input
    messages and nothing the golden bag demanded) rather than by matching
    outputs.  ``report`` carries the full :class:`SimulationReport` when
    the verdict came out of ``ScenarioSuite.run``.
    """
    scenario: str
    passed: bool
    vacuous: bool = False
    diffs: list[Diff] = field(default_factory=list)
    metrics: dict[str, TopicMetrics] = field(default_factory=dict)
    golden_path: Optional[str] = None
    report: Optional[Any] = None        # SimulationReport (layer above)

    @property
    def status(self) -> str:
        if not self.passed:
            return "FAIL"
        return "PASS(vacuous)" if self.vacuous else "PASS"

    def __bool__(self) -> bool:
        return self.passed

    def summary(self) -> str:
        head = f"{self.scenario}: {self.status}"
        if self.diffs:
            head += "".join(f"\n  - {d}" for d in self.diffs)
        return head


class Aggregator:
    """The aggregation pipeline stage: merge -> metrics -> compare -> verdict.

    ``tolerance`` selects the golden-matching mode: ``0`` (default) is
    exact — per-topic counts, time bounds and payload checksums must match
    bit-for-bit; ``> 0`` allows per-byte payload deviation up to
    ``tolerance`` (in byte units) between time-aligned message pairs,
    for scenarios whose user logic is numerically jittery.
    ``metric_batch`` sizes the assembled batches the jitted reductions
    consume (the aggregation analogue of replay ``batch_size``).
    """

    def __init__(self, tolerance: int = 0, metric_batch: int = 256):
        if tolerance < 0:
            raise ValueError("tolerance must be >= 0")
        self.tolerance = tolerance
        self.metric_batch = metric_batch

    # -- merge --------------------------------------------------------------

    def merge(self, sources: Iterable[BagSource],
              path: Optional[str] = None) -> Bag:
        """Timestamp-ordered k-way merge (see :func:`merge_bags`)."""
        return merge_bags(sources, path=path)

    # -- metrics ------------------------------------------------------------

    def _topic_checksum(self, messages: Sequence[Message]) -> int:
        from repro.data.pipeline import (assemble_message_batch,
                                         iter_message_batches)
        digest = _jitted()["digest"]
        total = np.uint64(0)
        for batch in iter_message_batches(messages, self.metric_batch):
            arrays = assemble_message_batch(batch)
            ts_low = (arrays["timestamps"].astype(np.uint64)
                      & _U32).astype(np.uint32)
            total = (total + np.uint64(int(digest(
                arrays["payload"], arrays["lengths"], ts_low)))) & _U32
        return int(total)

    def compute_metrics(self, bag: Bag) -> dict[str, TopicMetrics]:
        """Per-topic metrics over a (merged) output bag."""
        by_topic: dict[str, list[Message]] = {}
        for msg in iter_time_ordered(bag):
            by_topic.setdefault(msg.topic, []).append(msg)
        metrics: dict[str, TopicMetrics] = {}
        for topic in sorted(by_topic):
            msgs = by_topic[topic]
            ts = np.fromiter((m.timestamp for m in msgs), dtype=np.int64,
                             count=len(msgs))
            gaps = np.diff(ts) if len(ts) > 1 else np.zeros(1, np.int64)
            p50, p90, p99 = np.percentile(gaps, [50, 90, 99])
            metrics[topic] = TopicMetrics(
                topic=topic,
                count=len(msgs),
                bytes_total=sum(len(m.data) for m in msgs),
                t_min=int(ts.min()),
                t_max=int(ts.max()),
                gap_p50_ns=float(p50),
                gap_p90_ns=float(p90),
                gap_p99_ns=float(p99),
                checksum=self._topic_checksum(msgs),
            )
        return metrics

    # -- golden comparison --------------------------------------------------

    def compare(self, actual: Bag, golden: Bag,
                actual_metrics: Optional[dict[str, TopicMetrics]] = None,
                ) -> list[Diff]:
        """Diff a merged output bag against a golden bag.

        Exact mode (``tolerance == 0``) compares the per-topic metric
        summaries — counts, time bounds, checksums — without pairing
        individual messages.  Tolerance mode time-aligns message pairs per
        topic and bounds the per-byte payload deviation with a jitted
        reduction; counts and timestamps must still match exactly.
        """
        if actual_metrics is None:
            actual_metrics = self.compute_metrics(actual)
        golden_metrics = self.compute_metrics(golden)
        diffs: list[Diff] = []
        for topic in sorted(set(actual_metrics) | set(golden_metrics)):
            a = actual_metrics.get(topic)
            g = golden_metrics.get(topic)
            if g is None:
                diffs.append(Diff(topic, "count", 0, a.count,
                                  "topic absent from golden"))
                continue
            if a is None:
                diffs.append(Diff(topic, "count", g.count, 0,
                                  "topic missing from output"))
                continue
            if a.count != g.count:
                diffs.append(Diff(topic, "count", g.count, a.count))
                continue        # aligned compare is meaningless off-count
            for fld in ("t_min", "t_max"):
                if getattr(a, fld) != getattr(g, fld):
                    diffs.append(Diff(topic, fld, getattr(g, fld),
                                      getattr(a, fld)))
            if self.tolerance == 0:
                if a.checksum != g.checksum:
                    diffs.append(Diff(
                        topic, "checksum", g.checksum, a.checksum,
                        "payload or timestamp mismatch"))
            else:
                diffs.extend(self._compare_payloads(topic, actual, golden))
        return diffs

    def _compare_payloads(self, topic: str, actual: Bag,
                          golden: Bag) -> list[Diff]:
        from repro.data.pipeline import assemble_message_batch
        max_abs_diff = _jitted()["max_abs_diff"]
        a_msgs = list(iter_time_ordered(actual, topics=[topic]))
        g_msgs = list(iter_time_ordered(golden, topics=[topic]))
        diffs: list[Diff] = []
        worst = 0
        for lo in range(0, len(a_msgs), self.metric_batch):
            a_batch = a_msgs[lo:lo + self.metric_batch]
            g_batch = g_msgs[lo:lo + self.metric_batch]
            for a, g in zip(a_batch, g_batch):
                if a.timestamp != g.timestamp:
                    diffs.append(Diff(topic, "timestamp", g.timestamp,
                                      a.timestamp, "pairwise time mismatch"))
                    return diffs
                if len(a.data) != len(g.data):
                    diffs.append(Diff(topic, "payload", len(g.data),
                                      len(a.data),
                                      f"length mismatch at t={a.timestamp}"))
                    return diffs
            aa = assemble_message_batch(a_batch)
            gg = assemble_message_batch(g_batch)
            nb = max(aa["payload"].shape[1], gg["payload"].shape[1])
            ap = np.zeros((len(a_batch), nb), np.uint8)
            gp = np.zeros((len(g_batch), nb), np.uint8)
            ap[:, :aa["payload"].shape[1]] = aa["payload"]
            gp[:, :gg["payload"].shape[1]] = gg["payload"]
            worst = max(worst, int(max_abs_diff(ap, aa["lengths"],
                                                gp, gg["lengths"])))
        if worst > self.tolerance:
            diffs.append(Diff(topic, "payload",
                              f"<= {self.tolerance}/byte", worst,
                              "max abs byte deviation over tolerance"))
        return diffs

    # -- the full stage -----------------------------------------------------

    def aggregate(self, scenario: str, sources: Iterable[BagSource],
                  golden: Optional[BagSource] = None,
                  messages_in: Optional[int] = None) -> tuple[Bag, Verdict]:
        """Merge shard/partition outputs and score them.

        Returns ``(merged bag, verdict)``.  With no golden source the
        verdict passes by construction (metrics-only aggregation); a zero
        input selection is a *vacuous* pass unless the golden bag demanded
        output.  ``messages_in`` (when known from the replay report) feeds
        the vacuous-pass determination.
        """
        merged = self.merge(sources)
        metrics = self.compute_metrics(merged)
        golden_path = golden if isinstance(golden, str) else None
        diffs: list[Diff] = []
        if golden is not None:
            gbag, owned = _open_source(golden)
            try:
                diffs = self.compare(merged, gbag, actual_metrics=metrics)
            finally:
                if owned:
                    gbag.close()
        vacuous = (merged.num_messages == 0 and not diffs
                   and (messages_in in (None, 0)))
        verdict = Verdict(scenario=scenario, passed=not diffs,
                          vacuous=vacuous, diffs=diffs, metrics=metrics,
                          golden_path=golden_path)
        return merged, verdict
