"""BinPipedRDD (paper §3.1, Fig 4) — binary streaming for a framework whose
native currency is not bytes.

Spark's problem: RDDs are text-oriented; multimedia partitions must be
encoded (heterogeneous fields -> uniform byte-array format), serialized
(many byte arrays -> one stream), piped to the user logic, and the results
encoded/serialized back into ``RDD[Bytes]`` partitions.

JAX's version of the same problem: ``jit`` consumes dense, fixed-layout
arrays, not variable-length records.  So the pipeline here is:

    encode   : record fields (str / int / float / bytes / ndarray) ->
               self-describing byte string                       (host)
    serialize: list[bytes] -> one stream                          (host)
    frame    : stream -> (payload u8[N], offsets i32[R], lengths i32[R])
               fixed-layout arrays a TPU kernel can consume       (host)
    decode   : on-device unpack of framed payloads                (device —
               see kernels/sensor_decode for the Pallas version)

``deserialize``/``decode`` invert the host stages, and every stage is
round-trip property-tested.
"""

from __future__ import annotations

import struct
from typing import Any, Iterable, Sequence

import numpy as np

# field type tags of the uniform format
_T_BYTES = 0
_T_STR = 1
_T_INT = 2
_T_FLOAT = 3
_T_NDARRAY = 4

_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")
_I64 = struct.Struct("<q")
_F64 = struct.Struct("<d")

_DTYPE_CODES = {
    "uint8": 0, "int8": 1, "int16": 2, "int32": 3, "int64": 4,
    "float16": 5, "float32": 6, "float64": 7, "bfloat16": 8, "uint16": 9,
}
_CODE_DTYPES = {v: k for k, v in _DTYPE_CODES.items()}


def _encode_field(out: bytearray, value: Any) -> None:
    if isinstance(value, (bytes, bytearray, memoryview)):
        b = bytes(value)
        out += bytes([_T_BYTES]) + _U32.pack(len(b)) + b
    elif isinstance(value, str):
        b = value.encode("utf-8")
        out += bytes([_T_STR]) + _U32.pack(len(b)) + b
    elif isinstance(value, (bool, np.bool_)):
        out += bytes([_T_INT]) + _U32.pack(8) + _I64.pack(int(value))
    elif isinstance(value, (int, np.integer)):
        out += bytes([_T_INT]) + _U32.pack(8) + _I64.pack(int(value))
    elif isinstance(value, (float, np.floating)):
        out += bytes([_T_FLOAT]) + _U32.pack(8) + _F64.pack(float(value))
    elif isinstance(value, np.ndarray):
        dt = str(value.dtype)
        if dt not in _DTYPE_CODES:
            raise TypeError(f"unsupported ndarray dtype {dt}")
        body = value.tobytes()
        hdr = bytes([_DTYPE_CODES[dt], value.ndim]) + b"".join(
            _U32.pack(d) for d in value.shape)
        out += bytes([_T_NDARRAY]) + _U32.pack(len(hdr) + len(body)) + hdr + body
    else:
        raise TypeError(f"unsupported field type {type(value)!r}")


def encode(fields: Sequence[Any]) -> bytes:
    """Encode one record's fields into the uniform byte-array format."""
    out = bytearray(_U32.pack(len(fields)))
    for v in fields:
        _encode_field(out, v)
    return bytes(out)


def decode(blob: bytes) -> list[Any]:
    """Invert :func:`encode`."""
    (nfields,) = _U32.unpack_from(blob, 0)
    pos = 4
    fields: list[Any] = []
    for _ in range(nfields):
        tag = blob[pos]; pos += 1
        (ln,) = _U32.unpack_from(blob, pos); pos += 4
        body = blob[pos:pos + ln]; pos += ln
        if tag == _T_BYTES:
            fields.append(bytes(body))
        elif tag == _T_STR:
            fields.append(body.decode("utf-8"))
        elif tag == _T_INT:
            fields.append(_I64.unpack(body)[0])
        elif tag == _T_FLOAT:
            fields.append(_F64.unpack(body)[0])
        elif tag == _T_NDARRAY:
            dtype = _CODE_DTYPES[body[0]]
            ndim = body[1]
            shape = tuple(
                _U32.unpack_from(body, 2 + 4 * i)[0] for i in range(ndim))
            arr = np.frombuffer(body[2 + 4 * ndim:], dtype=dtype).reshape(shape)
            fields.append(arr.copy())
        else:
            raise ValueError(f"bad field tag {tag}")
    return fields


def serialize(records: Iterable[bytes]) -> bytes:
    """Combine per-record byte arrays into one binary stream."""
    recs = list(records)
    out = bytearray(_U32.pack(len(recs)))
    for r in recs:
        out += _U64.pack(len(r)) + r
    return bytes(out)


def deserialize(stream: bytes) -> list[bytes]:
    """Invert :func:`serialize`."""
    (n,) = _U32.unpack_from(stream, 0)
    pos = 4
    recs: list[bytes] = []
    for _ in range(n):
        (ln,) = _U64.unpack_from(stream, pos); pos += 8
        recs.append(stream[pos:pos + ln]); pos += ln
    return recs


# --------------------------------------------------------------------------
# Fixed-layout framing: the TPU-native tail of the pipe.
# --------------------------------------------------------------------------

def frame(records: Sequence[bytes], align: int = 128,
          ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack records into ``(payload u8[N], offsets i32[R], lengths i32[R])``.

    Offsets are ``align``-aligned (default 128 = TPU lane width) so a Pallas
    kernel can tile the payload without crossing record boundaries mid-lane.
    """
    offsets = np.zeros(len(records), dtype=np.int32)
    lengths = np.zeros(len(records), dtype=np.int32)
    pos = 0
    for i, r in enumerate(records):
        offsets[i] = pos
        lengths[i] = len(r)
        pos += (len(r) + align - 1) // align * align
    payload = np.zeros(pos if pos else align, dtype=np.uint8)
    for i, r in enumerate(records):
        payload[offsets[i]:offsets[i] + lengths[i]] = np.frombuffer(
            r, dtype=np.uint8)
    return payload, offsets, lengths


def unframe(payload: np.ndarray, offsets: np.ndarray,
            lengths: np.ndarray) -> list[bytes]:
    """Invert :func:`frame`."""
    return [payload[o:o + l].tobytes()
            for o, l in zip(offsets.tolist(), lengths.tolist())]


class BinaryPartition:
    """One partition of a binary dataset — the unit the scheduler ships.

    Mirrors ``RDD[Bytes]`` partitions: an ordered list of encoded records
    plus the lineage handle used for fault-tolerant recompute.
    """

    def __init__(self, records: list[bytes], lineage: tuple = ()):
        self.records = records
        self.lineage = lineage          # e.g. ("bag", path, chunk_lo, chunk_hi)

    def __len__(self) -> int:
        return len(self.records)

    def to_stream(self) -> bytes:
        return serialize(self.records)

    @classmethod
    def from_stream(cls, stream: bytes, lineage: tuple = ()) -> "BinaryPartition":
        return cls(deserialize(stream), lineage)

    def to_arrays(self, align: int = 128):
        return frame(self.records, align=align)

    def map(self, user_logic) -> "BinaryPartition":
        """Apply User Logic record-wise (decode -> compute -> encode)."""
        out = [encode(user_logic(decode(r))) for r in self.records]
        return BinaryPartition(out, lineage=self.lineage + ("map",))
