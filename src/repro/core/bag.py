"""Bag format: the paper's two-tier logical structure (Fig 2).

Upper tier:  :class:`Bag` — user-facing record API (topic, timestamp, payload),
             grouping records into chunks with a time/topic index.
Lower tier:  :class:`ChunkedFile` — chunk store on disk;
             :class:`MemoryChunkedFile` — the paper's contribution (Fig 6):
             inherits ChunkedFile and overrides every I/O method to read and
             write chunks in RAM instead of the disk, so ROSPlay/ROSRecord
             stream through memory ("ROSBag cache", §3.2).

Binary layout (disk):
    [8s magic "REPROBAG"][u32 version]
    chunk*:  [u32 crc-less header: record_count][u64 payload_len][payload]
    footer:  written by Bag.close() via the index block (see Bag._write_index)

Chunk payload = concatenated records:
    [u32 topic_id][u64 timestamp_ns][u32 data_len][data]
"""

from __future__ import annotations

import hashlib
import heapq
import io
import os
import struct
import threading
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator, Optional, Sequence, Union

from repro.shm import SegmentHandle, read_segment

_MAGIC = b"REPROBAG"
_VERSION = 2
_HDR = struct.Struct("<IQ")          # record_count, payload_len
_REC = struct.Struct("<IQI")         # topic_id, timestamp_ns, data_len
DEFAULT_CHUNK_BYTES = 768 * 1024     # rosbag's default chunk threshold


@dataclass(frozen=True)
class Message:
    topic: str
    timestamp: int           # nanoseconds
    data: bytes


@dataclass
class ChunkInfo:
    offset: int               # opaque handle given by the ChunkedFile tier
    record_count: int
    t_min: int
    t_max: int
    topics: set = field(default_factory=set)


class ChunkedFile:
    """Lower tier: sequential chunk store backed by the disk.

    The Bag tier only ever calls :meth:`write_chunk`, :meth:`read_chunk`,
    :meth:`flush` and :meth:`close`, so a subclass that overrides those —
    like :class:`MemoryChunkedFile` — transparently changes the medium.
    """

    def __init__(self, path: Optional[str] = None, mode: str = "r"):
        self.path = path
        self.mode = mode
        self._lock = threading.Lock()
        if mode == "w":
            self._f: io.BufferedIOBase = open(path, "wb")
            self._f.write(_MAGIC + struct.pack("<I", _VERSION))
        elif mode == "r":
            self._f = open(path, "rb")
            magic = self._f.read(8)
            if magic != _MAGIC:
                raise ValueError(f"not a repro bag: {path!r}")
            (version,) = struct.unpack("<I", self._f.read(4))
            if version != _VERSION:
                raise ValueError(f"bag version {version} != {_VERSION}")
        else:
            raise ValueError(mode)

    # -- methods a subclass overrides to change the storage medium ---------

    def write_chunk(self, payload: bytes, record_count: int) -> int:
        """Append one chunk; returns its opaque offset handle."""
        with self._lock:
            off = self._f.tell()
            self._f.write(_HDR.pack(record_count, len(payload)))
            self._f.write(payload)
            return off

    def read_chunk(self, offset: int) -> tuple[bytes, int]:
        """Return (payload, record_count) for the chunk at ``offset``."""
        with self._lock:
            self._f.seek(offset)
            record_count, payload_len = _HDR.unpack(self._f.read(_HDR.size))
            return self._f.read(payload_len), record_count

    def write_blob(self, blob: bytes) -> int:
        """Raw append (used for the index block)."""
        with self._lock:
            off = self._f.tell()
            self._f.write(blob)
            return off

    def read_blob(self, offset: int, length: int) -> bytes:
        with self._lock:
            self._f.seek(offset)
            return self._f.read(length)

    def size(self) -> int:
        with self._lock:
            pos = self._f.tell()
            self._f.seek(0, os.SEEK_END)
            end = self._f.tell()
            self._f.seek(pos)
            return end

    def flush(self) -> None:
        self._f.flush()
        os.fsync(self._f.fileno())

    def close(self) -> None:
        try:
            self._f.close()
        except Exception:
            pass


class MemoryChunkedFile(ChunkedFile):
    """The paper's ROSBag cache (§3.2, Fig 6).

    Inherits from ChunkedFile and overrides *all* of its I/O methods; chunks
    live in process memory, so playback and recording never touch the disk.

    Write mode stores chunk payloads as *references* in a segment list
    (zero-copy appends; the disk-format image is only materialised by
    ``image()``/``persist()``); read mode wraps a single immutable buffer
    with a memoryview (zero upfront copy).  ``persist()``/``from_file()``
    move whole images between RAM and disk, which is how a worker
    materialises a partition it received over the wire.
    """

    def __init__(self, image: Optional[bytes] = None):
        # NOTE: deliberately does NOT call super().__init__ — no file handle.
        self.path = None
        self.mode = "rw"
        self._closed = False
        self._lock = threading.Lock()
        header = _MAGIC + struct.pack("<I", _VERSION)
        if image is not None:
            if bytes(image[:8]) != _MAGIC:
                raise ValueError("not a repro bag image")
            self._ro: Optional[memoryview] = memoryview(image)
            self._size = len(image)
            self._chunks: dict[int, tuple[int, bytes]] = {}
            self._segs: list[bytes] = []
        else:
            self._ro = None
            self._size = len(header)
            self._chunks = {}
            self._segs = [header]

    def write_chunk(self, payload: bytes, record_count: int) -> int:
        with self._lock:
            if self._closed:
                raise RuntimeError("memory bag is closed")
            off = self._size
            self._chunks[off] = (record_count, payload)   # reference, no copy
            self._segs.append(None)                       # placeholder
            self._segs[-1] = (off, record_count, payload)  # type: ignore
            self._size += _HDR.size + len(payload)
            return off

    def read_chunk(self, offset: int) -> tuple[bytes, int]:
        with self._lock:
            if self._ro is not None:
                record_count, payload_len = _HDR.unpack_from(self._ro, offset)
                start = offset + _HDR.size
                return bytes(self._ro[start:start + payload_len]), record_count
            record_count, payload = self._chunks[offset]
            return payload, record_count

    def write_blob(self, blob: bytes) -> int:
        with self._lock:
            if self._closed:
                raise RuntimeError("memory bag is closed")
            off = self._size
            self._segs.append((off, None, blob))  # type: ignore
            self._size += len(blob)
            return off

    def read_blob(self, offset: int, length: int) -> bytes:
        with self._lock:
            if self._ro is not None:
                return bytes(self._ro[offset:offset + length])
        # write-mode read (rare: only the index loader) — materialise
        img = self.image()
        return img[offset:offset + length]

    def size(self) -> int:
        with self._lock:
            return self._size

    def flush(self) -> None:  # RAM is always "flushed"
        pass

    def close(self) -> None:
        """Close the cache.  The disk-format image is captured at close time,
        so :meth:`image` stays valid afterwards (close-safe by contract —
        workers ship ``bag.close(); bag.chunked_file.image()`` as the task
        result); further writes raise."""
        with self._lock:
            if self._closed:
                return
            if self._ro is None:
                # consolidate segments into the final image now, while the
                # write-mode state is guaranteed intact
                img = self._join_segs()
                self._segs = [img]
            self._closed = True

    # -- RAM <-> disk interchange ------------------------------------------

    def _join_segs(self) -> bytes:
        """Single-join materialisation of the write-mode segment list.
        Caller holds the lock."""
        parts: list[bytes] = []
        for seg in self._segs:
            if isinstance(seg, bytes):
                parts.append(seg)
            else:
                off, rc, payload = seg
                if rc is None:
                    parts.append(payload)
                else:
                    parts.append(_HDR.pack(rc, len(payload)))
                    parts.append(payload)
        return b"".join(parts)

    def image(self) -> bytes:
        """Materialise the disk-format byte image (single join).  Safe to
        call before or after :meth:`close`.  Read mode over a full bytes
        image returns it as-is (zero copy — bytes is immutable), so
        image -> open_read -> image round-trips don't duplicate fleets of
        merged output on the driver."""
        with self._lock:
            if self._ro is not None:
                base = self._ro.obj
                if type(base) is bytes and len(base) == self._size:
                    return base
                return bytes(self._ro)
            return self._join_segs()

    def persist(self, path: str) -> None:
        with open(path, "wb") as f:
            f.write(self.image())

    @classmethod
    def from_file(cls, path: str) -> "MemoryChunkedFile":
        with open(path, "rb") as f:
            return cls(f.read())


class Bag:
    """Upper tier: topic/timestamp record API over a ChunkedFile.

    ``Bag.open_write(...)`` / ``Bag.open_read(...)`` choose the backend:
    ``backend="disk"`` uses :class:`ChunkedFile`, ``backend="memory"`` uses
    :class:`MemoryChunkedFile` (the paper's cache).
    """

    _INDEX = struct.Struct("<QIQQ")   # chunk offset, record_count, t_min, t_max

    def __init__(self, chunked: ChunkedFile, writable: bool,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES):
        self._cf = chunked
        self._writable = writable
        self._chunk_bytes = chunk_bytes
        self._topics: dict[str, int] = {}
        self._topic_names: list[str] = []
        self._chunks: list[ChunkInfo] = []
        self._pending = bytearray()
        self._pending_records: list[tuple[int, int]] = []  # (topic_id, t)
        self._closed = False
        if not writable:
            self._load_index()

    # -- constructors --------------------------------------------------------

    @classmethod
    def open_write(cls, path: Optional[str] = None, backend: str = "disk",
                   chunk_bytes: int = DEFAULT_CHUNK_BYTES) -> "Bag":
        if backend == "disk":
            return cls(ChunkedFile(path, "w"), True, chunk_bytes)
        elif backend == "memory":
            return cls(MemoryChunkedFile(), True, chunk_bytes)
        raise ValueError(backend)

    @classmethod
    def open_read(cls, path: Optional[str] = None, backend: str = "disk",
                  image: Optional[bytes] = None) -> "Bag":
        if backend == "disk":
            return cls(ChunkedFile(path, "r"), False)
        elif backend == "memory":
            return cls(MemoryChunkedFile(image), False)
        raise ValueError(backend)

    @property
    def chunked_file(self) -> ChunkedFile:
        return self._cf

    # -- write path -----------------------------------------------------------

    def _topic_id(self, topic: str) -> int:
        tid = self._topics.get(topic)
        if tid is None:
            tid = len(self._topic_names)
            self._topics[topic] = tid
            self._topic_names.append(topic)
        return tid

    def write(self, topic: str, timestamp: int, data: bytes) -> None:
        if not self._writable or self._closed:
            raise RuntimeError("bag not writable")
        tid = self._topic_id(topic)
        if not self._pending_records and len(data) >= self._chunk_bytes:
            # large-record fast path: one record = one chunk, single copy
            payload = _REC.pack(tid, timestamp, len(data)) + data
            self._chunks.append(ChunkInfo(
                offset=self._cf.write_chunk(payload, 1), record_count=1,
                t_min=timestamp, t_max=timestamp, topics={tid}))
            return
        self._pending += _REC.pack(tid, timestamp, len(data))
        self._pending += data
        self._pending_records.append((tid, timestamp))
        if len(self._pending) >= self._chunk_bytes:
            self._flush_chunk()

    def write_message(self, msg: Message) -> None:
        self.write(msg.topic, msg.timestamp, msg.data)

    def _flush_chunk(self) -> None:
        if not self._pending_records:
            return
        ts = [t for _, t in self._pending_records]
        info = ChunkInfo(
            offset=self._cf.write_chunk(bytes(self._pending),
                                        len(self._pending_records)),
            record_count=len(self._pending_records),
            t_min=min(ts), t_max=max(ts),
            topics={tid for tid, _ in self._pending_records},
        )
        self._chunks.append(info)
        self._pending.clear()
        self._pending_records.clear()

    def _write_index(self) -> None:
        blob = bytearray()
        names = "\x00".join(self._topic_names).encode()
        blob += struct.pack("<I", len(names)) + names
        blob += struct.pack("<I", len(self._chunks))
        for c in self._chunks:
            blob += self._INDEX.pack(c.offset, c.record_count, c.t_min, c.t_max)
            blob += struct.pack("<I", len(c.topics))
            for tid in sorted(c.topics):
                blob += struct.pack("<I", tid)
        off = self._cf.write_blob(bytes(blob))
        self._cf.write_blob(struct.pack("<QQ", off, len(blob)) + b"RIDX")

    def close(self) -> None:
        if self._closed:
            return
        if self._writable:
            self._flush_chunk()
            self._write_index()
            self._cf.flush()
        self._cf.close()
        self._closed = True

    def __enter__(self) -> "Bag":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- read path -------------------------------------------------------------

    def _load_index(self) -> None:
        size = self._cf.size()
        if size < 32:
            raise ValueError("bag missing index (not closed?)")
        tail = self._cf.read_blob(size - 20, 20)
        off, blen = struct.unpack("<QQ", tail[:16])
        if tail[16:] != b"RIDX" or off + blen > size:
            raise ValueError("bag missing index (not closed?)")
        blob = self._cf.read_blob(off, blen)
        pos = 0
        (nlen,) = struct.unpack_from("<I", blob, pos); pos += 4
        names = blob[pos:pos + nlen].decode(); pos += nlen
        self._topic_names = names.split("\x00") if names else []
        self._topics = {n: i for i, n in enumerate(self._topic_names)}
        (nchunks,) = struct.unpack_from("<I", blob, pos); pos += 4
        for _ in range(nchunks):
            o, rc, tmin, tmax = self._INDEX.unpack_from(blob, pos)
            pos += self._INDEX.size
            (ntop,) = struct.unpack_from("<I", blob, pos); pos += 4
            tops = set(struct.unpack_from(f"<{ntop}I", blob, pos)); pos += 4 * ntop
            self._chunks.append(ChunkInfo(o, rc, tmin, tmax, tops))

    @property
    def topics(self) -> list[str]:
        return list(self._topic_names)

    @property
    def num_chunks(self) -> int:
        return len(self._chunks)

    @property
    def num_messages(self) -> int:
        return sum(c.record_count for c in self._chunks)

    def chunk_infos(self) -> list[ChunkInfo]:
        return list(self._chunks)

    def _iter_chunk(self, info: ChunkInfo) -> Iterator[Message]:
        payload, record_count = self._cf.read_chunk(info.offset)
        pos = 0
        for _ in range(record_count):
            tid, ts, dlen = _REC.unpack_from(payload, pos)
            pos += _REC.size
            data = payload[pos:pos + dlen]
            pos += dlen
            yield Message(self._topic_names[tid], ts, data)

    def content_digest(self) -> str:
        """Streaming chunk-level SHA-256 of the bag's logical content.

        Covers the format version, topic table and every chunk (record
        count, index time bounds, raw payload bytes) — one chunk resident
        at a time, **no record decode**: the per-record framing inside a
        chunk payload is hashed as raw bytes, so digesting costs one
        sequential sweep of the storage tier, not a replay.  Any flipped
        payload byte, timestamp, topic rename or re-chunking changes the
        digest.  This is the bag term of the result-cache key
        (:mod:`repro.cache`): disk and memory backends with identical
        images digest identically.
        """
        if self._writable:
            raise RuntimeError("content_digest requires a read-mode bag")
        h = hashlib.sha256()
        h.update(_MAGIC + struct.pack("<I", _VERSION))
        names = "\x00".join(self._topic_names).encode()
        h.update(struct.pack("<I", len(names)) + names)
        for info in self._chunks:
            payload, record_count = self._cf.read_chunk(info.offset)
            h.update(struct.pack("<IQQ", record_count, info.t_min,
                                 info.t_max))
            h.update(payload)
        return h.hexdigest()

    def read_messages(self, topics: Optional[Sequence[str]] = None,
                      start: Optional[int] = None,
                      end: Optional[int] = None,
                      chunk_range: Optional[tuple[int, int]] = None,
                      ) -> Iterator[Message]:
        """Time-ordered replay.  ``chunk_range=(lo, hi)`` restricts to a chunk
        slice — this is the partitioning handle the scheduler uses."""
        want: Optional[set[int]] = None
        if topics is not None:
            want = {self._topics[t] for t in topics if t in self._topics}
            if not want:
                return
        chunks = self._chunks
        if chunk_range is not None:
            chunks = chunks[chunk_range[0]:chunk_range[1]]
        for info in chunks:
            if start is not None and info.t_max < start:
                continue
            if end is not None and info.t_min >= end:
                continue
            if want is not None and not (info.topics & want):
                continue
            for msg in self._iter_chunk(info):
                if want is not None and self._topics.get(msg.topic) not in want:
                    continue
                if start is not None and msg.timestamp < start:
                    continue
                if end is not None and msg.timestamp >= end:
                    continue
                yield msg


def iter_time_ordered(bag: Bag, topics: Optional[Sequence[str]] = None,
                      start: Optional[int] = None, end: Optional[int] = None,
                      chunk_range: Optional[tuple[int, int]] = None,
                      window: int = 4096) -> Iterator[Message]:
    """Globally time-ordered replay over a bag selection.

    Bag chunks are time-ordered per chunk but may interleave across chunk
    boundaries (e.g. jittered multi-topic writes); a merge-sort over a
    small heap window restores global order without materialising the
    selection.  This is the ordering contract ``RosPlay`` publishes with
    and :func:`merge_bags` merges with.
    """
    it = bag.read_messages(topics=topics, start=start, end=end,
                           chunk_range=chunk_range)
    heap: list[tuple[int, int, Message]] = []
    seq = 0
    for msg in it:
        heapq.heappush(heap, (msg.timestamp, seq, msg))
        seq += 1
        if len(heap) > window:
            yield heapq.heappop(heap)[2]
    while heap:
        yield heapq.heappop(heap)[2]


def bag_content_digest(source: "Bag | bytes | str") -> str:
    """:meth:`Bag.content_digest` over any bag-backed source — an open
    read-mode ``Bag``, a memory-bag image (``bytes``) or a disk path."""
    bag, owned = _open_source(source)
    try:
        return bag.content_digest()
    finally:
        if owned:
            bag.close()


BagSource = Union["Bag", bytes, bytearray, memoryview, str, SegmentHandle,
                  Iterable[Message], "Callable[[], object]"]


def _open_source(source: BagSource) -> tuple[Bag, bool]:
    """Open a bag-backed merge source; returns (bag, owned).  Accepts an
    already-open ``Bag``, a memory-bag image (``bytes``), a disk path
    (``str``), or a shared-memory spill (:class:`~repro.shm.SegmentHandle`
    — the segment stays linked for retries; its owner unlinks it)."""
    if isinstance(source, Bag):
        return source, False
    if isinstance(source, SegmentHandle):
        return Bag.open_read(backend="memory",
                             image=read_segment(source)), True
    if isinstance(source, (bytes, bytearray, memoryview)):
        return Bag.open_read(backend="memory", image=bytes(source)), True
    return Bag.open_read(str(source), backend="disk"), True


def _iter_source(source: BagSource) -> Iterator[Message]:
    """Time-ordered message stream out of any merge source.

    Bag-backed sources (``Bag`` / image / path) are opened lazily inside
    the generator and closed as soon as they are exhausted, so a k-way
    merge holds each owned source only while it is still feeding the
    heap.  A zero-argument callable is resolved on first pull (deferred
    open — e.g. a temp-file spill that appears once a worker lands); any
    other iterable is streamed as-is — the hook that lets shard iterators
    (worker result streams, spilled partitions) merge without ever
    materialising their partition image on the driver.
    """
    if callable(source):
        source = source()
    if isinstance(source, (Bag, bytes, bytearray, memoryview, str,
                           SegmentHandle)):
        bag, owned = _open_source(source)
        try:
            yield from iter_time_ordered(bag)
        finally:
            if owned:
                bag.close()
    else:
        yield from source


def merge_bags(sources: Iterable[BagSource], path: Optional[str] = None,
               chunk_bytes: int = DEFAULT_CHUNK_BYTES) -> Bag:
    """Timestamp-ordered k-way merge of bags into one output bag with a
    rebuilt time/topic index — the bag-layer half of the aggregation stage
    (shard/partition output images -> one fleet-level result bag).

    ``sources`` are ``Bag`` instances, memory-bag images (``bytes``),
    disk paths, time-ordered ``Message`` iterators, or zero-argument
    callables resolving to any of those; source order breaks timestamp
    ties, so merging partition images in (shard, partition) order is
    deterministic.  Iterator/callable sources are the **streaming mode**:
    nothing is materialised per source on the driver — shard outputs
    spilled to disk merge through index-only disk readers, and exhausted
    sources are closed mid-merge instead of being held until the end.
    Returns a read-mode ``Bag``: memory-backed when ``path`` is None,
    else persisted to ``path`` on disk.  Merging zero sources yields a
    valid empty bag.

    Each source must come out of :func:`iter_time_ordered` monotonic —
    true for anything recorded from time-ordered replay.  A pathological
    source whose internal disorder exceeds the heap window would silently
    poison ``heapq.merge``, so monotonicity is checked and raises
    ``ValueError`` instead.
    """
    def keyed(idx: int, source: BagSource,
              ) -> Iterator[tuple[tuple[int, int, int], Message]]:
        last = None
        for seq, msg in enumerate(_iter_source(source)):
            if last is not None and msg.timestamp < last:
                raise ValueError(
                    f"merge source {idx} is out of timestamp order beyond "
                    "the ordering window; re-record it through time-ordered "
                    "replay before merging")
            last = msg.timestamp
            yield (msg.timestamp, idx, seq), msg

    backend = "disk" if path is not None else "memory"
    out = Bag.open_write(path=path, backend=backend, chunk_bytes=chunk_bytes)
    streams = [keyed(i, s) for i, s in enumerate(sources)]
    for _, msg in heapq.merge(*streams, key=lambda kv: kv[0]):
        out.write_message(msg)
    out.close()
    if path is not None:
        return Bag.open_read(path, backend="disk")
    return Bag.open_read(backend="memory", image=out.chunked_file.image())


def partition_bag(bag: Bag, num_partitions: int) -> list[tuple[int, int]]:
    """Split a bag into ``num_partitions`` contiguous chunk ranges with
    roughly equal record counts — the RDD-partitioning step of the platform."""
    counts = [c.record_count for c in bag.chunk_infos()]
    total = sum(counts)
    if not counts:
        return []
    num_partitions = max(1, min(num_partitions, len(counts)))
    target = total / num_partitions
    parts: list[tuple[int, int]] = []
    acc, lo = 0, 0
    for i, c in enumerate(counts):
        acc += c
        if acc >= target and len(parts) < num_partitions - 1:
            parts.append((lo, i + 1))
            lo, acc = i + 1, 0
    parts.append((lo, len(counts)))
    return parts
