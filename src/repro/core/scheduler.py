"""Driver/worker scheduler: the Spark layer of the platform (paper §3, Fig 3).

"The Spark Driver allocates resource from the Spark worker based on the
requested amount of data and computation.  Each Spark worker first reads the
Rosbag data into memory and then launches a ROS node to process the incoming
data."

This module reproduces the *scheduling semantics* a production platform needs
at thousand-node scale, in-process (threads) so it is testable on one core:

* task queue with locality-free FIFO dispatch,
* **fault tolerance**: heartbeat timeouts and fail-fast exceptions requeue
  the task; recompute is safe because every task carries its *lineage*
  (source partition handle), like RDDs,
* **straggler mitigation**: speculative re-execution — when a task has run
  longer than ``speculation_factor ×`` the median completed duration, a
  backup copy is launched on another worker and the first finisher wins,
* **elastic scaling**: workers can join and leave (or die) mid-job,
* bounded retries: a task failing ``max_attempts`` times fails the job
  (poison-pill semantics, not an infinite loop).

The same scheduler drives both the playback simulation (each task = one bag
partition through user logic) and host-side data loading for the training
pipeline.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Optional


class TaskState(Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"


@dataclass
class Task:
    task_id: int
    fn: Callable[..., Any]
    args: tuple
    lineage: tuple = ()              # recompute handle, e.g. ("bag", path, lo, hi)
    attempt: int = 0
    state: TaskState = TaskState.PENDING
    result: Any = None
    error: Optional[BaseException] = None
    started_at: dict[int, float] = field(default_factory=dict)  # attempt -> t
    finished_by: Optional[str] = None


class WorkerError(RuntimeError):
    pass


class Worker(threading.Thread):
    """A simulated cluster worker.

    Fault injection for tests/benchmarks:
      ``fail_after``  : raise on the Nth task it executes (process crash),
      ``slow_factor`` : multiply user-logic sleep time (straggler),
      ``kill()``      : stop heartbeating and accepting work (node loss).
    """

    def __init__(self, worker_id: str, inbox: "queue.Queue",
                 report: Callable[["Worker", Task, int, Any, Optional[BaseException]], None],
                 heartbeat: Callable[["Worker"], None],
                 fail_after: Optional[int] = None,
                 slow_factor: float = 1.0):
        super().__init__(name=f"worker-{worker_id}", daemon=True)
        self.worker_id = worker_id
        self._inbox = inbox
        self._report = report
        self._heartbeat = heartbeat
        self._fail_after = fail_after
        self.slow_factor = slow_factor
        self._alive = True
        self._executed = 0

    def kill(self) -> None:
        self._alive = False

    @property
    def is_alive_worker(self) -> bool:
        return self._alive

    def run(self) -> None:
        while True:
            if not self._alive:
                return                # dead node: stop consuming work
            try:
                item = self._inbox.get(timeout=0.05)
            except queue.Empty:
                self._heartbeat(self)
                continue
            if item is None:          # shutdown sentinel
                return
            task, attempt = item
            if not self._alive:
                # died between get() and here: this one task is lost
                return
            self._heartbeat(self)
            self._executed += 1
            if self._fail_after is not None and self._executed >= self._fail_after:
                self._alive = False   # crash: no report, no more heartbeats
                continue
            try:
                if self.slow_factor > 1.0:
                    # stragglers burn extra wall time before doing the work
                    time.sleep(0.001 * (self.slow_factor - 1.0))
                result = task.fn(*task.args, worker_id=self.worker_id) \
                    if _wants_worker_id(task.fn) else task.fn(*task.args)
                self._report(self, task, attempt, result, None)
            except BaseException as e:   # noqa: BLE001 - report any failure
                self._report(self, task, attempt, None, e)


def _wants_worker_id(fn: Callable) -> bool:
    try:
        import inspect
        return "worker_id" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


class Scheduler:
    """The driver. ``submit`` tasks, ``run`` to completion, ``results`` out."""

    def __init__(self, num_workers: int = 4,
                 max_attempts: int = 4,
                 heartbeat_timeout: float = 2.0,
                 speculation: bool = True,
                 speculation_factor: float = 4.0,
                 speculation_min_done: int = 3):
        self._tasks: dict[int, Task] = {}
        self._next_id = 0
        self._inbox: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._done_durations: list[float] = []
        self._workers: dict[str, Worker] = {}
        self._last_beat: dict[str, float] = {}
        self._max_attempts = max_attempts
        self._hb_timeout = heartbeat_timeout
        self._spec = speculation
        self._spec_factor = speculation_factor
        self._spec_min_done = speculation_min_done
        self._outstanding = 0
        self._failed_job: Optional[BaseException] = None
        self.stats = {"retries": 0, "speculative_launches": 0,
                      "worker_deaths": 0, "tasks_done": 0}
        for i in range(num_workers):
            self.add_worker(f"w{i}")

    # -- elastic membership --------------------------------------------------

    def add_worker(self, worker_id: str, **kw) -> Worker:
        w = Worker(worker_id, self._inbox, self._on_report, self._on_beat, **kw)
        with self._lock:
            self._workers[worker_id] = w
            self._last_beat[worker_id] = time.monotonic()
        w.start()
        return w

    def remove_worker(self, worker_id: str) -> None:
        with self._lock:
            w = self._workers.pop(worker_id, None)
            self._last_beat.pop(worker_id, None)
        if w:
            w.kill()

    def kill_worker(self, worker_id: str) -> None:
        """Simulate node loss (stops heartbeats; running task is lost)."""
        with self._lock:
            w = self._workers.get(worker_id)
        if w:
            w.kill()

    @property
    def num_alive_workers(self) -> int:
        with self._lock:
            return sum(1 for w in self._workers.values() if w.is_alive_worker)

    # -- submission ------------------------------------------------------------

    def submit(self, fn: Callable[..., Any], *args, lineage: tuple = ()) -> int:
        with self._lock:
            tid = self._next_id
            self._next_id += 1
            task = Task(tid, fn, args, lineage)
            self._tasks[tid] = task
            self._outstanding += 1
        self._dispatch(task)
        return tid

    def _dispatch(self, task: Task) -> None:
        task.state = TaskState.RUNNING
        task.started_at[task.attempt] = time.monotonic()
        self._inbox.put((task, task.attempt))

    # -- worker callbacks --------------------------------------------------------

    def _on_beat(self, worker: Worker) -> None:
        with self._lock:
            self._last_beat[worker.worker_id] = time.monotonic()

    def _on_report(self, worker: Worker, task: Task, attempt: int,
                   result: Any, error: Optional[BaseException]) -> None:
        with self._lock:
            self._last_beat[worker.worker_id] = time.monotonic()
            if task.state == TaskState.DONE:
                return                      # a speculative copy already won
            if error is None:
                task.state = TaskState.DONE
                task.result = result
                task.finished_by = worker.worker_id
                start = task.started_at.get(attempt)
                if start is not None:
                    self._done_durations.append(time.monotonic() - start)
                self._outstanding -= 1
                self.stats["tasks_done"] += 1
            else:
                task.attempt += 1
                self.stats["retries"] += 1
                if task.attempt >= self._max_attempts:
                    task.state = TaskState.FAILED
                    task.error = error
                    self._failed_job = error
                    self._outstanding -= 1
                else:
                    self._dispatch(task)

    # -- driver loop -----------------------------------------------------------------

    def _check_faults(self) -> None:
        now = time.monotonic()
        with self._lock:
            dead = [wid for wid, w in self._workers.items()
                    if not w.is_alive_worker
                    or now - self._last_beat.get(wid, now) > self._hb_timeout]
            for wid in dead:
                w = self._workers.pop(wid, None)
                self._last_beat.pop(wid, None)
                if w is not None:
                    self.stats["worker_deaths"] += 1
            # requeue tasks whose only running attempt may have been lost
            if dead:
                for task in self._tasks.values():
                    if task.state == TaskState.RUNNING:
                        started = task.started_at.get(task.attempt, 0)
                        if now - started > self._hb_timeout:
                            task.attempt += 1
                            self.stats["retries"] += 1
                            if task.attempt >= self._max_attempts:
                                task.state = TaskState.FAILED
                                task.error = WorkerError("lost on dead worker")
                                self._failed_job = task.error
                                self._outstanding -= 1
                            else:
                                self._dispatch(task)

    def _check_stragglers(self) -> None:
        if not self._spec:
            return
        with self._lock:
            if len(self._done_durations) < self._spec_min_done:
                return
            durs = sorted(self._done_durations)
            median = durs[len(durs) // 2]
            threshold = max(self._spec_factor * median, 0.05)
            now = time.monotonic()
            for task in self._tasks.values():
                if task.state != TaskState.RUNNING:
                    continue
                started = task.started_at.get(task.attempt)
                if started is None:
                    continue
                if now - started > threshold and task.attempt + 1 not in task.started_at:
                    # launch one backup copy (same attempt counter slot + 1)
                    task.attempt += 1
                    task.started_at[task.attempt] = now
                    self.stats["speculative_launches"] += 1
                    self._inbox.put((task, task.attempt))

    def run(self, timeout: float = 120.0) -> dict[int, Any]:
        """Drive to completion; returns {task_id: result}."""
        deadline = time.monotonic() + timeout
        while True:
            with self._lock:
                outstanding = self._outstanding
                failed = self._failed_job
            if failed is not None:
                raise WorkerError(f"job failed: {failed}") from failed
            if outstanding == 0:
                break
            if time.monotonic() > deadline:
                raise TimeoutError("scheduler run timed out")
            if self.num_alive_workers == 0:
                raise WorkerError("no alive workers and tasks outstanding")
            self._check_faults()
            self._check_stragglers()
            time.sleep(0.005)
        with self._lock:
            return {tid: t.result for tid, t in self._tasks.items()
                    if t.state == TaskState.DONE}

    def shutdown(self) -> None:
        with self._lock:
            workers = list(self._workers.values())
            self._workers.clear()
        for w in workers:
            w.kill()
        for w in workers:
            self._inbox.put(None)

    def __enter__(self) -> "Scheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
