"""Driver/worker scheduler: the Spark layer of the platform (paper §3, Fig 3).

"The Spark Driver allocates resource from the Spark worker based on the
requested amount of data and computation.  Each Spark worker first reads the
Rosbag data into memory and then launches a ROS node to process the incoming
data."

This module reproduces the *scheduling semantics* a production platform needs
at thousand-node scale:

* task queue with locality-free FIFO dispatch,
* **fault tolerance**: heartbeat timeouts and fail-fast exceptions requeue
  the task; recompute is safe because every task carries its *lineage*
  (source partition handle), like RDDs,
* **straggler mitigation**: speculative re-execution — when a task has run
  longer than ``speculation_factor ×`` the median completed duration *of its
  own lineage stage* (e.g. its scenario), a backup copy is launched on
  another worker and the first finisher wins.  Medians are per stage so a
  fast scenario's completions never flag a slow scenario's perfectly
  healthy tasks in a heterogeneous suite,
* **elastic scaling**: workers can join and leave (or die) mid-job,
* bounded retries: a task failing ``max_attempts`` times fails the job
  (poison-pill semantics, not an infinite loop),
* **quarantine mode** (``quarantine=True``): a perma-failing task is
  marked FAILED and *surrendered* instead of failing the whole job — the
  driver keeps going and reports the failure through ``on_task_failed``,
  which is how the scenario suite degrades one scenario to an ERROR
  verdict while the rest of the fleet completes,
* **per-task deadlines** (``task_deadline_s``): an attempt running past
  the deadline is retried on another worker (and counts against
  ``max_attempts``) — a task wedged inside user logic can't pin the job
  to the run timeout.

*Where* tasks execute is delegated to an :class:`ExecutorBackend`
(:mod:`repro.core.executors`): ``backend="thread"`` is the in-process pool
(latency/offload-bound logic), ``backend="process"`` runs one OS process per
worker so CPU-bound user logic parallelizes.  Scheduling semantics are
identical on both — the fault-tolerance test suite runs parametrized over
the two backends.

The same scheduler drives both the playback simulation (each task = one bag
partition through user logic) and host-side data loading for the training
pipeline.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Optional, Union

from repro.obs import metrics as obs_metrics
from repro.obs import trace as otrace

from .executors import (ExecutorBackend, ProcessBackend, TaskPayload,
                        ThreadBackend, Worker, make_backend)

__all__ = ["Task", "TaskState", "Scheduler", "Worker", "WorkerError",
           "ExecutorBackend", "ThreadBackend", "ProcessBackend"]


class TaskState(Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"


@dataclass
class Task:
    task_id: int
    fn: Callable[..., Any]
    args: tuple
    lineage: tuple = ()              # recompute handle, e.g. ("bag", path, lo, hi)
    attempt: int = 0
    state: TaskState = TaskState.PENDING
    result: Any = None
    error: Optional[BaseException] = None
    started_at: dict[int, float] = field(default_factory=dict)  # attempt -> t
    finished_at: Optional[float] = None
    finished_by: Optional[str] = None
    speculated: bool = False         # at most one backup copy per task


class WorkerError(RuntimeError):
    pass


class Scheduler:
    """The driver. ``submit`` tasks, ``run`` to completion, ``results`` out."""

    def __init__(self, num_workers: int = 4,
                 max_attempts: int = 4,
                 heartbeat_timeout: float = 2.0,
                 speculation: bool = True,
                 speculation_factor: float = 4.0,
                 speculation_min_done: int = 3,
                 backend: Union[str, ExecutorBackend] = "thread",
                 quarantine: bool = False,
                 task_deadline_s: Optional[float] = None):
        self._tasks: dict[int, Task] = {}
        self._next_id = 0
        self._lock = threading.Lock()
        # completed-task durations keyed by lineage stage (see _stage_key):
        # speculation thresholds are per stage, not global
        self._done_durations: dict[tuple, list[float]] = {}
        self._last_beat: dict[str, float] = {}
        self._max_attempts = max_attempts
        self._hb_timeout = heartbeat_timeout
        self._spec = speculation
        self._spec_factor = speculation_factor
        self._spec_min_done = speculation_min_done
        self._quarantine = quarantine
        self._task_deadline = task_deadline_s
        self._outstanding = 0
        self._newly_done: list[int] = []     # completions not yet notified
        self._newly_failed: list[int] = []   # quarantined, not yet notified
        self._failed_job: Optional[BaseException] = None
        # counters live in the repro.obs.metrics registry; the ``stats``
        # property below is the deprecated dict-shaped view
        self._metrics = obs_metrics.scope("scheduler")
        self._m = {k: self._metrics.counter(k)
                   for k in ("retries", "speculative_launches",
                             "worker_deaths", "tasks_done", "tasks_failed",
                             "deadline_retries")}
        self._extra_stats: dict[str, int] = {}
        # open ``sched.task`` dispatch spans keyed by (task_id, attempt)
        self._trace_slots: dict[tuple[int, int], list] = {}
        self._backend = make_backend(backend)
        self._backend.start(self._on_report, self._on_beat)
        for i in range(num_workers):
            self.add_worker(f"w{i}")

    @property
    def backend(self) -> ExecutorBackend:
        return self._backend

    @property
    def stats(self) -> dict:
        """Deprecated dict view over the scheduler's registry counters
        (use ``repro.obs.metrics``).  Read-only in effect: mutating the
        returned dict does not touch the underlying metrics."""
        out = {k: c.value for k, c in self._m.items()}
        out.update(self._extra_stats)
        return out

    @property
    def spill_stats(self) -> dict[str, int]:
        """Out-of-band payload movement counters from the backend (zero
        for backends that never spill): total result/arg spills plus how
        many of those rode shared-memory segments and their byte volume.
        Folded into :attr:`stats` when :meth:`run` returns."""
        b = self._backend
        return {k: getattr(b, k, 0)
                for k in ("spills", "arg_spills",
                          "shm_spills", "shm_spill_bytes")}

    # -- elastic membership --------------------------------------------------

    def add_worker(self, worker_id: str, **kw) -> None:
        with self._lock:
            self._last_beat[worker_id] = time.monotonic()
        self._backend.add_worker(worker_id, **kw)

    def remove_worker(self, worker_id: str) -> None:
        with self._lock:
            self._last_beat.pop(worker_id, None)
        self._backend.remove_worker(worker_id)
        # elastic scale-down loses whatever was shipped to the worker and
        # not yet reported (process workers are terminated; dead thread
        # workers leave their in-flight task) — recompute it now
        self._requeue_lost(self._backend.lost_assignments(worker_id))

    def _requeue_lost(self, lost: list[tuple[int, int]]) -> None:
        with self._lock:
            for task_id, attempt in lost:
                task = self._tasks.get(task_id)
                if (task is not None and task.state == TaskState.RUNNING
                        and task.attempt == attempt):
                    self._retry_locked(
                        task, WorkerError("lost on removed worker"))

    def kill_worker(self, worker_id: str) -> None:
        """Simulate node loss (stops heartbeats; running task is lost)."""
        self._backend.kill_worker(worker_id)

    @property
    def num_alive_workers(self) -> int:
        return self._backend.num_alive()

    # -- submission ------------------------------------------------------------

    def submit(self, fn: Callable[..., Any], *args, lineage: tuple = ()) -> int:
        with self._lock:
            tid = self._next_id
            self._next_id += 1
            task = Task(tid, fn, args, lineage)
            self._tasks[tid] = task
            self._outstanding += 1
        self._dispatch(task)
        return tid

    def _dispatch(self, task: Task) -> None:
        task.state = TaskState.RUNNING
        task.started_at[task.attempt] = time.monotonic()
        ctx = 0
        tr = otrace.TRACER
        if tr is not None:
            # the dispatch span covers queue wait + execution (closed at
            # report/retry); its id is the trace context the worker-side
            # ``task.run`` span parents under
            attrs = {"task": task.task_id, "attempt": task.attempt}
            if task.lineage:
                attrs["stage"] = list(task.lineage[:2])
            slot = tr.begin("sched.task", "sched", attrs=attrs)
            self._trace_slots[(task.task_id, task.attempt)] = slot
            ctx = otrace.Tracer.span_id(slot)
        payload: TaskPayload = (task.task_id, task.fn, task.args,
                                task.attempt, ctx)
        self._backend.submit(payload)

    @staticmethod
    def _stage_key(lineage: tuple) -> tuple:
        """Duration-statistics bucket for a task.  Scenario-engine lineage
        is ``("scenario", name, shard, path, lo, hi)`` — the first two
        elements identify the stage; tasks submitted without lineage share
        the ``()`` bucket (the seed-era global median)."""
        return tuple(lineage[:2])

    # -- worker callbacks --------------------------------------------------------

    def _on_beat(self, worker_id: str) -> None:
        with self._lock:
            self._last_beat[worker_id] = time.monotonic()

    def _on_report(self, worker_id: str, task_id: int, attempt: int,
                   result: Any, error: Optional[BaseException]) -> None:
        with self._lock:
            self._last_beat[worker_id] = time.monotonic()
            slot = self._trace_slots.pop((task_id, attempt), None)
            if slot is not None:
                otrace.Tracer.end(slot)
            task = self._tasks.get(task_id)
            if task is None or task.state != TaskState.RUNNING:
                return      # a speculative copy already won, or job failed
            if error is None:
                task.state = TaskState.DONE
                task.result = result
                task.finished_by = worker_id
                task.finished_at = time.monotonic()
                start = task.started_at.get(attempt)
                if start is not None:
                    self._done_durations.setdefault(
                        self._stage_key(task.lineage), []).append(
                            task.finished_at - start)
                self._outstanding -= 1
                self._m["tasks_done"].inc()
                self._newly_done.append(task_id)
            elif attempt == task.attempt:
                self._retry_locked(task, error)
            # else: stale failure from a superseded attempt — a newer
            # (speculative or retried) copy is already in flight; don't
            # burn a retry on it

    def _retry_locked(self, task: Task, error: BaseException) -> None:
        slot = self._trace_slots.pop((task.task_id, task.attempt), None)
        if slot is not None:
            otrace.Tracer.end(slot)         # lost/expired attempt's span
        tr = otrace.TRACER
        if tr is not None:
            tr.instant("sched.retry", "sched",
                       attrs={"task": task.task_id,
                              "attempt": task.attempt,
                              "err": f"{type(error).__name__}: "
                                     f"{error}"[:120]})
        task.attempt += 1
        self._m["retries"].inc()
        if task.attempt >= self._max_attempts:
            task.state = TaskState.FAILED
            task.error = error
            self._outstanding -= 1
            if self._quarantine:
                # surrender the poison task, keep the job: the failure is
                # delivered through on_task_failed, never re-dispatched
                self._m["tasks_failed"].inc()
                self._newly_failed.append(task.task_id)
            else:
                self._failed_job = error
        else:
            self._dispatch(task)

    # -- driver loop -----------------------------------------------------------------

    def _check_faults(self) -> None:
        now = time.monotonic()
        with self._lock:
            last_beat = dict(self._last_beat)
        dead = [wid for wid in self._backend.worker_ids()
                if not self._backend.worker_alive(wid)
                or now - last_beat.get(wid, now) > self._hb_timeout]
        lost: list[tuple[int, int]] = []
        for wid in dead:
            self._backend.remove_worker(wid)
            lost.extend(self._backend.lost_assignments(wid))
            with self._lock:
                self._last_beat.pop(wid, None)
                self._m["worker_deaths"].inc()
            tr = otrace.TRACER
            if tr is not None:
                tr.instant("sched.worker_death", "sched",
                           attrs={"worker": wid})
        # recompute payloads that died with their worker (lineage makes
        # this safe): only if no newer attempt is already in flight
        self._requeue_lost(lost)
        with self._lock:
            # staleness backstop: requeue tasks whose only running attempt
            # may have been lost (e.g. in a dead worker's shared queue slot)
            if dead:
                for task in self._tasks.values():
                    if task.state == TaskState.RUNNING:
                        started = task.started_at.get(task.attempt, 0)
                        if now - started > self._hb_timeout:
                            self._retry_locked(
                                task, WorkerError("lost on dead worker"))

    def _check_deadlines(self) -> None:
        """Retry RUNNING attempts older than ``task_deadline_s`` — the
        worker may be wedged in user logic (no crash, heartbeats flowing),
        which neither the fault sweep nor speculation medians catch when
        every sibling is equally stuck.  Retries burn attempts, so a task
        that *always* exceeds the deadline converges to FAILED/quarantine
        instead of looping."""
        if self._task_deadline is None:
            return
        now = time.monotonic()
        with self._lock:
            for task in self._tasks.values():
                if task.state != TaskState.RUNNING:
                    continue
                started = task.started_at.get(task.attempt)
                if started is not None \
                        and now - started > self._task_deadline:
                    self._m["deadline_retries"].inc()
                    self._retry_locked(task, WorkerError(
                        f"task {task.task_id} attempt {task.attempt} "
                        f"exceeded the {self._task_deadline}s deadline"))

    def _check_stragglers(self) -> None:
        if not self._spec:
            return
        with self._lock:
            # per-stage thresholds: a task is a straggler only relative to
            # completed tasks of its *own* lineage stage, so heterogeneous
            # suites don't cross-flag
            thresholds: dict[tuple, float] = {}
            for key, durs in self._done_durations.items():
                if len(durs) < self._spec_min_done:
                    continue
                ordered = sorted(durs)
                median = ordered[len(ordered) // 2]
                thresholds[key] = max(self._spec_factor * median, 0.05)
            if not thresholds:
                return
            now = time.monotonic()
            tr = otrace.TRACER
            backups: list[TaskPayload] = []
            for task in self._tasks.values():
                if task.state != TaskState.RUNNING or task.speculated:
                    continue
                threshold = thresholds.get(self._stage_key(task.lineage))
                if threshold is None:
                    continue        # stage has too few completions to judge
                started = task.started_at.get(task.attempt)
                if started is None:
                    continue
                if now - started > threshold:
                    # launch one backup copy (same attempt counter slot + 1)
                    task.speculated = True
                    task.attempt += 1
                    task.started_at[task.attempt] = now
                    self._m["speculative_launches"].inc()
                    ctx = 0
                    if tr is not None:
                        slot = tr.begin("sched.task", "sched",
                                        attrs={"task": task.task_id,
                                               "attempt": task.attempt,
                                               "speculative": True})
                        self._trace_slots[(task.task_id, task.attempt)] = slot
                        ctx = otrace.Tracer.span_id(slot)
                    backups.append((task.task_id, task.fn, task.args,
                                    task.attempt, ctx))
        for payload in backups:
            self._backend.submit(payload)

    def run(self, timeout: float = 120.0,
            on_task_done: Optional[Callable[[int, Any], None]] = None,
            on_task_failed: Optional[Callable[[int, BaseException],
                                              None]] = None,
            ) -> dict[int, Any]:
        """Drive to completion; returns {task_id: result}.

        ``on_task_done(task_id, result)`` — if given — is invoked from the
        *driver loop* (never a worker thread) once per completed task, in
        completion order.  The callback may call :meth:`submit`, which is
        how pipeline stages chain: e.g. the scenario suite schedules a
        scenario's aggregation task the moment its last replay partition
        reports, so aggregation overlaps the remaining replay work.  The
        loop only exits when nothing is outstanding *and* every completion
        has been notified, so late submissions from callbacks are never
        dropped.

        ``on_task_failed(task_id, error)`` is the quarantine twin: invoked
        (driver loop, in failure order) for each task surrendered at
        ``max_attempts`` when ``quarantine=True``.  Without the flag a
        perma-failed task raises :class:`WorkerError` here instead.
        """
        deadline = time.monotonic() + timeout
        while True:
            with self._lock:
                fresh, self._newly_done = self._newly_done, []
                fresh_failed, self._newly_failed = self._newly_failed, []
            if on_task_done is not None:
                for tid in fresh:
                    with self._lock:
                        task = self._tasks.get(tid)
                        result = task.result if task is not None else None
                    on_task_done(tid, result)
            for tid in fresh_failed:
                if on_task_failed is None:
                    continue
                with self._lock:
                    task = self._tasks.get(tid)
                    error = task.error if task is not None else None
                on_task_failed(tid, error)
            with self._lock:
                outstanding = self._outstanding
                failed = self._failed_job
                drained = not self._newly_done and not self._newly_failed
            if failed is not None:
                raise WorkerError(f"job failed: {failed}") from failed
            if outstanding == 0 and drained and not fresh \
                    and not fresh_failed:
                break
            if outstanding > 0:
                if time.monotonic() > deadline:
                    raise TimeoutError("scheduler run timed out")
                if self.num_alive_workers == 0:
                    raise WorkerError(
                        "no alive workers and tasks outstanding")
            # fault/straggler sweeps run every iteration — a steady stream
            # of completions must not starve dead-worker detection
            self._check_faults()
            self._check_deadlines()
            self._check_stragglers()
            if not fresh and not fresh_failed:
                time.sleep(0.005)   # idle tick; skip the nap mid-burst
        spill = self.spill_stats
        self._extra_stats.update(spill)
        for k, v in spill.items():
            self._metrics.gauge(k).set(v)
        with self._lock:
            return {tid: t.result for tid, t in self._tasks.items()
                    if t.state == TaskState.DONE}

    def discard(self, task_id: int) -> None:
        """Drop a DONE (or quarantined-FAILED) task's result and args
        from driver memory.

        The task record (state, lineage, timings) survives, so stats and
        ``task_finished_at`` keep working — only the payload references
        are released.  This is what keeps driver residency at O(one
        in-flight scenario) instead of O(total fleet output): callers that
        consume a result inside an ``on_task_done`` callback discard it
        immediately after.
        """
        with self._lock:
            task = self._tasks.get(task_id)
            if task is not None and task.state in (TaskState.DONE,
                                                   TaskState.FAILED):
                task.result = None
                task.args = ()

    def task_finished_at(self, task_id: int) -> Optional[float]:
        with self._lock:
            task = self._tasks.get(task_id)
            return task.finished_at if task else None

    def shutdown(self) -> None:
        self._backend.shutdown()

    def __enter__(self) -> "Scheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
