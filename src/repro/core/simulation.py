"""The scenario engine: distributed simulation over a suite of scenarios
(paper Fig 3 + Fig 5 workflow, generalized from "replay one bag" to "run a
test matrix over a drive fleet").

    Scenario catalog --ScenarioSuite--> Scheduler/ExecutorBackend
        --RosPlay--> MessageBus --User Logic--> RosRecord --> Bag
        --Aggregator--> merged Bag + metrics --> Verdict

A :class:`Scenario` describes one functional/performance test: one bag
(``bag_path``) or a sharded fleet of bags (``bag_paths``), a topic filter,
a time window, a latency/fault profile, a user-logic ref and an optional
golden bag.  A :class:`ScenarioSuite` fans every partition of every shard
of every scenario through ONE scheduler (thread or process backend), then
hands each scenario's partition outputs to the aggregation layer
(:mod:`repro.core.aggregation`): shard outputs are k-way merged into one
timestamp-ordered bag, per-topic metrics are computed, golden bags are
compared, and ``run`` returns per-scenario :class:`Verdict`\\ s — the
paper's "massive test suites over a shared cluster", scored.

Per the paper: "Each Spark worker first reads the Rosbag data into memory
and then launches a ROS node to process the incoming data."  Here each task:

1. reads its chunk-range partition from the source bag (applying the
   scenario's topic filter and time window),
2. copies it into a ``MemoryChunkedFile``-backed bag (the ROSBag cache —
   this is the I/O optimisation §4.1 measures),
3. replays it through the user logic attached to the bus — per message, or
   in timestamp-ordered micro-batches when ``Scenario.batch_size`` is set
   (``RosPlay.run_batched`` -> ``MessageBus.publish_batch``), so the logic
   can be a jitted array step over assembled batches
   (:func:`repro.data.pipeline.assemble_message_batch` +
   :func:`repro.kernels.sensor_decode.sensor_decode`),
4. records outputs into a memory bag and ships its image plus KB-sized
   partial per-topic metrics (a streaming :class:`MetricsTap` on the sink
   side — fork-safe numpy digests on process workers, the fused Pallas
   consume step for batched in-process scenarios) as the task result;
   per-scenario aggregation then runs as its own scheduled task
   (lineage stage ``"aggregate"``), overlapping remaining replay work.
   Latency-modeling scenarios replay as a staged read → logic → record
   pipeline over queued bus lanes (``Scenario.pipeline``), overlapping
   disk I/O, compute and bag serialization inside each task.

``user_logic`` contracts:
  per-message : ``Message -> Optional[(topic, bytes)]`` (output inherits the
                input timestamp — the seed contract),
  batched     : ``list[Message] -> Optional[iterable[(topic, ts, bytes)]]``.
Either may be given as a ``"module:attr"`` string ref, resolved inside the
worker — required for the process backend, where the callable must cross a
pickle boundary.
"""

from __future__ import annotations

import hashlib
import importlib
import json
import os
import random
import sys
import threading
import time
import zlib
from dataclasses import dataclass, field, fields as dataclass_fields
from typing import Callable, Optional, Sequence, Union

from repro import chaos
from repro.obs import metrics as obs_metrics
from repro.obs import trace as otrace
from repro.shm import SegmentHandle, read_segment, shm_available

from .aggregation import Aggregator, MetricsTap, TopicMetrics, Verdict
from .bag import Bag, Message, partition_bag
from .binpipe import BinaryPartition, encode
from .executors import ExecutorBackend
from .playback import (MESSAGE_PREFETCH, TRACE_CHUNK, MessageBus, RosPlay,
                       RosRecord)
from .scheduler import Scheduler

UserLogic = Callable[[Message], Optional[tuple[str, bytes]]]
BatchUserLogic = Callable[[Sequence[Message]],
                          Optional[Sequence[tuple[str, int, bytes]]]]
LogicRef = Union[UserLogic, BatchUserLogic, str]


def resolve_logic_ref(ref: LogicRef) -> Callable:
    """Resolve a ``"package.module:attr"`` string ref to the callable it
    names; callables pass through.  String refs are what a process-backend
    scenario ships across the pickle boundary.

    ``"perception://<model>"`` refs resolve to the stock jitted
    decode→forward batched logic (:mod:`repro.perception`), cached per
    process so every partition naming the same model shares one compiled
    step and one deterministic param set.  Perception scenarios must set
    ``batch_size`` (the step consumes assembled batches) and run on
    in-process backends (see :class:`ScenarioSuite`).
    """
    if callable(ref):
        return ref
    if str(ref).startswith("perception://"):
        from repro.perception import get_step
        return get_step(str(ref))
    mod_name, _, attr = str(ref).partition(":")
    if not attr:
        raise ValueError(f"logic ref {ref!r} is not 'module:attr'")
    fn = getattr(importlib.import_module(mod_name), attr)
    if not callable(fn):
        raise TypeError(f"logic ref {ref!r} resolved to non-callable {fn!r}")
    return fn


def _logic_fingerprint(ref: LogicRef) -> str:
    """Canonical content-addressable identity of a user-logic ref.

    String refs (``"module:attr"`` / ``"perception://<model>"``) are their
    own identity.  A module-level callable is accepted iff it re-resolves
    to itself through its ``module:qualname`` — the same contract the
    process backend already imposes — and fingerprints as that ref.
    Lambdas, closures and bound methods have no stable identity across
    runs, so they raise: a scenario carrying one is simply *uncacheable*
    (the suite replays it every time rather than risking a stale hit).
    """
    if isinstance(ref, str):
        return ref
    mod = getattr(ref, "__module__", None)
    qualname = getattr(ref, "__qualname__", None)
    if mod and qualname and "<" not in qualname:
        try:
            obj: object = importlib.import_module(mod)
            for part in qualname.split("."):
                obj = getattr(obj, part)
        except (ImportError, AttributeError):
            obj = None
        if obj is ref:
            return f"{mod}:{qualname}"
    raise ValueError(
        f"user_logic {ref!r} has no stable content identity (lambda, "
        "closure or non-importable callable); use a 'module:attr' ref to "
        "make the scenario cacheable")


#: Scenario fields that name *where* content lives rather than *what* runs;
#: the result-cache key digests their content separately (bag/golden
#: digests), so renaming a scenario or moving a bag never invalidates.
_FINGERPRINT_EXCLUDE = ("name", "bag_path", "bag_paths", "golden_bag_path")


@dataclass(frozen=True)
class Scenario:
    """One entry of the test matrix.

    The bag source is either ``bag_path`` (one recorded drive) or
    ``bag_paths`` (a sharded fleet — one bag per vehicle/segment); exactly
    one must be given.  Every shard is partitioned, replayed and recorded
    independently; the aggregation layer merges the shard outputs back
    into one timestamp-ordered result bag.  ``num_partitions`` is
    *per shard*.

    ``batch_size=None`` replays per message (seed behaviour); an integer
    switches to batched replay and the batched user-logic contract.
    ``drop_rate`` is the fault profile: that fraction of input messages is
    dropped (deterministically, per ``seed``) before reaching user logic —
    simulated sensor dropouts.  ``latency_model_s`` sleeps once per user
    logic invocation (per message, or per batch — batching amortizes it,
    like a real accelerator-offloaded model step).

    ``golden_bag_path`` names a recorded expected-output bag; the
    aggregator diffs the merged output against it (exact or
    tolerance-based, see :class:`repro.core.aggregation.Aggregator`) and
    the scenario's verdict fails on any mismatch.

    ``pipeline`` selects the partition replay shape: ``True`` is the
    staged read → logic → record pipeline over queue-backed bus
    subscriptions (disk I/O, user logic and bag serialization overlap),
    ``False`` the synchronous seed shape, and ``None`` (default) resolves
    automatically — staged when the scenario models per-invocation
    compute latency (``latency_model_s > 0``, the regime where the logic
    stage yields and overlap wins), synchronous for free-running logic
    where queue handoffs would only tax the hot loop.  Outputs, metrics
    and verdicts are bit-identical either way, so the switch is purely a
    performance choice.  ``queue_depth`` bounds each pipeline stage's
    FIFO (the backpressure window); ``None`` (default) is **adaptive** —
    lanes start shallow and deepen themselves while the producer outruns
    the sink, bounded by a memory cap (see
    :class:`repro.core.playback.MessageBus`).  ``metrics_engine`` picks the
    sink-stage digest reduction
    (:class:`repro.core.aggregation.MetricsTap`): ``"auto"`` resolves to
    the fused Pallas consume step for batched in-process scenarios and the
    fork-safe numpy engine otherwise (process workers never init jax).
    ``ts_sketch`` bounds the sink's per-topic timestamp state to a KMV
    sample of that many values (see
    :class:`repro.core.aggregation.TopicMetrics`): counts, bounds and
    checksums — everything golden verdicts read — stay exact; gap
    percentiles become estimates.  ``None`` (default) keeps exact
    multisets.

    ``exports``/``imports`` wire scenarios together through the
    distributed message pool (:mod:`repro.net`): a scenario's user-logic
    outputs on its ``exports`` topics are routed — in-process or over
    cross-process transports, the suite decides — to every scenario that
    lists those topics in ``imports``.  An importing scenario replays the
    merged, timestamp-ordered import stream through its user logic as one
    extra partition (inputs, like bag traffic: excluded from its own
    recording), scheduled once all its providers finish.  The routing
    graph must be a DAG and each topic may have exactly one exporter; a
    topic cannot appear in both tuples of one scenario.  Outputs are
    bit-identical whichever transport shape carries the stream.
    """
    name: str
    bag_path: Optional[str] = None
    user_logic: LogicRef = None
    topics: Optional[tuple[str, ...]] = None
    start: Optional[int] = None          # time window, ns (inclusive)
    end: Optional[int] = None            # time window, ns (exclusive)
    latency_model_s: float = 0.0
    drop_rate: float = 0.0
    seed: int = 0
    batch_size: Optional[int] = None
    num_partitions: Optional[int] = None
    use_memory_cache: bool = True
    bag_paths: Optional[tuple[str, ...]] = None   # fleet shards
    golden_bag_path: Optional[str] = None
    pipeline: Optional[bool] = None      # None = auto (see docstring)
    queue_depth: Optional[int] = None    # None = adaptive lanes
    metrics_engine: str = "auto"
    ts_sketch: Optional[int] = None      # None = exact timestamp multisets
    exports: Optional[tuple[str, ...]] = None     # topics fed to importers
    imports: Optional[tuple[str, ...]] = None     # topics fed by exporters

    def __post_init__(self):
        if self.user_logic is None:
            raise ValueError(f"scenario {self.name!r} has no user_logic")
        if self.metrics_engine not in ("auto", "numpy", "jax", "fused"):
            raise ValueError(f"scenario {self.name!r}: unknown "
                             f"metrics_engine {self.metrics_engine!r}")
        if self.ts_sketch is not None and self.ts_sketch < 1:
            raise ValueError(f"scenario {self.name!r}: ts_sketch >= 1 "
                             "(or None for exact timestamp multisets)")
        if (isinstance(self.user_logic, str)
                and self.user_logic.startswith("perception://")
                and self.batch_size is None):
            raise ValueError(
                f"scenario {self.name!r}: perception:// logic is batched — "
                "set batch_size")
        if self.queue_depth is not None and self.queue_depth < 1:
            raise ValueError(f"scenario {self.name!r}: queue_depth >= 1 "
                             "(or None for adaptive)")
        if (self.bag_path is None) == (self.bag_paths is None):
            raise ValueError(f"scenario {self.name!r}: give exactly one of "
                             "bag_path / bag_paths")
        if self.bag_paths is not None and not isinstance(self.bag_paths,
                                                         tuple):
            object.__setattr__(self, "bag_paths", tuple(self.bag_paths))
        for fld in ("exports", "imports"):
            v = getattr(self, fld)
            if v is not None and not isinstance(v, tuple):
                object.__setattr__(self, fld, tuple(v))
        if self.exports and self.imports:
            both = set(self.exports) & set(self.imports)
            if both:
                raise ValueError(
                    f"scenario {self.name!r}: topics {sorted(both)} are "
                    "both imported and exported — relaying a topic through "
                    "a scenario is ambiguous; transform it onto a new topic")

    @property
    def shard_paths(self) -> tuple[str, ...]:
        """The fleet as a tuple of bag paths (length 1 for ``bag_path``)."""
        return ((self.bag_path,) if self.bag_path is not None
                else self.bag_paths)

    def fingerprint(self) -> str:
        """Canonical SHA-256 over every replay-relevant parameter — the
        scenario term of the result-cache key (:mod:`repro.cache`).

        Covers the topic filter, time window, latency/drop profiles and
        seed, batch/queue/pipeline parameters, metric engine and sketch
        settings, the exports/imports wiring and the user-logic ref —
        every dataclass field except the scenario *name* and the bag /
        golden *paths* (their content is digested separately, so a
        rename or relocation with identical bytes still hits).  Any
        parameter change produces a new fingerprint and forces a clean
        re-replay.  Raises ``ValueError`` when the user logic has no
        stable content identity (see :func:`_logic_fingerprint`) — such
        scenarios are uncacheable, never wrongly cached.
        """
        spec = {}
        for f in dataclass_fields(self):
            if f.name in _FINGERPRINT_EXCLUDE:
                continue
            value = getattr(self, f.name)
            if f.name == "user_logic":
                value = _logic_fingerprint(value)
            spec[f.name] = value
        return hashlib.sha256(
            json.dumps(spec, sort_keys=True).encode()).hexdigest()

    @property
    def staged(self) -> bool:
        """The resolved replay shape: explicit ``pipeline`` wins; auto
        (``None``) stages exactly the latency-modeling scenarios, where
        the logic stage sleeps/offloads and overlap pays — free-running
        logic keeps the zero-handoff synchronous hot loop."""
        if self.pipeline is not None:
            return self.pipeline
        return self.latency_model_s > 0


@dataclass
class SimulationReport:
    """Per-scenario replay outcome, post-aggregation.

    ``output_image`` is the merged, timestamp-ordered output bag (all
    shards, all partitions — one image), and ``metrics`` the per-topic
    :class:`TopicMetrics` the aggregator computed over it.  The seed-era
    per-partition image list (``partition_images`` / the deprecated
    ``output_images`` accessor) is gone: the driver holds exactly one
    merged image per scenario.
    """
    messages_in: int
    messages_out: int
    wall_time_s: float
    partitions: int
    scheduler_stats: dict
    scenario: str = ""
    backend: str = ""
    batch_size: Optional[int] = None
    messages_dropped: int = 0
    shards: int = 1
    output_image: Optional[bytes] = None     # merged output bag image
    metrics: dict[str, TopicMetrics] = field(default_factory=dict)

    @property
    def throughput_msgs_s(self) -> float:
        return self.messages_in / self.wall_time_s if self.wall_time_s else 0.0

    def open_output_bag(self) -> Bag:
        """The merged output as a readable memory bag."""
        if self.output_image is None:
            raise ValueError("report has no merged output image")
        return Bag.open_read(backend="memory", image=self.output_image)


def _run_scenario_partition(scenario: Scenario, source: "str | bytes",
                            chunk_range: Optional[tuple[int, int]],
                            metrics_engine: str = "numpy",
                            export_to: Optional[tuple[str, int, str]] = None,
                            rng_tag: Optional[str] = None,
                            collect_exports: bool = False,
                            ) -> tuple[int, int, int, bytes, dict,
                                       Optional[list]]:
    """One worker task: play one shard partition through the user logic.

    ``source`` is a disk bag path or a memory-bag image (bytes — either
    shape may arrive for an *import partition*: the driver ships the
    merged import stream inline or as a spill path, see
    :class:`ScenarioSuite`).  ``chunk_range=None`` marks an import
    partition: the whole source replays and the scenario's topic/time
    selection does **not** re-filter it (the driver already filtered by
    ``Scenario.imports``; the provider's selection shaped the stream).
    ``rng_tag`` overrides the shard-path term of the drop-RNG seed so an
    import partition draws identically whether its stream arrived as
    bytes or as a spill path.

    Export routing: when ``scenario.exports`` is consumed by the suite,
    either ``export_to=(host, port, stream_id)`` streams the exported
    topics over a :class:`repro.net.transport.LaneTransport` bridge to the
    driver-hosted endpoint as they are published (the cross-process
    shape), or ``collect_exports=True`` captures them into the task
    result (the in-process shape).  Both capture exactly the partition's
    publish order; the suite's merge makes the shapes bit-identical.

    With ``scenario.staged`` (explicit ``pipeline=True``, or auto for
    latency-modeling scenarios) the partition runs as a three-stage
    pipeline over queue-backed bus subscriptions:

        read stage    — a prefetch reader thread decodes bag chunks and
                        keeps messages/micro-batches buffered ahead,
        logic stage   — fault profile + user logic on its own lane worker
                        (one lane shared across input topics, so the
                        drop-RNG draw order is exactly the publish order),
        sink stage    — ``RosRecord`` (bag serialization) and a
                        :class:`MetricsTap` (per-record digests) each on
                        their own lane.

    Disk I/O, XLA compute and bag serialization overlap instead of
    alternating; bounded lanes give backpressure; ``bus.drain()`` is the
    end-of-replay barrier that makes the overlap invisible to results.
    ``pipeline=False`` delivers every stage synchronously (the seed
    shape).  Both shapes produce bit-identical outputs and partials.

    Returns (messages_in, messages_out, messages_dropped, output bag image,
    partial metrics, exported messages or None).  The partial metrics —
    per-topic mergeable
    :class:`TopicMetrics` over this partition's *output* — are computed
    here, on the worker, *as outputs stream through the sink stage*: the
    driver combines KB-sized partials instead of re-reading MB-sized
    payload matrices, and the worker no longer re-sweeps its own output
    image at end of task.
    """
    logic = resolve_logic_ref(scenario.user_logic)
    is_import = chunk_range is None
    # import partitions bypass the scenario's own selection: the stream
    # was already filtered to Scenario.imports by the driver, and the
    # provider's topic/time window shaped it
    topics = (None if is_import or scenario.topics is None
              else list(scenario.topics))
    t_start = None if is_import else scenario.start
    t_end = None if is_import else scenario.end
    if isinstance(source, SegmentHandle):
        # arg-spilled image parked in /dev/shm by the driver: one attach
        # and copy-out; the driver's pool still owns the segment, so a
        # retried or speculative attempt re-reads the same handle
        src = Bag.open_read(backend="memory", image=read_segment(source))
    elif isinstance(source, (bytes, bytearray)):
        src = Bag.open_read(backend="memory", image=bytes(source))
    else:
        src = Bag.open_read(source, backend="disk")
    if scenario.use_memory_cache:
        # materialise the (filtered) partition into the ROSBag cache (§3.2):
        cache = Bag.open_write(backend="memory")
        for msg in src.read_messages(topics=topics, start=t_start,
                                     end=t_end, chunk_range=chunk_range):
            cache.write_message(msg)
        cache.close()
        play_bag = Bag.open_read(backend="memory",
                                 image=cache.chunked_file.image())
        play = dict(chunk_range=None, topics=None, start=None, end=None)
        input_topics = play_bag.topics
    else:
        play_bag = src
        play = dict(chunk_range=chunk_range, topics=topics,
                    start=t_start, end=t_end)
        input_topics = ([t for t in src.topics if t in topics]
                        if topics is not None else src.topics)

    staged = scenario.staged
    mode = "queued" if staged else "sync"
    depth = scenario.queue_depth
    bus = MessageBus()
    out_bag = Bag.open_write(backend="memory")
    # record everything the user logic publishes, but not the replayed
    # inputs; in batched mode the recorder rides the batch subscription so
    # no per-message callback remains on the replay hot path
    rec = RosRecord(bus, out_bag, topics=None, exclude_topics=src.topics,
                    batch=scenario.batch_size is not None,
                    mode=mode, queue_maxsize=depth)
    # metrics ride the sink stage: per-record digests accumulate as outputs
    # stream past, so partials are ready at drain (no output-image re-sweep);
    # input-topic exclusion is enforced bus-side (sink_kw below)
    tap = MetricsTap(engine=metrics_engine, ts_sketch=scenario.ts_sketch)

    n_out = 0
    n_drop = 0
    # deterministic fault profile, decorrelated across shards + partitions
    # (crc32, not hash(): str hashing is per-process randomized); import
    # partitions seed from their rng_tag so the draw sequence is invariant
    # to how the stream was shipped (inline bytes vs spill path)
    tag = rng_tag if rng_tag is not None else (
        source if isinstance(source, str) else "<memory>")
    lo, hi = chunk_range if chunk_range is not None else (0, 0)
    rng = random.Random(scenario.seed * 1_000_003
                        + zlib.crc32(tag.encode()) * 131
                        + lo * 8191 + hi)
    drop = scenario.drop_rate

    # chaos: captured ONCE per partition — the common no-chaos case costs
    # a single global read here and one None check per delivery
    chaos_plan = chaos.active_plan()

    # one shared "logic" lane across all input topics: the drop-RNG draw
    # order (and hence the output stream) is exactly the synchronous one.
    # The tap excludes input topics bus-side, so replay traffic is never
    # even enqueued toward the metrics sink.
    logic_kw = dict(mode=mode, maxsize=depth, group="logic")
    sink_kw = dict(mode=mode, maxsize=depth, group="metrics",
                   exclude_topics=src.topics)
    # logic-stage tracing: one span per micro-batch in batched mode;
    # per-message mode emits one chunk-level ``logic.step`` span per
    # TRACE_CHUNK callbacks (two clock reads per message when enabled,
    # zero when disabled) so the hot path never pays per-message spans
    _ls = [0, 0, 0]                      # chunk t0, callbacks, busy ns

    def _flush_logic(now: int) -> None:
        tr = otrace.TRACER
        if tr is not None and _ls[1]:
            tr.emit("logic.step", "logic", _ls[0], now,
                    attrs={"n": _ls[1], "busy_ns": _ls[2]})
        _ls[0] = _ls[1] = _ls[2] = 0

    def _logic_tick(t0: int) -> None:
        now = time.perf_counter_ns()
        if _ls[0] == 0:
            _ls[0] = t0
        _ls[1] += 1
        _ls[2] += now - t0
        if _ls[1] >= TRACE_CHUNK:
            _flush_logic(now)

    if scenario.batch_size is None:
        def on_msg(msg: Message) -> None:
            nonlocal n_out, n_drop
            t0 = (time.perf_counter_ns()
                  if otrace.TRACER is not None else 0)
            try:
                if drop and rng.random() < drop:
                    n_drop += 1
                    return
                if scenario.latency_model_s:
                    time.sleep(scenario.latency_model_s)  # simulated model
                if chaos_plan is not None and chaos_plan.probe(
                        "logic_raise", scenario.name) is not None:
                    raise chaos.ChaosFault(
                        f"injected user-logic failure in {scenario.name!r}")
                out = logic(msg)
                if out is not None:
                    topic, data = out
                    bus.advertise(topic).publish(msg.timestamp, data)
                    n_out += 1
            finally:
                if t0:
                    _logic_tick(t0)

        for t in input_topics:
            bus.subscribe(t, on_msg, **logic_kw)
        bus.subscribe(None, tap.on_message, **sink_kw)
    else:
        def on_batch(msgs: list[Message]) -> None:
            nonlocal n_out, n_drop
            tr = otrace.TRACER
            slot = (tr.begin("logic.step", "logic", attrs={"n": len(msgs)})
                    if tr is not None else None)
            try:
                if drop:
                    kept = [m for m in msgs if rng.random() >= drop]
                    n_drop += len(msgs) - len(kept)
                    msgs = kept
                    if not msgs:
                        return
                if scenario.latency_model_s:
                    time.sleep(scenario.latency_model_s)  # one step/batch
                if chaos_plan is not None and chaos_plan.probe(
                        "logic_raise", scenario.name) is not None:
                    raise chaos.ChaosFault(
                        f"injected user-logic failure in {scenario.name!r}")
                outs = logic(msgs)
                if outs:
                    out_msgs = [Message(t, ts, d) for t, ts, d in outs]
                    bus.publish_batch(out_msgs)
                    n_out += len(out_msgs)
            finally:
                if slot is not None:
                    otrace.Tracer.end(slot)

        for t in input_topics:
            bus.subscribe_batch(t, on_batch, **logic_kw)
        bus.subscribe_batch(None, tap.on_batch, **sink_kw)

    # export routing: the exported topics leave this partition either over
    # a transport bridge (cross-process shape: streamed to the driver's
    # endpoint as they are published) or through a synchronous capture
    # returned with the result (in-process shape).  Both observe exactly
    # the publish order.
    exported: Optional[list[Message]] = None
    bridge = None
    export_topics = sorted(scenario.exports or ())
    if export_topics and export_to is not None:
        from repro.net.transport import LaneTransport
        # 4th element (use the same-host shm ring) is optional so older
        # 3-tuple callers keep the pure-TCP shape
        host, port, stream_id = export_to[:3]
        use_shm = bool(export_to[3]) if len(export_to) > 3 else False
        transport = LaneTransport.connect((host, port), stream_id=stream_id,
                                          shm=use_shm)
        bridge = bus.bridge(export_topics, transport,
                            maxsize=scenario.queue_depth)
    elif export_topics and collect_exports:
        exported = []
        for t in export_topics:
            bus.subscribe(t, exported.append)

    rec.start()
    player = RosPlay(play_bag, bus, **play)
    try:
        if scenario.batch_size is None:
            n_in = player.run(prefetch=MESSAGE_PREFETCH if staged else 0)
        else:
            # double-buffered framing: the bag-chunk reader thread keeps
            # the next micro-batch decoded while this one is in flight
            n_in = player.run_batched(scenario.batch_size,
                                      prefetch=2 if staged else 0)
        bus.drain()         # barrier: every stage flushed, errors surface
        _flush_logic(time.perf_counter_ns())    # close the last logic chunk
        if bridge is not None:
            bridge.drain()  # cross-wire barrier: the collector has the
            #                 full stream before this task can report
        rec.stop()          # surfaces deferred recorder write errors
    finally:
        if bridge is not None:
            try:
                bridge.close()
            except BaseException:  # noqa: BLE001 - drain above is the
                pass               # barrier; close is best-effort release
        try:
            rec.stop()      # no-op when already stopped (exception-safe)
        except BaseException:   # noqa: BLE001 - the drain/stop error above
            pass                # is the one that must propagate
        bus.close()         # always stop lane workers — no thread leak
        src.close()         # and never leak bag handles on a failed task
        if scenario.use_memory_cache:
            play_bag.close()
    out_bag.close()
    # image() is close-safe by contract (captured at close time) — the
    # use-after-close here was a latent bug before MemoryChunkedFile.close
    # consolidated the image
    image = out_bag.chunked_file.image()
    return n_in, n_out, n_drop, image, tap.finalize(), exported


def _run_scenario_aggregate(aggregator: Aggregator, scenario_name: str,
                            sources: Sequence,
                            partials: Sequence[dict],
                            golden_path: Optional[str],
                            messages_in: int) -> tuple[bytes, Verdict]:
    """One worker task: the aggregation stage of one scenario.

    Merges the (shard, partition)-ordered output sources into one
    timestamp-ordered bag, folds the worker-computed partial metrics
    (no payload re-sweep), compares against the golden bag, and returns
    ``(merged image, verdict)``.  ``sources`` are memory-bag images *or
    spill paths* (see ``ProcessBackend.spill_arg``): on the process
    backend the driver parks each partition image in the backend's spill
    dir and ships only the path, so the worker merges through streaming
    index-only disk readers and MB-sized images never ride the task pipe
    in either direction.  Scheduled on the shared pool with lineage stage
    ``"aggregate"`` so it overlaps remaining replay work and gets the
    scheduler's full retry/speculation semantics — spill files outlive
    the task (the backend reaps them at shutdown), so recompute is safe.
    """
    with otrace.span("aggregate.merge", "agg",
                     attrs={"scenario": scenario_name,
                            "sources": len(sources)}):
        merged, verdict = aggregator.aggregate(
            scenario_name, sources, golden=golden_path,
            messages_in=messages_in, partials=list(partials))
        image = merged.chunked_file.image()
        merged.close()
    return image, verdict


def _run_partition(bag_path: str, chunk_range: tuple[int, int],
                   user_logic: UserLogic, use_memory_cache: bool,
                   latency_model_s: float = 0.0) -> tuple[int, int, bytes]:
    """Seed-compatible single-partition entry point (per-message replay).

    Returns (messages_in, messages_out, output bag image).
    """
    sc = Scenario(name="partition", bag_path=bag_path, user_logic=user_logic,
                  latency_model_s=latency_model_s,
                  use_memory_cache=use_memory_cache)
    n_in, n_out, _, image, _, _ = _run_scenario_partition(sc, bag_path,
                                                          chunk_range)
    return n_in, n_out, image


def _selection_matches_nothing(src: Bag, sc: Scenario) -> bool:
    """True when the scenario's topic filter / time window provably selects
    zero messages of ``src`` (from the chunk index alone).  Such shards get
    no tasks at all — an empty selection is a clean zero-message report and
    a vacuous PASS, not a degenerate partition plan."""
    if not src.num_chunks:
        return True
    if sc.topics is not None and not (set(sc.topics) & set(src.topics)):
        return True
    if sc.start is not None or sc.end is not None:
        for info in src.chunk_infos():
            if sc.start is not None and info.t_max < sc.start:
                continue
            if sc.end is not None and info.t_min >= sc.end:
                continue
            return False
        return True
    return False


class ScenarioSuite:
    """Run a whole catalog of heterogeneous scenarios through ONE scheduler
    and score the results through the aggregation layer.

    Every shard of every scenario is partitioned independently (its own
    ``num_partitions`` per shard, default = ``num_workers``), all
    partitions are submitted up front, and the shared worker pool — thread
    or process backend — drains the matrix with the scheduler's full
    fault-tolerance/speculation semantics.  Shards whose topic filter /
    time window provably selects nothing are pruned at planning time.

    Aggregation is itself scheduled: the moment a scenario's last replay
    partition reports, its merge + metrics + golden-compare run as one
    ordinary task (lineage stage ``"aggregate"``) on the same pool,
    overlapping the other scenarios' remaining replay work instead of
    running serially on the driver after the drain.  Workers ship partial
    per-topic metrics (KBs) next to each partition image, so the metric
    stage is a pure combine — the driver never re-reads payload bytes,
    and per-task results are discarded as soon as they are consumed.

    ``run`` returns ``{scenario.name: Verdict}``: each verdict carries the
    golden-comparison outcome (or an unconditional pass when the scenario
    has no golden bag), per-topic metrics, and the full
    :class:`SimulationReport` — whose ``output_image`` is the merged,
    timestamp-ordered output of all shards, whose ``wall_time_s`` spans
    suite start to the scenario's last finished partition, and whose
    ``scheduler_stats`` are the shared pool's counters.

    Scenarios may be wired together through the **distributed message
    pool**: a scenario's ``exports`` topics feed every scenario that
    ``imports`` them.  The suite plans the routing graph (validated as a
    single-exporter DAG), and when a provider's last partition reports,
    its per-partition export streams — concatenated in deterministic
    (shard, partition) order and stably time-sorted — become the
    importer's *import partition*: one extra task replaying the merged
    stream through the importer's user logic, submitted the moment all
    of its providers are final.  ``export_transport`` picks the carrier:
    ``"inline"`` rides exports on task results, ``"wire"`` streams them
    over :mod:`repro.net` LaneTransports to a backend-hosted
    :class:`~repro.net.transport.RemoteBus` collector (with credit-based
    backpressure and drain barriers), ``"shm"`` is wire with the
    same-host shared-memory ring negotiated per stream (frames bypass
    the TCP stack; falls back to TCP framing when the handshake
    declines), and ``"auto"`` (default) routes out-of-band exactly where
    results would otherwise ride the process-backend pipe, preferring
    shm > wire.  Outputs, checksums and verdicts are bit-identical
    across carriers and backends — ``benchmarks/transport.py`` and
    ``benchmarks/shm.py`` assert it every run; each verdict records
    which carrier actually ran in ``Verdict.transport``.

    ``on_scheduler`` (if given) is called with the live Scheduler right
    after submission — the hook fault-injection harnesses use to kill
    workers / add elastic capacity mid-suite.  ``aggregator`` overrides
    the default exact-matching :class:`Aggregator`.

    ``run(verdict_log=path)`` additionally appends one JSONL record per
    scenario (name, verdict, metric checksums, timings) to ``path`` and
    rewrites a suite manifest (scenario → golden path → verdict) next to
    it — the CI-native face of the regression harness.

    ``run(cache=...)`` (a :class:`repro.cache.ResultCache` or a store
    root path) turns on the **content-addressed result cache**: at
    planning time each scenario's key — bag content digests + parameter
    fingerprint + logic version + kernel/interpret config + provider
    keys (ARCHITECTURE.md §9) — is probed against the store, and every
    hit is pruned from scheduling entirely: its verdict, metrics, merged
    output image and export stream rehydrate from the entry, so an
    unchanged suite re-run costs a digest sweep and a metadata read
    instead of a replay.  Misses replay normally and bank their outcome.
    Replay here is bit-identical across backends/carriers/shapes, which
    is what makes a cached result substitutable for a recomputed one;
    each verdict carries ``cache="hit"|"miss"`` provenance (persisted to
    the JSONL log and manifest), and ``last_cache_stats`` exposes the
    run's hit/miss/put counters.  Corrupt or truncated entries read as
    misses — the cache can cost a replay, never a suite.

    ``on_error`` picks the failure model (ARCHITECTURE.md §10).  The
    default ``"raise"`` keeps the historical semantics: the first
    perma-failed task fails the whole run.  ``"degrade"`` runs the
    scheduler in quarantine mode instead — a scenario whose partition
    (or aggregation) perma-fails degrades to a
    ``Verdict(status="ERROR")`` carrying the cause string, every
    scenario downstream of a failed *exporter* in the routing DAG gets
    an ERROR with the upstream lineage, and everything else completes
    bit-identically to a clean run.  ERROR verdicts are never banked in
    the result cache and ride into the verdict JSONL/manifest like any
    other status.
    """

    def __init__(self, scenarios: Sequence[Scenario], num_workers: int = 4,
                 backend: Union[str, ExecutorBackend] = "thread",
                 scheduler_kwargs: Optional[dict] = None,
                 on_scheduler: Optional[Callable[[Scheduler], None]] = None,
                 aggregator: Optional[Aggregator] = None,
                 export_transport: str = "auto",
                 on_error: str = "raise"):
        names = [s.name for s in scenarios]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate scenario names in {names}")
        if export_transport not in ("auto", "shm", "wire", "inline"):
            raise ValueError(f"unknown export_transport {export_transport!r}")
        if on_error not in ("raise", "degrade"):
            raise ValueError(f"unknown on_error {on_error!r}")
        self.scenarios = list(scenarios)
        self.num_workers = num_workers
        self.backend = backend
        self.scheduler_kwargs = scheduler_kwargs or {}
        self.on_scheduler = on_scheduler
        self.aggregator = aggregator or Aggregator()
        self.export_transport = export_transport
        self.on_error = on_error
        #: hit/miss/put counters of the last ``run(cache=...)``; None when
        #: the last run had no cache
        self.last_cache_stats: Optional[dict] = None

    def _plan_routing(self) -> tuple[list[set], list[set]]:
        """Resolve ``Scenario.exports``/``imports`` into the routing graph.

        Returns ``(needs, consumers)``: ``needs[i]`` is the set of
        scenario indices ``i`` imports from, ``consumers[j]`` the set fed
        by ``j``.  Validates that every imported topic has exactly one
        exporter, nothing self-imports, and the graph is a DAG — a cycle
        would deadlock the suite (each side waiting for the other's
        exports), so it fails at planning time instead.
        """
        providers: dict[str, int] = {}
        for i, sc in enumerate(self.scenarios):
            for t in sc.exports or ():
                if t in providers:
                    raise ValueError(
                        f"topic {t!r} exported by both "
                        f"{self.scenarios[providers[t]].name!r} and "
                        f"{sc.name!r}; each topic has one exporter")
                providers[t] = i
        needs: list[set] = [set() for _ in self.scenarios]
        consumers: list[set] = [set() for _ in self.scenarios]
        for i, sc in enumerate(self.scenarios):
            for t in sc.imports or ():
                j = providers.get(t)
                if j is None:
                    raise ValueError(f"scenario {sc.name!r} imports {t!r} "
                                     "which no scenario exports")
                if j == i:
                    raise ValueError(
                        f"scenario {sc.name!r} imports its own export {t!r}")
                needs[i].add(j)
                consumers[j].add(i)
        state = [0] * len(self.scenarios)     # 0 unseen / 1 visiting / 2 done

        def visit(i: int) -> None:
            if state[i] == 1:
                raise ValueError(
                    f"routing cycle through scenario "
                    f"{self.scenarios[i].name!r}: imports must form a DAG")
            if state[i]:
                return
            state[i] = 1
            for j in needs[i]:
                visit(j)
            state[i] = 2

        for i in range(len(self.scenarios)):
            visit(i)
        return needs, consumers

    def _resolve_export_transport(self, backend_name: str) -> str:
        """``"auto"`` routes exports out-of-band exactly where they would
        otherwise ride the task-result pipe (the process backend),
        preferring the same-host shm ring over loopback TCP when the host
        supports it (shm > wire > inline); in-process thread workers hand
        the driver a reference instead.  ``"shm"`` asks transports to
        negotiate the ring but still degrades per-stream to TCP framing
        when the handshake declines.  All shapes are bit-identical, so
        the choice is pure mechanics."""
        if self.export_transport != "auto":
            return self.export_transport
        if backend_name != "process":
            return "inline"
        return "shm" if shm_available() else "wire"

    def _plan_cache_keys(self, cache, needs: list[set]) -> list:
        """Per-scenario result-cache keys; ``None`` marks an uncacheable
        scenario (non-addressable user logic — or one anywhere upstream
        of it, since an importer's inputs include its providers' exports).

        Keys are pure functions of configuration and bag *content*:
        logic version + kernel/interpret config + aggregator tolerance +
        ``Scenario.fingerprint()`` + per-shard bag digests + the golden
        bag digest + (recursively) the providers' keys — so a change
        anywhere upstream in the routing DAG invalidates every scenario
        downstream.  Any I/O or digest failure degrades that scenario to
        uncacheable rather than failing the suite.
        """
        keys: list = [None] * len(self.scenarios)
        done = [False] * len(self.scenarios)

        def key_of(i: int):
            if done[i]:
                return keys[i]
            done[i] = True
            sc = self.scenarios[i]
            try:
                fp = sc.fingerprint()
                provider_keys = []
                for j in sorted(needs[i]):
                    kj = key_of(j)
                    if kj is None:
                        return None
                    provider_keys.append(kj)
                digests = [cache.bag_digest(p) for p in sc.shard_paths]
                golden = (cache.bag_digest(sc.golden_bag_path)
                          if sc.golden_bag_path is not None else None)
                keys[i] = cache.scenario_key(
                    fp, digests, golden, provider_keys,
                    tolerance=self.aggregator.tolerance)
            except (OSError, ValueError):
                keys[i] = None
            return keys[i]

        for i in range(len(self.scenarios)):
            key_of(i)
        return keys

    def _plan(self, sc: Scenario) -> list[tuple[int, str, tuple[int, int]]]:
        """One (shard index, shard path, chunk range) triple per task."""
        tasks: list[tuple[int, str, tuple[int, int]]] = []
        for si, shard in enumerate(sc.shard_paths):
            src = Bag.open_read(shard, backend="disk")
            if _selection_matches_nothing(src, sc):
                src.close()
                continue
            parts = partition_bag(src, sc.num_partitions or self.num_workers)
            src.close()
            tasks.extend((si, shard, pr) for pr in parts)
        return tasks

    @staticmethod
    def _resolve_metrics_engine(sc: Scenario, backend_name: str) -> str:
        """Pick the partition sink's digest engine.  Process workers are
        pinned to the fork-safe numpy engine (never init jax in a forked
        child of a jax-loaded driver); in-process, ``"auto"`` makes the
        fused Pallas consume step the stock batched shape and numpy the
        per-message one.  All engines are bit-identical, so this choice
        can never move a checksum or a verdict."""
        if backend_name == "process":
            return "numpy"
        if sc.metrics_engine == "auto":
            return "fused" if sc.batch_size is not None else "numpy"
        return sc.metrics_engine

    def run(self, timeout: float = 300.0,
            verdict_log: Optional[str] = None,
            manifest_path: Optional[str] = None,
            cache=None,
            trace: Optional[str] = None) -> dict[str, Verdict]:
        """Drive every scenario to a verdict (see class docstring).

        ``trace=<path>`` records the run with the :mod:`repro.obs`
        tracer and writes a Chrome/Perfetto-loadable ``trace.json`` to
        ``path`` when the suite finishes (also on failure — the flight
        recorder matters most when a run dies): one stitched timeline of
        driver and worker spans across scheduler, lanes, replay, logic,
        transport, shm and cache seams.  Per-scenario per-stage
        durations derived from the trace ride into the verdict JSONL,
        and a ``repro.obs.metrics`` snapshot into the manifest.
        """
        for sc in self.scenarios:
            # fail before burning replay time, not at aggregation
            if (sc.golden_bag_path is not None
                    and not os.path.exists(sc.golden_bag_path)):
                raise FileNotFoundError(
                    f"scenario {sc.name!r}: golden bag "
                    f"{sc.golden_bag_path!r} does not exist")
        plans = [(sc, self._plan(sc)) for sc in self.scenarios]
        needs, consumers = self._plan_routing()

        # -- flight recorder --------------------------------------------
        # own_trace: this run installed the tracer and tears it down; a
        # pre-enabled tracer (a benchmark harness) is borrowed instead.
        # Setup precedes the cache probe so cache.load spans are captured.
        own_trace = False
        suite_tracer: Optional[otrace.Tracer] = None
        suite_slot = None
        trace_out: dict = {}            # filled once by _finish_trace
        if trace is not None:
            own_trace = not otrace.enabled()
            if own_trace:
                otrace.enable(root_name="suite")
            suite_tracer = otrace.get_tracer()
            suite_slot = suite_tracer.begin(
                "suite.run", "suite",
                attrs={"scenarios": [sc.name for sc in self.scenarios]})
            suite_tracer.push(otrace.Tracer.span_id(suite_slot))

        def _finish_trace() -> None:
            # idempotent: the normal path calls it after the cache-put
            # sweep (so the stage breakdown rides into the verdict log);
            # the crash path reaches it from the finally below — a
            # partial trace is the whole point of a flight recorder
            nonlocal suite_tracer
            if suite_tracer is None:
                return
            tr, suite_tracer = suite_tracer, None
            from repro.obs import export as obs_export
            tr.pop()
            otrace.Tracer.end(suite_slot)
            records = tr.drain_all()
            trace_out["stages"] = obs_export.stage_breakdown(records)
            trace_out["spans"] = len(records)
            try:
                obs_export.write_trace(trace, records,
                                       driver_pid=os.getpid())
            finally:
                if own_trace:
                    otrace.disable()

        # -- result cache probe (the unchanged-suite hot path) ----------
        # a hit scenario contributes ZERO tasks: its verdict, metrics,
        # merged image and export stream rehydrate from the store, and
        # the suite only schedules what actually changed
        encode_stream = decode_stream = _CachedResult = None
        cache_keys: list = [None] * len(self.scenarios)
        cached: list = [None] * len(self.scenarios)
        if cache is not None:
            from repro.cache import CachedResult as _CachedResult
            from repro.cache import (ResultCache,
                                     decode_message_stream as decode_stream,
                                     encode_message_stream as encode_stream)
            if not isinstance(cache, ResultCache):
                cache = ResultCache(cache)
            cache_keys = self._plan_cache_keys(cache, needs)
            for i, key in enumerate(cache_keys):
                if key is None:
                    continue
                if not plans[i][1] and not needs[i]:
                    # pruned-empty scenario: the vacuous verdict is
                    # cheaper to recompute than to round-trip
                    cache_keys[i] = None
                    continue
                cached[i] = cache.load(
                    key, require_exports=bool(consumers[i]
                                              and self.scenarios[i].exports))
        self.last_cache_stats = None

        t0 = time.monotonic()
        # tid -> (scenario i, (shard j, partition k)) for result assembly;
        # an importing scenario's import partition carries key (-1, 0) so
        # the import-stream output merges first, deterministically
        owner: dict[int, tuple[int, tuple[int, int]]] = {}
        pending = [0 if cached[i] is not None
                   else len(tasks) + (1 if needs[i] else 0)
                   for i, (_, tasks) in enumerate(plans)]
        total_tasks = list(pending)
        # scenario i -> (shard, partition) -> (image, partial metrics);
        # released to the aggregation task as soon as the scenario drains
        parts: list[Optional[dict]] = [{} for _ in plans]
        counts = [[0, 0, 0] for _ in plans]      # in / out / dropped
        # degraded-mode failure ledger: cause string per errored scenario
        scn_error: list[Optional[str]] = [None] * len(plans)
        # export-carrier provenance per scenario ("shm"/"wire"/"inline";
        # None = exports nothing, or rehydrated from the result cache)
        scn_transport: list[Optional[str]] = [None] * len(plans)
        degrade = self.on_error == "degrade"
        sched_kwargs = dict(self.scheduler_kwargs)
        if degrade:
            # poison tasks surrender instead of failing the job; the
            # failure is delivered through on_task_failed below and the
            # scenario that owned it degrades to an ERROR verdict
            sched_kwargs.setdefault("quarantine", True)
        replay_end = [0.0 for _ in plans]        # last replay-task finish
        agg_owner: dict[int, int] = {}           # aggregation tid -> i
        agg_out: dict[int, tuple[bytes, Verdict]] = {}
        # every driver-side spill reference still live (temp-file path or
        # shm SegmentHandle); the finally sweep is the error-path cleanup,
        # per-completion reclaims the eager one
        tracked_spills: set = set()
        reclaim_holder: list[Callable] = []

        try:
            with Scheduler(num_workers=self.num_workers,
                           backend=self.backend,
                           **sched_kwargs) as sched:
                backend_name = sched.backend.name
                if backend_name == "process":
                    jitted = [sc.name for sc in self.scenarios
                              if isinstance(sc.user_logic, str)
                              and sc.user_logic.startswith("perception://")]
                    if jitted:
                        # forked workers must never initialise jax (the
                        # driver is jax-loaded; fork + XLA threads can
                        # deadlock) — fail loudly instead of hanging
                        raise ValueError(
                            f"scenarios {jitted} use perception:// logic, "
                            "which is jitted and cannot run on the process "
                            "backend; use the thread backend")
                pool_agg = self.aggregator
                if backend_name == "process" and pool_agg.engine != "numpy":
                    # never initialize jax inside a forked worker of a
                    # jax-loaded driver (deadlock risk) — the numpy engine
                    # is bit-identical, so the downgrade can't move a
                    # verdict
                    pool_agg = Aggregator(tolerance=pool_agg.tolerance,
                                          metric_batch=pool_agg.metric_batch,
                                          engine="numpy")

                # spill-aware dispatch: on backends with an argument spill
                # (process), large partition images / import streams are
                # parked out-of-band and tasks get references — a shm
                # SegmentHandle (one memcpy each way) or a temp-file path
                # (streaming disk readers) — so the driver never pickles
                # bulk bytes through the pipe
                spill_arg = getattr(sched.backend, "spill_arg", None)
                spill_bytes = getattr(sched.backend, "spill_bytes", None)
                reclaim = getattr(sched.backend, "reclaim_spill", None)
                if reclaim is not None:
                    reclaim_holder.append(reclaim)

                def spill_source(data: bytes
                                 ) -> "bytes | str | SegmentHandle":
                    if (spill_arg is None or spill_bytes is None
                            or len(data) <= spill_bytes):
                        return data
                    path = spill_arg(data)
                    tracked_spills.add(path)
                    return path

                def reclaim_paths(paths) -> None:
                    for p in paths:
                        tracked_spills.discard(p)
                        if reclaim is not None:
                            reclaim(p)

                # -- export routing state -------------------------------
                resolved_transport = \
                    self._resolve_export_transport(backend_name)
                wire = (resolved_transport in ("wire", "shm")
                        and any(consumers))
                use_shm = resolved_transport == "shm"
                collect_lock = threading.Lock()
                # (scenario i, partition key) -> committed export stream
                collected: dict[tuple[int, tuple[int, int]],
                                list[Message]] = {}
                stream_key: dict[str, tuple[int, tuple[int, int]]] = {}
                ep_addr: Optional[tuple[str, int]] = None
                if wire:
                    # the backend hosts the listener; partitions bridge
                    # their exported topics here over LaneTransports.
                    # Streams commit at each DRAIN barrier, which the
                    # partition passes before reporting — so a committed
                    # stream is always complete, and a crashed attempt's
                    # partial stream is never committed (its retry's is)
                    def export_sink(stream_id: str, msgs) -> None:
                        with collect_lock:
                            collected[stream_key[stream_id]] = list(msgs)
                    ep_addr = sched.backend.host_endpoint(sink=export_sink)
                    # the endpoint just hosted: its stream_carriers map is
                    # the transport-provenance source of truth per stream
                    ep_obj = sched.backend.endpoints[-1]
                # scenario i -> partition keys expected to export
                export_keys: dict[int, list[tuple[int, int]]] = {}
                exports_inline: dict[tuple[int, tuple[int, int]],
                                     list[Message]] = {}
                exports_of: dict[int, list[Message]] = {}
                # cache-hit importers never submit an import partition;
                # seeding them here also lets providers release streams
                # once every *live* importer has consumed
                submitted_imports: set = {i for i in range(len(plans))
                                          if cached[i] is not None}
                # encoded export streams captured for store writes
                export_snaps: dict[int, bytes] = {}
                agg_spills: dict[int, list[str]] = {}
                spill_by_tid: dict[int, list[str]] = {}

                def register_export_stream(i: int, key: tuple[int, int],
                                           ) -> tuple[Optional[tuple],
                                                      bool]:
                    """(export_to, collect_exports) for one partition of
                    an exporting scenario, registering its stream id."""
                    export_keys.setdefault(i, []).append(key)
                    if not wire:
                        return None, True
                    sid = f"{plans[i][0].name}#{key[0]}#{key[1]}"
                    stream_key[sid] = (i, key)
                    return (ep_addr[0], ep_addr[1], sid, use_shm), False

                def submit_aggregate(i: int) -> None:
                    sc = plans[i][0]
                    rows = parts[i]
                    ordered = sorted(rows)   # (shard, partition): merge
                    sources = [spill_source(rows[k][0])  # deterministic
                               for k in ordered]
                    partials = [rows[k][1] for k in ordered]
                    agg_spills[i] = [s for s in sources
                                     if isinstance(s, (str, SegmentHandle))]
                    tid = sched.submit(
                        _run_scenario_aggregate, pool_agg, sc.name,
                        sources, partials, sc.golden_bag_path,
                        counts[i][0], lineage=("aggregate", sc.name))
                    agg_owner[tid] = i
                    parts[i] = None          # driver drops its references

                def collect_export_stream(j: int) -> list[Message]:
                    """The scenario's full export stream: per-partition
                    streams concatenated in deterministic (shard,
                    partition) order, then stably time-sorted — identical
                    whichever transport shape carried them."""
                    msgs: list[Message] = []
                    for key in sorted(export_keys.get(j, [])):
                        if wire:
                            with collect_lock:
                                msgs.extend(collected.pop((j, key), ()))
                        else:
                            msgs.extend(exports_inline.pop((j, key), ()))
                    msgs.sort(key=lambda m: m.timestamp)
                    return msgs

                def finish_exports(j: int) -> None:
                    exports_of[j] = collect_export_stream(j)
                    if cache_keys[j] is not None:
                        # snapshot before importers consume + release: the
                        # store entry must carry the committed stream so a
                        # future importer downstream of this (cached)
                        # exporter can still replay
                        export_snaps[j] = encode_stream(exports_of[j])
                    for i in sorted(consumers[j]):
                        maybe_submit_import(i)

                def maybe_submit_import(i: int) -> None:
                    """Submit scenario i's import partition once every
                    provider's export stream is final."""
                    if i in submitted_imports:
                        return
                    if any(j not in exports_of for j in needs[i]):
                        return
                    submitted_imports.add(i)
                    sc = plans[i][0]
                    want = set(sc.imports or ())
                    msgs = [m for j in sorted(needs[i])
                            for m in exports_of[j] if m.topic in want]
                    msgs.sort(key=lambda m: m.timestamp)    # stable merge
                    cache = Bag.open_write(backend="memory")
                    for m in msgs:
                        cache.write_message(m)
                    cache.close()
                    source = spill_source(cache.chunked_file.image())
                    engine = self._resolve_metrics_engine(sc, backend_name)
                    key = (-1, 0)
                    export_to, collect = ((None, False) if not consumers[i]
                                          else register_export_stream(i,
                                                                      key))
                    tid = sched.submit(
                        _run_scenario_partition, sc, source, None, engine,
                        export_to, f"<imports:{sc.name}>", collect,
                        lineage=("scenario", sc.name, -1, "<imports>",
                                 0, 0))
                    owner[tid] = (i, key)
                    if isinstance(source, (str, SegmentHandle)):
                        spill_by_tid[tid] = [source]
                    # release provider streams every importer has now
                    # consumed — driver residency stays O(in-flight
                    # routing), matching the parts[i]=None discipline
                    for j in sorted(needs[i]):
                        if consumers[j] <= submitted_imports:
                            exports_of[j] = []

                def fail_scenario(i: int, cause: str) -> None:
                    """Degrade scenario i to ERROR and cascade through the
                    routing DAG: an importer of a failed exporter can never
                    see a complete input stream, so it errors too (with the
                    upstream lineage in its cause).  Cache-hit consumers
                    are immune — they rehydrate, they never replay."""
                    if scn_error[i] is not None:
                        return
                    scn_error[i] = cause
                    parts[i] = None          # drop partial partition images
                    reclaim_paths(agg_spills.pop(i, ()))
                    # a failed scenario never submits its import partition;
                    # marking it "submitted" also lets providers release
                    # streams no live importer is still waiting on
                    submitted_imports.add(i)
                    name = plans[i][0].name
                    for c in sorted(consumers[i]):
                        if cached[c] is not None:
                            continue
                        fail_scenario(
                            c, f"upstream scenario {name!r} errored: "
                               f"{cause}")

                def on_task_failed(tid: int, error) -> None:
                    # quarantine delivery: a task burned max_attempts.
                    # Replay-partition failures poison the whole scenario
                    # (and its DAG downstream); an aggregation failure
                    # degrades only its own verdict — the exports were
                    # committed at the drain barrier before the aggregate
                    # was even submitted, so downstream inputs are sound.
                    reclaim_paths(spill_by_tid.pop(tid, ()))
                    sched.discard(tid)
                    if tid in owner:
                        i, _key = owner[tid]
                        fail_scenario(i, str(error))
                    else:
                        i = agg_owner[tid]
                        reclaim_paths(agg_spills.pop(i, ()))
                        if scn_error[i] is None:
                            scn_error[i] = str(error)

                def on_task_done(tid: int, result) -> None:
                    if tid in owner:
                        i, key = owner[tid]
                        if scn_error[i] is not None:
                            # straggler partition of an already-degraded
                            # scenario: release and forget
                            sched.discard(tid)
                            reclaim_paths(spill_by_tid.pop(tid, ()))
                            return
                        n_in, n_out, n_drop, image, partial, exported = \
                            result
                        counts[i][0] += n_in
                        counts[i][1] += n_out
                        counts[i][2] += n_drop
                        parts[i][key] = (image, partial)
                        if consumers[i] and not wire:
                            exports_inline[(i, key)] = exported or []
                        end = sched.task_finished_at(tid)
                        if end is not None:
                            replay_end[i] = max(replay_end[i], end)
                        sched.discard(tid)
                        reclaim_paths(spill_by_tid.pop(tid, ()))
                        pending[i] -= 1
                        if pending[i] == 0:
                            # the scenario's last partition just reported:
                            # its aggregation overlaps the other
                            # scenarios' remaining replay work on the
                            # same pool, and its export stream is final —
                            # importers waiting on it can now be planned
                            submit_aggregate(i)
                            if consumers[i]:
                                finish_exports(i)
                    else:
                        i = agg_owner[tid]
                        agg_out[i] = result
                        sched.discard(tid)
                        reclaim_paths(agg_spills.pop(i, ()))

                for i, (sc, tasks) in enumerate(plans):
                    if cached[i] is not None:
                        continue        # rehydrated: no replay tasks at all
                    engine = self._resolve_metrics_engine(sc, backend_name)
                    exporting = bool(consumers[i])
                    part_of_shard: dict[int, int] = {}
                    for si, shard, (lo, hi) in tasks:
                        k = part_of_shard.get(si, 0)
                        part_of_shard[si] = k + 1
                        export_to, collect = ((None, False) if not exporting
                                              else register_export_stream(
                                                  i, (si, k)))
                        tid = sched.submit(
                            _run_scenario_partition, sc, shard, (lo, hi),
                            engine, export_to, None, collect,
                            lineage=("scenario", sc.name, si, shard,
                                     lo, hi))
                        owner[tid] = (i, (si, k))
                # a cache-hit exporter's stream is final at t0: decode it
                # from the store entry and unblock live importers now —
                # this is how a changed importer replays bit-identically
                # downstream of an *unchanged, never-replayed* provider
                for j in range(len(plans)):
                    if cached[j] is None or not consumers[j]:
                        continue
                    if any(cached[c] is None for c in consumers[j]):
                        exports_of[j] = decode_stream(cached[j].export_image)
                        for i in sorted(consumers[j]):
                            maybe_submit_import(i)
                # a pruned-empty exporter produces no tasks, so its
                # (empty) export stream is final now — unblock importers
                # before the run, not never
                for j in range(len(plans)):
                    if (cached[j] is None and consumers[j]
                            and not plans[j][1] and not needs[j]):
                        finish_exports(j)
                if self.on_scheduler is not None:
                    self.on_scheduler(sched)
                sched.run(timeout=timeout, on_task_done=on_task_done,
                          on_task_failed=(on_task_failed if degrade
                                          else None))
                stats = dict(sched.stats)
                # transport provenance, read before the endpoint stops:
                # a wire-mode exporter's streams each negotiated a
                # carrier at HELLO ("shm" only after a ring switch), and
                # a scenario is "shm" only if every stream made the
                # switch — a mixed outcome is reported as the weaker
                # carrier rather than overstated
                for i in range(len(plans)):
                    if not consumers[i] or cached[i] is not None:
                        continue
                    if not wire:
                        scn_transport[i] = "inline"
                        continue
                    got = [c for c in (
                        ep_obj.stream_carriers.get(
                            f"{plans[i][0].name}#{k[0]}#{k[1]}")
                        for k in export_keys.get(i, ())) if c is not None]
                    if got:
                        scn_transport[i] = ("shm" if all(c == "shm"
                                                         for c in got)
                                            else "wire")
        finally:
            # error-path spill cleanup: a failed suite must not leave
            # parked images/import streams behind (the backend's
            # shutdown-time directory reap is the backstop when the
            # scheduler owned the spill dir)
            if tracked_spills and reclaim_holder:
                for p in list(tracked_spills):
                    reclaim_holder[0](p)
            if sys.exc_info()[0] is not None:
                # an exception is propagating: write the partial trace
                # now (the normal-path finalize below is unreachable)
                _finish_trace()

        verdicts: dict[str, Verdict] = {}
        for i, (sc, tasks) in enumerate(plans):
            if cached[i] is not None:
                # cache hit: the whole scenario — verdict, diffs, metrics
                # (with their timestamp multisets), merged output image —
                # rehydrates from the store; replay never ran, so the
                # reported wall time is the metadata read (~0)
                ent = cached[i]
                verdict = Verdict(
                    scenario=sc.name, passed=ent.passed,
                    vacuous=ent.vacuous, diffs=ent.rebuild_diffs(),
                    metrics=ent.metrics, golden_path=sc.golden_bag_path,
                    cache="hit")
                image = ent.output_image
                n_in, n_out, n_drop = (ent.messages_in, ent.messages_out,
                                       ent.messages_dropped)
                n_parts, wall = ent.partitions, 0.0
            elif scn_error[i] is not None:
                # degraded: the scenario never produced comparable
                # outputs, so neither PASS nor FAIL is honest — an ERROR
                # verdict carries the cause lineage and an empty output
                # image, and is never banked in the result cache
                empty = Bag.open_write(backend="memory")
                empty.close()
                image = empty.chunked_file.image()
                verdict = Verdict(
                    scenario=sc.name, passed=False, error=scn_error[i],
                    golden_path=sc.golden_bag_path,
                    cache="miss" if cache is not None else None)
                n_in, n_out, n_drop = counts[i]
                n_parts = total_tasks[i]
                wall = (replay_end[i] - t0) if replay_end[i] else 0.0
            else:
                if tasks or needs[i]:
                    image, verdict = agg_out[i]
                else:
                    # pruned-empty scenario: a clean zero-message vacuous
                    # verdict, no tasks burned on the pool
                    merged, verdict = self.aggregator.aggregate(
                        sc.name, [], golden=sc.golden_bag_path,
                        messages_in=0)
                    image = merged.chunked_file.image()
                    merged.close()
                if cache is not None:
                    verdict.cache = "miss"
                n_in, n_out, n_drop = counts[i]
                n_parts = total_tasks[i]
                wall = (replay_end[i] - t0) if replay_end[i] else 0.0
            verdict.transport = scn_transport[i]
            report = SimulationReport(
                messages_in=n_in,
                messages_out=n_out,
                wall_time_s=wall,
                partitions=n_parts,
                scheduler_stats=stats,
                scenario=sc.name,
                backend=backend_name,
                batch_size=sc.batch_size,
                messages_dropped=n_drop,
                shards=len(sc.shard_paths),
                output_image=image,
                metrics=verdict.metrics,
            )
            verdict.report = report
            verdicts[sc.name] = verdict
            if (cache is not None and cache_keys[i] is not None
                    and cached[i] is None and scn_error[i] is None):
                # freshly computed + content-addressable: bank it (a
                # failed write costs coverage, never the suite)
                cache.put(cache_keys[i], _CachedResult(
                    scenario=sc.name, passed=verdict.passed,
                    vacuous=verdict.vacuous,
                    diffs=[{"topic": d.topic, "field": d.field,
                            "expected": d.expected, "actual": d.actual,
                            "detail": d.detail} for d in verdict.diffs],
                    metrics=verdict.metrics, output_image=image,
                    export_image=export_snaps.get(i),
                    messages_in=n_in, messages_out=n_out,
                    messages_dropped=n_drop, partitions=n_parts,
                    shards=len(sc.shard_paths), wall_time_s=wall))
        if cache is not None:
            self.last_cache_stats = dict(cache.stats)
        _finish_trace()
        if verdict_log is not None:
            self._persist_verdicts(verdict_log, manifest_path, verdicts,
                                   backend_name,
                                   stages=trace_out.get("stages"),
                                   metrics_snapshot=obs_metrics.snapshot())
        return verdicts

    @staticmethod
    def _persist_verdicts(verdict_log: str, manifest_path: Optional[str],
                          verdicts: dict[str, Verdict],
                          backend_name: str, *,
                          stages: Optional[dict] = None,
                          metrics_snapshot: Optional[dict] = None) -> None:
        """Append one JSONL record per scenario to ``verdict_log`` and
        rewrite the suite manifest (scenario → golden path → verdict).

        The log is append-only — consecutive suite runs accumulate a
        verdict history a CI job can diff or trend; the manifest
        (``manifest_path``, default ``<verdict_log>.manifest.json``) is
        the current snapshot a gate inspects without parsing history.
        Metric checksums ride along so a PASS can additionally be pinned
        bit-exactly across runs.  A traced run adds per-scenario
        ``stages`` (stage → busy ns, from the span timeline) to each
        record — what ``verdict_report`` trends — and every run embeds
        the ``repro.obs.metrics`` snapshot in the manifest.
        """
        now = time.time()
        records = []
        for name, v in verdicts.items():
            r = v.report
            rec = {
                "scenario": name,
                "status": v.status,
                "passed": v.passed,
                "vacuous": v.vacuous,
                "golden": v.golden_path,
                "diffs": [str(d) for d in v.diffs],
                "checksums": {t: m.checksum for t, m in v.metrics.items()},
                "messages_in": r.messages_in,
                "messages_out": r.messages_out,
                "messages_dropped": r.messages_dropped,
                "wall_time_s": r.wall_time_s,
                "partitions": r.partitions,
                "shards": r.shards,
                "backend": backend_name,
                "cache": v.cache,
                "transport": v.transport,
                "error": v.error,
                "unix_time": now,
            }
            if stages is not None:
                rec["stages"] = stages.get(name)
            records.append(rec)
        with open(verdict_log, "a") as f:
            for rec in records:
                f.write(json.dumps(rec, sort_keys=True) + "\n")
        manifest = {
            "verdict_log": os.path.abspath(verdict_log),
            "backend": backend_name,
            "unix_time": now,
            "passed": all(r["passed"] for r in records),
            "scenarios": {
                r["scenario"]: {"golden": r["golden"],
                                "status": r["status"],
                                "passed": r["passed"],
                                "cache": r["cache"],
                                "transport": r["transport"]}
                for r in records
            },
        }
        if metrics_snapshot is not None:
            manifest["metrics"] = metrics_snapshot
        mpath = manifest_path or verdict_log + ".manifest.json"
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)
            f.write("\n")


class DistributedSimulation:
    """Partition a recorded bag across a worker pool and replay it through
    user logic — the full platform of the paper, minus the physical cluster.

    Now a thin wrapper over a one-scenario :class:`ScenarioSuite`; prefer
    the suite API for anything beyond a single homogeneous replay.
    """

    def __init__(self, bag_path: str, user_logic: LogicRef,
                 num_workers: int = 4, num_partitions: Optional[int] = None,
                 use_memory_cache: bool = True,
                 latency_model_s: float = 0.0,
                 batch_size: Optional[int] = None,
                 backend: Union[str, ExecutorBackend] = "thread",
                 scheduler_kwargs: Optional[dict] = None):
        self.scenario = Scenario(
            name="sim", bag_path=bag_path, user_logic=user_logic,
            latency_model_s=latency_model_s, batch_size=batch_size,
            num_partitions=num_partitions or num_workers,
            use_memory_cache=use_memory_cache)
        self.num_workers = num_workers
        self.backend = backend
        self.scheduler_kwargs = scheduler_kwargs or {}

    @property
    def bag_path(self) -> str:
        return self.scenario.bag_path

    @property
    def user_logic(self) -> LogicRef:
        return self.scenario.user_logic

    def run(self, timeout: float = 300.0) -> SimulationReport:
        suite = ScenarioSuite([self.scenario], num_workers=self.num_workers,
                              backend=self.backend,
                              scheduler_kwargs=self.scheduler_kwargs)
        return suite.run(timeout=timeout)[self.scenario.name].report


def bag_to_partitions(bag_path: str, num_partitions: int,
                      topics: Optional[Sequence[str]] = None,
                      ) -> list[BinaryPartition]:
    """Export a bag as BinPipedRDD-style binary partitions (encode stage of
    Fig 4): each record becomes the uniform format [topic, timestamp, data].
    """
    bag = Bag.open_read(bag_path, backend="disk")
    parts = partition_bag(bag, num_partitions)
    out = []
    for lo, hi in parts:
        records = [encode([m.topic, m.timestamp, m.data])
                   for m in bag.read_messages(topics=topics,
                                              chunk_range=(lo, hi))]
        out.append(BinaryPartition(records,
                                   lineage=("bag", bag_path, lo, hi)))
    bag.close()
    return out
