"""The scenario engine: distributed simulation over a suite of scenarios
(paper Fig 3 + Fig 5 workflow, generalized from "replay one bag" to "run a
test matrix over a drive fleet").

    Scenario catalog --ScenarioSuite--> Scheduler/ExecutorBackend
        --RosPlay--> MessageBus --User Logic--> RosRecord --> Bag
        --Aggregator--> merged Bag + metrics --> Verdict

A :class:`Scenario` describes one functional/performance test: one bag
(``bag_path``) or a sharded fleet of bags (``bag_paths``), a topic filter,
a time window, a latency/fault profile, a user-logic ref and an optional
golden bag.  A :class:`ScenarioSuite` fans every partition of every shard
of every scenario through ONE scheduler (thread or process backend), then
hands each scenario's partition outputs to the aggregation layer
(:mod:`repro.core.aggregation`): shard outputs are k-way merged into one
timestamp-ordered bag, per-topic metrics are computed, golden bags are
compared, and ``run`` returns per-scenario :class:`Verdict`\\ s — the
paper's "massive test suites over a shared cluster", scored.

Per the paper: "Each Spark worker first reads the Rosbag data into memory
and then launches a ROS node to process the incoming data."  Here each task:

1. reads its chunk-range partition from the source bag (applying the
   scenario's topic filter and time window),
2. copies it into a ``MemoryChunkedFile``-backed bag (the ROSBag cache —
   this is the I/O optimisation §4.1 measures),
3. replays it through the user logic attached to the bus — per message, or
   in timestamp-ordered micro-batches when ``Scenario.batch_size`` is set
   (``RosPlay.run_batched`` -> ``MessageBus.publish_batch``), so the logic
   can be a jitted array step over assembled batches
   (:func:`repro.data.pipeline.assemble_message_batch` +
   :func:`repro.kernels.sensor_decode.sensor_decode`),
4. records outputs into a memory bag and ships its image plus KB-sized
   partial per-topic metrics (a streaming :class:`MetricsTap` on the sink
   side — fork-safe numpy digests on process workers, the fused Pallas
   consume step for batched in-process scenarios) as the task result;
   per-scenario aggregation then runs as its own scheduled task
   (lineage stage ``"aggregate"``), overlapping remaining replay work.
   Latency-modeling scenarios replay as a staged read → logic → record
   pipeline over queued bus lanes (``Scenario.pipeline``), overlapping
   disk I/O, compute and bag serialization inside each task.

``user_logic`` contracts:
  per-message : ``Message -> Optional[(topic, bytes)]`` (output inherits the
                input timestamp — the seed contract),
  batched     : ``list[Message] -> Optional[iterable[(topic, ts, bytes)]]``.
Either may be given as a ``"module:attr"`` string ref, resolved inside the
worker — required for the process backend, where the callable must cross a
pickle boundary.
"""

from __future__ import annotations

import importlib
import json
import os
import random
import time
import zlib
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence, Union

from .aggregation import Aggregator, MetricsTap, TopicMetrics, Verdict
from .bag import Bag, Message, partition_bag
from .binpipe import BinaryPartition, encode
from .executors import ExecutorBackend
from .playback import MESSAGE_PREFETCH, MessageBus, RosPlay, RosRecord
from .scheduler import Scheduler

UserLogic = Callable[[Message], Optional[tuple[str, bytes]]]
BatchUserLogic = Callable[[Sequence[Message]],
                          Optional[Sequence[tuple[str, int, bytes]]]]
LogicRef = Union[UserLogic, BatchUserLogic, str]


def resolve_logic_ref(ref: LogicRef) -> Callable:
    """Resolve a ``"package.module:attr"`` string ref to the callable it
    names; callables pass through.  String refs are what a process-backend
    scenario ships across the pickle boundary."""
    if callable(ref):
        return ref
    mod_name, _, attr = str(ref).partition(":")
    if not attr:
        raise ValueError(f"logic ref {ref!r} is not 'module:attr'")
    fn = getattr(importlib.import_module(mod_name), attr)
    if not callable(fn):
        raise TypeError(f"logic ref {ref!r} resolved to non-callable {fn!r}")
    return fn


@dataclass(frozen=True)
class Scenario:
    """One entry of the test matrix.

    The bag source is either ``bag_path`` (one recorded drive) or
    ``bag_paths`` (a sharded fleet — one bag per vehicle/segment); exactly
    one must be given.  Every shard is partitioned, replayed and recorded
    independently; the aggregation layer merges the shard outputs back
    into one timestamp-ordered result bag.  ``num_partitions`` is
    *per shard*.

    ``batch_size=None`` replays per message (seed behaviour); an integer
    switches to batched replay and the batched user-logic contract.
    ``drop_rate`` is the fault profile: that fraction of input messages is
    dropped (deterministically, per ``seed``) before reaching user logic —
    simulated sensor dropouts.  ``latency_model_s`` sleeps once per user
    logic invocation (per message, or per batch — batching amortizes it,
    like a real accelerator-offloaded model step).

    ``golden_bag_path`` names a recorded expected-output bag; the
    aggregator diffs the merged output against it (exact or
    tolerance-based, see :class:`repro.core.aggregation.Aggregator`) and
    the scenario's verdict fails on any mismatch.

    ``pipeline`` selects the partition replay shape: ``True`` is the
    staged read → logic → record pipeline over queue-backed bus
    subscriptions (disk I/O, user logic and bag serialization overlap),
    ``False`` the synchronous seed shape, and ``None`` (default) resolves
    automatically — staged when the scenario models per-invocation
    compute latency (``latency_model_s > 0``, the regime where the logic
    stage yields and overlap wins), synchronous for free-running logic
    where queue handoffs would only tax the hot loop.  Outputs, metrics
    and verdicts are bit-identical either way, so the switch is purely a
    performance choice.  ``queue_depth`` bounds each pipeline stage's
    FIFO (the backpressure window).  ``metrics_engine`` picks the
    sink-stage digest reduction
    (:class:`repro.core.aggregation.MetricsTap`): ``"auto"`` resolves to
    the fused Pallas consume step for batched in-process scenarios and the
    fork-safe numpy engine otherwise (process workers never init jax).
    """
    name: str
    bag_path: Optional[str] = None
    user_logic: LogicRef = None
    topics: Optional[tuple[str, ...]] = None
    start: Optional[int] = None          # time window, ns (inclusive)
    end: Optional[int] = None            # time window, ns (exclusive)
    latency_model_s: float = 0.0
    drop_rate: float = 0.0
    seed: int = 0
    batch_size: Optional[int] = None
    num_partitions: Optional[int] = None
    use_memory_cache: bool = True
    bag_paths: Optional[tuple[str, ...]] = None   # fleet shards
    golden_bag_path: Optional[str] = None
    pipeline: Optional[bool] = None      # None = auto (see docstring)
    queue_depth: int = 8
    metrics_engine: str = "auto"

    def __post_init__(self):
        if self.user_logic is None:
            raise ValueError(f"scenario {self.name!r} has no user_logic")
        if self.metrics_engine not in ("auto", "numpy", "jax", "fused"):
            raise ValueError(f"scenario {self.name!r}: unknown "
                             f"metrics_engine {self.metrics_engine!r}")
        if self.queue_depth < 1:
            raise ValueError(f"scenario {self.name!r}: queue_depth >= 1")
        if (self.bag_path is None) == (self.bag_paths is None):
            raise ValueError(f"scenario {self.name!r}: give exactly one of "
                             "bag_path / bag_paths")
        if self.bag_paths is not None and not isinstance(self.bag_paths,
                                                         tuple):
            object.__setattr__(self, "bag_paths", tuple(self.bag_paths))

    @property
    def shard_paths(self) -> tuple[str, ...]:
        """The fleet as a tuple of bag paths (length 1 for ``bag_path``)."""
        return ((self.bag_path,) if self.bag_path is not None
                else self.bag_paths)

    @property
    def staged(self) -> bool:
        """The resolved replay shape: explicit ``pipeline`` wins; auto
        (``None``) stages exactly the latency-modeling scenarios, where
        the logic stage sleeps/offloads and overlap pays — free-running
        logic keeps the zero-handoff synchronous hot loop."""
        if self.pipeline is not None:
            return self.pipeline
        return self.latency_model_s > 0


@dataclass
class SimulationReport:
    """Per-scenario replay outcome, post-aggregation.

    ``output_image`` is the merged, timestamp-ordered output bag (all
    shards, all partitions — one image), and ``metrics`` the per-topic
    :class:`TopicMetrics` the aggregator computed over it.  The seed-era
    per-partition image list (``partition_images`` / the deprecated
    ``output_images`` accessor) is gone: the driver holds exactly one
    merged image per scenario.
    """
    messages_in: int
    messages_out: int
    wall_time_s: float
    partitions: int
    scheduler_stats: dict
    scenario: str = ""
    backend: str = ""
    batch_size: Optional[int] = None
    messages_dropped: int = 0
    shards: int = 1
    output_image: Optional[bytes] = None     # merged output bag image
    metrics: dict[str, TopicMetrics] = field(default_factory=dict)

    @property
    def throughput_msgs_s(self) -> float:
        return self.messages_in / self.wall_time_s if self.wall_time_s else 0.0

    def open_output_bag(self) -> Bag:
        """The merged output as a readable memory bag."""
        if self.output_image is None:
            raise ValueError("report has no merged output image")
        return Bag.open_read(backend="memory", image=self.output_image)


def _run_scenario_partition(scenario: Scenario, shard_path: str,
                            chunk_range: tuple[int, int],
                            metrics_engine: str = "numpy",
                            ) -> tuple[int, int, int, bytes, dict]:
    """One worker task: play one shard partition through the user logic.

    With ``scenario.staged`` (explicit ``pipeline=True``, or auto for
    latency-modeling scenarios) the partition runs as a three-stage
    pipeline over queue-backed bus subscriptions:

        read stage    — a prefetch reader thread decodes bag chunks and
                        keeps messages/micro-batches buffered ahead,
        logic stage   — fault profile + user logic on its own lane worker
                        (one lane shared across input topics, so the
                        drop-RNG draw order is exactly the publish order),
        sink stage    — ``RosRecord`` (bag serialization) and a
                        :class:`MetricsTap` (per-record digests) each on
                        their own lane.

    Disk I/O, XLA compute and bag serialization overlap instead of
    alternating; bounded lanes give backpressure; ``bus.drain()`` is the
    end-of-replay barrier that makes the overlap invisible to results.
    ``pipeline=False`` delivers every stage synchronously (the seed
    shape).  Both shapes produce bit-identical outputs and partials.

    Returns (messages_in, messages_out, messages_dropped, output bag image,
    partial metrics).  The partial metrics — per-topic mergeable
    :class:`TopicMetrics` over this partition's *output* — are computed
    here, on the worker, *as outputs stream through the sink stage*: the
    driver combines KB-sized partials instead of re-reading MB-sized
    payload matrices, and the worker no longer re-sweeps its own output
    image at end of task.
    """
    logic = resolve_logic_ref(scenario.user_logic)
    topics = list(scenario.topics) if scenario.topics is not None else None
    src = Bag.open_read(shard_path, backend="disk")
    if scenario.use_memory_cache:
        # materialise the (filtered) partition into the ROSBag cache (§3.2):
        cache = Bag.open_write(backend="memory")
        for msg in src.read_messages(topics=topics, start=scenario.start,
                                     end=scenario.end,
                                     chunk_range=chunk_range):
            cache.write_message(msg)
        cache.close()
        play_bag = Bag.open_read(backend="memory",
                                 image=cache.chunked_file.image())
        play = dict(chunk_range=None, topics=None, start=None, end=None)
        input_topics = play_bag.topics
    else:
        play_bag = src
        play = dict(chunk_range=chunk_range, topics=topics,
                    start=scenario.start, end=scenario.end)
        input_topics = ([t for t in src.topics if t in topics]
                        if topics is not None else src.topics)

    staged = scenario.staged
    mode = "queued" if staged else "sync"
    depth = scenario.queue_depth
    bus = MessageBus()
    out_bag = Bag.open_write(backend="memory")
    # record everything the user logic publishes, but not the replayed
    # inputs; in batched mode the recorder rides the batch subscription so
    # no per-message callback remains on the replay hot path
    rec = RosRecord(bus, out_bag, topics=None, exclude_topics=src.topics,
                    batch=scenario.batch_size is not None,
                    mode=mode, queue_maxsize=depth)
    # metrics ride the sink stage: per-record digests accumulate as outputs
    # stream past, so partials are ready at drain (no output-image re-sweep);
    # input-topic exclusion is enforced bus-side (sink_kw below)
    tap = MetricsTap(engine=metrics_engine)

    n_out = 0
    n_drop = 0
    # deterministic fault profile, decorrelated across shards + partitions
    # (crc32, not hash(): str hashing is per-process randomized)
    rng = random.Random(scenario.seed * 1_000_003
                        + zlib.crc32(shard_path.encode()) * 131
                        + chunk_range[0] * 8191 + chunk_range[1])
    drop = scenario.drop_rate

    # one shared "logic" lane across all input topics: the drop-RNG draw
    # order (and hence the output stream) is exactly the synchronous one.
    # The tap excludes input topics bus-side, so replay traffic is never
    # even enqueued toward the metrics sink.
    logic_kw = dict(mode=mode, maxsize=depth, group="logic")
    sink_kw = dict(mode=mode, maxsize=depth, group="metrics",
                   exclude_topics=src.topics)
    if scenario.batch_size is None:
        def on_msg(msg: Message) -> None:
            nonlocal n_out, n_drop
            if drop and rng.random() < drop:
                n_drop += 1
                return
            if scenario.latency_model_s:
                time.sleep(scenario.latency_model_s)  # simulated perception
            out = logic(msg)
            if out is not None:
                topic, data = out
                bus.advertise(topic).publish(msg.timestamp, data)
                n_out += 1

        for t in input_topics:
            bus.subscribe(t, on_msg, **logic_kw)
        bus.subscribe(None, tap.on_message, **sink_kw)
    else:
        def on_batch(msgs: list[Message]) -> None:
            nonlocal n_out, n_drop
            if drop:
                kept = [m for m in msgs if rng.random() >= drop]
                n_drop += len(msgs) - len(kept)
                msgs = kept
                if not msgs:
                    return
            if scenario.latency_model_s:
                time.sleep(scenario.latency_model_s)  # one model step/batch
            outs = logic(msgs)
            if outs:
                out_msgs = [Message(t, ts, d) for t, ts, d in outs]
                bus.publish_batch(out_msgs)
                n_out += len(out_msgs)

        for t in input_topics:
            bus.subscribe_batch(t, on_batch, **logic_kw)
        bus.subscribe_batch(None, tap.on_batch, **sink_kw)

    rec.start()
    player = RosPlay(play_bag, bus, **play)
    try:
        if scenario.batch_size is None:
            n_in = player.run(prefetch=MESSAGE_PREFETCH if staged else 0)
        else:
            # double-buffered framing: the bag-chunk reader thread keeps
            # the next micro-batch decoded while this one is in flight
            n_in = player.run_batched(scenario.batch_size,
                                      prefetch=2 if staged else 0)
        bus.drain()         # barrier: every stage flushed, errors surface
        rec.stop()          # surfaces deferred recorder write errors
    finally:
        try:
            rec.stop()      # no-op when already stopped (exception-safe)
        except BaseException:   # noqa: BLE001 - the drain/stop error above
            pass                # is the one that must propagate
        bus.close()         # always stop lane workers — no thread leak
        src.close()         # and never leak bag handles on a failed task
        if scenario.use_memory_cache:
            play_bag.close()
    out_bag.close()
    # image() is close-safe by contract (captured at close time) — the
    # use-after-close here was a latent bug before MemoryChunkedFile.close
    # consolidated the image
    image = out_bag.chunked_file.image()
    return n_in, n_out, n_drop, image, tap.finalize()


def _run_scenario_aggregate(aggregator: Aggregator, scenario_name: str,
                            sources: Sequence,
                            partials: Sequence[dict],
                            golden_path: Optional[str],
                            messages_in: int) -> tuple[bytes, Verdict]:
    """One worker task: the aggregation stage of one scenario.

    Merges the (shard, partition)-ordered output sources into one
    timestamp-ordered bag, folds the worker-computed partial metrics
    (no payload re-sweep), compares against the golden bag, and returns
    ``(merged image, verdict)``.  ``sources`` are memory-bag images *or
    spill paths* (see ``ProcessBackend.spill_arg``): on the process
    backend the driver parks each partition image in the backend's spill
    dir and ships only the path, so the worker merges through streaming
    index-only disk readers and MB-sized images never ride the task pipe
    in either direction.  Scheduled on the shared pool with lineage stage
    ``"aggregate"`` so it overlaps remaining replay work and gets the
    scheduler's full retry/speculation semantics — spill files outlive
    the task (the backend reaps them at shutdown), so recompute is safe.
    """
    merged, verdict = aggregator.aggregate(
        scenario_name, sources, golden=golden_path,
        messages_in=messages_in, partials=list(partials))
    image = merged.chunked_file.image()
    merged.close()
    return image, verdict


def _run_partition(bag_path: str, chunk_range: tuple[int, int],
                   user_logic: UserLogic, use_memory_cache: bool,
                   latency_model_s: float = 0.0) -> tuple[int, int, bytes]:
    """Seed-compatible single-partition entry point (per-message replay).

    Returns (messages_in, messages_out, output bag image).
    """
    sc = Scenario(name="partition", bag_path=bag_path, user_logic=user_logic,
                  latency_model_s=latency_model_s,
                  use_memory_cache=use_memory_cache)
    n_in, n_out, _, image, _ = _run_scenario_partition(sc, bag_path,
                                                       chunk_range)
    return n_in, n_out, image


def _selection_matches_nothing(src: Bag, sc: Scenario) -> bool:
    """True when the scenario's topic filter / time window provably selects
    zero messages of ``src`` (from the chunk index alone).  Such shards get
    no tasks at all — an empty selection is a clean zero-message report and
    a vacuous PASS, not a degenerate partition plan."""
    if not src.num_chunks:
        return True
    if sc.topics is not None and not (set(sc.topics) & set(src.topics)):
        return True
    if sc.start is not None or sc.end is not None:
        for info in src.chunk_infos():
            if sc.start is not None and info.t_max < sc.start:
                continue
            if sc.end is not None and info.t_min >= sc.end:
                continue
            return False
        return True
    return False


class ScenarioSuite:
    """Run a whole catalog of heterogeneous scenarios through ONE scheduler
    and score the results through the aggregation layer.

    Every shard of every scenario is partitioned independently (its own
    ``num_partitions`` per shard, default = ``num_workers``), all
    partitions are submitted up front, and the shared worker pool — thread
    or process backend — drains the matrix with the scheduler's full
    fault-tolerance/speculation semantics.  Shards whose topic filter /
    time window provably selects nothing are pruned at planning time.

    Aggregation is itself scheduled: the moment a scenario's last replay
    partition reports, its merge + metrics + golden-compare run as one
    ordinary task (lineage stage ``"aggregate"``) on the same pool,
    overlapping the other scenarios' remaining replay work instead of
    running serially on the driver after the drain.  Workers ship partial
    per-topic metrics (KBs) next to each partition image, so the metric
    stage is a pure combine — the driver never re-reads payload bytes,
    and per-task results are discarded as soon as they are consumed.

    ``run`` returns ``{scenario.name: Verdict}``: each verdict carries the
    golden-comparison outcome (or an unconditional pass when the scenario
    has no golden bag), per-topic metrics, and the full
    :class:`SimulationReport` — whose ``output_image`` is the merged,
    timestamp-ordered output of all shards, whose ``wall_time_s`` spans
    suite start to the scenario's last finished partition, and whose
    ``scheduler_stats`` are the shared pool's counters.

    ``on_scheduler`` (if given) is called with the live Scheduler right
    after submission — the hook fault-injection harnesses use to kill
    workers / add elastic capacity mid-suite.  ``aggregator`` overrides
    the default exact-matching :class:`Aggregator`.

    ``run(verdict_log=path)`` additionally appends one JSONL record per
    scenario (name, verdict, metric checksums, timings) to ``path`` and
    rewrites a suite manifest (scenario → golden path → verdict) next to
    it — the CI-native face of the regression harness.
    """

    def __init__(self, scenarios: Sequence[Scenario], num_workers: int = 4,
                 backend: Union[str, ExecutorBackend] = "thread",
                 scheduler_kwargs: Optional[dict] = None,
                 on_scheduler: Optional[Callable[[Scheduler], None]] = None,
                 aggregator: Optional[Aggregator] = None):
        names = [s.name for s in scenarios]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate scenario names in {names}")
        self.scenarios = list(scenarios)
        self.num_workers = num_workers
        self.backend = backend
        self.scheduler_kwargs = scheduler_kwargs or {}
        self.on_scheduler = on_scheduler
        self.aggregator = aggregator or Aggregator()

    def _plan(self, sc: Scenario) -> list[tuple[int, str, tuple[int, int]]]:
        """One (shard index, shard path, chunk range) triple per task."""
        tasks: list[tuple[int, str, tuple[int, int]]] = []
        for si, shard in enumerate(sc.shard_paths):
            src = Bag.open_read(shard, backend="disk")
            if _selection_matches_nothing(src, sc):
                src.close()
                continue
            parts = partition_bag(src, sc.num_partitions or self.num_workers)
            src.close()
            tasks.extend((si, shard, pr) for pr in parts)
        return tasks

    @staticmethod
    def _resolve_metrics_engine(sc: Scenario, backend_name: str) -> str:
        """Pick the partition sink's digest engine.  Process workers are
        pinned to the fork-safe numpy engine (never init jax in a forked
        child of a jax-loaded driver); in-process, ``"auto"`` makes the
        fused Pallas consume step the stock batched shape and numpy the
        per-message one.  All engines are bit-identical, so this choice
        can never move a checksum or a verdict."""
        if backend_name == "process":
            return "numpy"
        if sc.metrics_engine == "auto":
            return "fused" if sc.batch_size is not None else "numpy"
        return sc.metrics_engine

    def run(self, timeout: float = 300.0,
            verdict_log: Optional[str] = None,
            manifest_path: Optional[str] = None) -> dict[str, Verdict]:
        for sc in self.scenarios:
            # fail before burning replay time, not at aggregation
            if (sc.golden_bag_path is not None
                    and not os.path.exists(sc.golden_bag_path)):
                raise FileNotFoundError(
                    f"scenario {sc.name!r}: golden bag "
                    f"{sc.golden_bag_path!r} does not exist")
        plans = [(sc, self._plan(sc)) for sc in self.scenarios]

        t0 = time.monotonic()
        # tid -> (scenario i, (shard j, partition k)) for result assembly
        owner: dict[int, tuple[int, tuple[int, int]]] = {}
        pending = [len(tasks) for _, tasks in plans]
        # scenario i -> (shard, partition) -> (image, partial metrics);
        # released to the aggregation task as soon as the scenario drains
        parts: list[Optional[dict]] = [{} for _ in plans]
        counts = [[0, 0, 0] for _ in plans]      # in / out / dropped
        replay_end = [0.0 for _ in plans]        # last replay-task finish
        agg_owner: dict[int, int] = {}           # aggregation tid -> i
        agg_out: dict[int, tuple[bytes, Verdict]] = {}

        with Scheduler(num_workers=self.num_workers, backend=self.backend,
                       **self.scheduler_kwargs) as sched:
            backend_name = sched.backend.name
            pool_agg = self.aggregator
            if backend_name == "process" and pool_agg.engine != "numpy":
                # never initialize jax inside a forked worker of a
                # jax-loaded driver (deadlock risk) — the numpy engine is
                # bit-identical, so the downgrade can't move a verdict
                pool_agg = Aggregator(tolerance=pool_agg.tolerance,
                                      metric_batch=pool_agg.metric_batch,
                                      engine="numpy")

            # spill-aware aggregate dispatch: on backends with an argument
            # spill (process), large partition images are parked in the
            # backend spill dir and the aggregate task gets paths — the
            # worker merges via streaming disk readers and the driver
            # never pickles bulk bytes through the pipe
            spill_arg = getattr(sched.backend, "spill_arg", None)
            spill_bytes = getattr(sched.backend, "spill_bytes", None)

            def submit_aggregate(i: int) -> None:
                sc = plans[i][0]
                rows = parts[i]
                ordered = sorted(rows)       # (shard, partition): merge
                images = [rows[k][0] for k in ordered]       # deterministic
                partials = [rows[k][1] for k in ordered]
                if spill_arg is not None and spill_bytes is not None:
                    images = [spill_arg(img) if len(img) > spill_bytes
                              else img for img in images]
                tid = sched.submit(
                    _run_scenario_aggregate, pool_agg, sc.name,
                    images, partials, sc.golden_bag_path, counts[i][0],
                    lineage=("aggregate", sc.name))
                agg_owner[tid] = i
                parts[i] = None              # driver drops its references

            def on_task_done(tid: int, result) -> None:
                if tid in owner:
                    i, key = owner[tid]
                    n_in, n_out, n_drop, image, partial = result
                    counts[i][0] += n_in
                    counts[i][1] += n_out
                    counts[i][2] += n_drop
                    parts[i][key] = (image, partial)
                    end = sched.task_finished_at(tid)
                    if end is not None:
                        replay_end[i] = max(replay_end[i], end)
                    sched.discard(tid)
                    pending[i] -= 1
                    if pending[i] == 0:
                        # the scenario's last partition just reported:
                        # its aggregation overlaps the other scenarios'
                        # remaining replay work on the same pool
                        submit_aggregate(i)
                else:
                    agg_out[agg_owner[tid]] = result
                    sched.discard(tid)

            for i, (sc, tasks) in enumerate(plans):
                engine = self._resolve_metrics_engine(sc, backend_name)
                part_of_shard: dict[int, int] = {}
                for si, shard, (lo, hi) in tasks:
                    k = part_of_shard.get(si, 0)
                    part_of_shard[si] = k + 1
                    tid = sched.submit(
                        _run_scenario_partition, sc, shard, (lo, hi),
                        engine,
                        lineage=("scenario", sc.name, si, shard, lo, hi))
                    owner[tid] = (i, (si, k))
            if self.on_scheduler is not None:
                self.on_scheduler(sched)
            sched.run(timeout=timeout, on_task_done=on_task_done)
            stats = dict(sched.stats)

        verdicts: dict[str, Verdict] = {}
        for i, (sc, tasks) in enumerate(plans):
            if tasks:
                image, verdict = agg_out[i]
            else:
                # pruned-empty scenario: a clean zero-message vacuous
                # verdict, no tasks burned on the pool
                merged, verdict = self.aggregator.aggregate(
                    sc.name, [], golden=sc.golden_bag_path, messages_in=0)
                image = merged.chunked_file.image()
                merged.close()
            wall = (replay_end[i] - t0) if replay_end[i] else 0.0
            report = SimulationReport(
                messages_in=counts[i][0],
                messages_out=counts[i][1],
                wall_time_s=wall,
                partitions=len(tasks),
                scheduler_stats=stats,
                scenario=sc.name,
                backend=backend_name,
                batch_size=sc.batch_size,
                messages_dropped=counts[i][2],
                shards=len(sc.shard_paths),
                output_image=image,
                metrics=verdict.metrics,
            )
            verdict.report = report
            verdicts[sc.name] = verdict
        if verdict_log is not None:
            self._persist_verdicts(verdict_log, manifest_path, verdicts,
                                   backend_name)
        return verdicts

    @staticmethod
    def _persist_verdicts(verdict_log: str, manifest_path: Optional[str],
                          verdicts: dict[str, Verdict],
                          backend_name: str) -> None:
        """Append one JSONL record per scenario to ``verdict_log`` and
        rewrite the suite manifest (scenario → golden path → verdict).

        The log is append-only — consecutive suite runs accumulate a
        verdict history a CI job can diff or trend; the manifest
        (``manifest_path``, default ``<verdict_log>.manifest.json``) is
        the current snapshot a gate inspects without parsing history.
        Metric checksums ride along so a PASS can additionally be pinned
        bit-exactly across runs.
        """
        now = time.time()
        records = []
        for name, v in verdicts.items():
            r = v.report
            records.append({
                "scenario": name,
                "status": v.status,
                "passed": v.passed,
                "vacuous": v.vacuous,
                "golden": v.golden_path,
                "diffs": [str(d) for d in v.diffs],
                "checksums": {t: m.checksum for t, m in v.metrics.items()},
                "messages_in": r.messages_in,
                "messages_out": r.messages_out,
                "messages_dropped": r.messages_dropped,
                "wall_time_s": r.wall_time_s,
                "partitions": r.partitions,
                "shards": r.shards,
                "backend": backend_name,
                "unix_time": now,
            })
        with open(verdict_log, "a") as f:
            for rec in records:
                f.write(json.dumps(rec, sort_keys=True) + "\n")
        manifest = {
            "verdict_log": os.path.abspath(verdict_log),
            "backend": backend_name,
            "unix_time": now,
            "passed": all(r["passed"] for r in records),
            "scenarios": {
                r["scenario"]: {"golden": r["golden"],
                                "status": r["status"],
                                "passed": r["passed"]}
                for r in records
            },
        }
        mpath = manifest_path or verdict_log + ".manifest.json"
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)
            f.write("\n")


class DistributedSimulation:
    """Partition a recorded bag across a worker pool and replay it through
    user logic — the full platform of the paper, minus the physical cluster.

    Now a thin wrapper over a one-scenario :class:`ScenarioSuite`; prefer
    the suite API for anything beyond a single homogeneous replay.
    """

    def __init__(self, bag_path: str, user_logic: LogicRef,
                 num_workers: int = 4, num_partitions: Optional[int] = None,
                 use_memory_cache: bool = True,
                 latency_model_s: float = 0.0,
                 batch_size: Optional[int] = None,
                 backend: Union[str, ExecutorBackend] = "thread",
                 scheduler_kwargs: Optional[dict] = None):
        self.scenario = Scenario(
            name="sim", bag_path=bag_path, user_logic=user_logic,
            latency_model_s=latency_model_s, batch_size=batch_size,
            num_partitions=num_partitions or num_workers,
            use_memory_cache=use_memory_cache)
        self.num_workers = num_workers
        self.backend = backend
        self.scheduler_kwargs = scheduler_kwargs or {}

    @property
    def bag_path(self) -> str:
        return self.scenario.bag_path

    @property
    def user_logic(self) -> LogicRef:
        return self.scenario.user_logic

    def run(self, timeout: float = 300.0) -> SimulationReport:
        suite = ScenarioSuite([self.scenario], num_workers=self.num_workers,
                              backend=self.backend,
                              scheduler_kwargs=self.scheduler_kwargs)
        return suite.run(timeout=timeout)[self.scenario.name].report


def bag_to_partitions(bag_path: str, num_partitions: int,
                      topics: Optional[Sequence[str]] = None,
                      ) -> list[BinaryPartition]:
    """Export a bag as BinPipedRDD-style binary partitions (encode stage of
    Fig 4): each record becomes the uniform format [topic, timestamp, data].
    """
    bag = Bag.open_read(bag_path, backend="disk")
    parts = partition_bag(bag, num_partitions)
    out = []
    for lo, hi in parts:
        records = [encode([m.topic, m.timestamp, m.data])
                   for m in bag.read_messages(topics=topics,
                                              chunk_range=(lo, hi))]
        out.append(BinaryPartition(records,
                                   lineage=("bag", bag_path, lo, hi)))
    bag.close()
    return out
