"""The distributed simulation driver (paper Fig 3 + Fig 5 workflow).

    Bag partitions --RosPlay--> MessageBus --User Logic--> RosRecord --> Bag
        (driver schedules one task per partition across the worker pool)

Per the paper: "Each Spark worker first reads the Rosbag data into memory
and then launches a ROS node to process the incoming data."  Here each task:

1. reads its chunk-range partition from the source bag,
2. copies it into a ``MemoryChunkedFile``-backed bag (the ROSBag cache —
   this is the I/O optimisation §4.1 measures),
3. replays it through the user logic attached to the bus,
4. records outputs into a memory bag whose image is the task result.

``user_logic`` is any callable ``Message -> Optional[(topic, bytes)]`` — in
production it is a jitted model step (see examples/distributed_playback.py);
the platform is generic (§5: "the simulator ... can be replaced").
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

from .bag import Bag, Message, partition_bag
from .binpipe import BinaryPartition, encode
from .playback import MessageBus, RosPlay, RosRecord
from .scheduler import Scheduler

UserLogic = Callable[[Message], Optional[tuple[str, bytes]]]


@dataclass
class SimulationReport:
    messages_in: int
    messages_out: int
    wall_time_s: float
    partitions: int
    scheduler_stats: dict
    output_images: list    # list[bytes] — memory-bag images, one per partition

    @property
    def throughput_msgs_s(self) -> float:
        return self.messages_in / self.wall_time_s if self.wall_time_s else 0.0


def _run_partition(bag_path: str, chunk_range: tuple[int, int],
                   user_logic: UserLogic, use_memory_cache: bool,
                   latency_model_s: float = 0.0) -> tuple[int, int, bytes]:
    """One worker task: play a partition through user logic, record results.

    Returns (messages_in, messages_out, output bag image).
    """
    src = Bag.open_read(bag_path, backend="disk")
    if use_memory_cache:
        # materialise the partition into the ROSBag cache first (§3.2):
        cache = Bag.open_write(backend="memory")
        for msg in src.read_messages(chunk_range=chunk_range):
            cache.write_message(msg)
        cache.close()
        play_bag = Bag.open_read(backend="memory",
                                 image=cache.chunked_file.image())
        play_range = None
    else:
        play_bag = src
        play_range = chunk_range

    bus = MessageBus()
    out_bag = Bag.open_write(backend="memory")
    # record everything the user logic publishes, but not the replayed inputs
    rec = RosRecord(bus, out_bag, topics=None, exclude_topics=src.topics)

    n_out = 0

    def on_msg(msg: Message) -> None:
        nonlocal n_out
        if latency_model_s:
            time.sleep(latency_model_s)      # simulated perception latency
        out = user_logic(msg)
        if out is not None:
            topic, data = out
            bus.advertise(topic).publish(msg.timestamp, data)
            n_out += 1

    # subscribe user logic to every *input* topic; outputs go to "/out/..."
    for t in src.topics:
        bus.subscribe(t, on_msg)
    rec.start()
    play = RosPlay(play_bag, bus, chunk_range=play_range)
    n_in = play.run()
    rec.stop()
    out_bag.close()
    src.close()
    if use_memory_cache:
        play_bag.close()
    return n_in, n_out, out_bag.chunked_file.image()


class DistributedSimulation:
    """Partition a recorded bag across a worker pool and replay it through
    user logic — the full platform of the paper, minus the physical cluster.
    """

    def __init__(self, bag_path: str, user_logic: UserLogic,
                 num_workers: int = 4, num_partitions: Optional[int] = None,
                 use_memory_cache: bool = True,
                 latency_model_s: float = 0.0,
                 scheduler_kwargs: Optional[dict] = None):
        self.bag_path = bag_path
        self.user_logic = user_logic
        self.num_workers = num_workers
        self.num_partitions = num_partitions or num_workers
        self.use_memory_cache = use_memory_cache
        self.latency_model_s = latency_model_s
        self.scheduler_kwargs = scheduler_kwargs or {}

    def run(self, timeout: float = 300.0) -> SimulationReport:
        src = Bag.open_read(self.bag_path, backend="disk")
        parts = partition_bag(src, self.num_partitions)
        src.close()
        t0 = time.monotonic()
        with Scheduler(num_workers=self.num_workers,
                       **self.scheduler_kwargs) as sched:
            for lo, hi in parts:
                sched.submit(
                    _run_partition, self.bag_path, (lo, hi),
                    self.user_logic, self.use_memory_cache,
                    self.latency_model_s,
                    lineage=("bag", self.bag_path, lo, hi))
            results = sched.run(timeout=timeout)
            stats = dict(sched.stats)
        wall = time.monotonic() - t0
        n_in = sum(r[0] for r in results.values())
        n_out = sum(r[1] for r in results.values())
        images = [r[2] for _, r in sorted(results.items())]
        return SimulationReport(n_in, n_out, wall, len(parts), stats, images)


def bag_to_partitions(bag_path: str, num_partitions: int,
                      topics: Optional[Sequence[str]] = None,
                      ) -> list[BinaryPartition]:
    """Export a bag as BinPipedRDD-style binary partitions (encode stage of
    Fig 4): each record becomes the uniform format [topic, timestamp, data].
    """
    bag = Bag.open_read(bag_path, backend="disk")
    parts = partition_bag(bag, num_partitions)
    out = []
    for lo, hi in parts:
        records = [encode([m.topic, m.timestamp, m.data])
                   for m in bag.read_messages(topics=topics,
                                              chunk_range=(lo, hi))]
        out.append(BinaryPartition(records,
                                   lineage=("bag", bag_path, lo, hi)))
    bag.close()
    return out
