"""The paper's primary contribution: a distributed playback-simulation
platform (Spark+ROS -> JAX/TPU adaptation, see DESIGN.md).

Layers:
    bag        -- Bag / ChunkedFile / MemoryChunkedFile (ROSBag cache, §3.2)
    binpipe    -- BinPipedRDD: encode/serialize/frame/decode (§3.1)
    playback   -- MessageBus / RosPlay / RosRecord (§2)
    scheduler  -- driver/worker scheduling, fault tolerance, stragglers (§3)
    simulation -- DistributedSimulation: the end-to-end platform (Figs 3&5)
"""

from .bag import Bag, ChunkedFile, MemoryChunkedFile, Message, partition_bag
from .binpipe import (BinaryPartition, decode, deserialize, encode, frame,
                      serialize, unframe)
from .playback import MessageBus, RosPlay, RosRecord
from .scheduler import Scheduler, Task, Worker, WorkerError
from .simulation import DistributedSimulation, SimulationReport, bag_to_partitions

__all__ = [
    "Bag", "ChunkedFile", "MemoryChunkedFile", "Message", "partition_bag",
    "BinaryPartition", "encode", "decode", "serialize", "deserialize",
    "frame", "unframe",
    "MessageBus", "RosPlay", "RosRecord",
    "Scheduler", "Task", "Worker", "WorkerError",
    "DistributedSimulation", "SimulationReport", "bag_to_partitions",
]
