"""The paper's primary contribution: a distributed playback-simulation
platform (Spark+ROS -> JAX/TPU adaptation, see DESIGN.md).

Layers:
    bag         -- Bag / ChunkedFile / MemoryChunkedFile (ROSBag cache, §3.2)
                   + merge_bags (timestamp-ordered k-way shard merge)
    binpipe     -- BinPipedRDD: encode/serialize/frame/decode (§3.1)
    playback    -- MessageBus / RosPlay / RosRecord, batched replay (§2)
    executors   -- ExecutorBackend: ThreadBackend / ProcessBackend pools
    scheduler   -- driver scheduling semantics: fault tolerance, stragglers (§3)
    simulation  -- Scenario / ScenarioSuite / DistributedSimulation (Figs 3&5)
    aggregation -- Aggregator: merge -> metrics -> golden compare -> Verdict
"""

from .aggregation import (Aggregator, Diff, MetricsTap, TopicMetrics,
                          Verdict, combine_digests, combine_metrics)
from .bag import (Bag, ChunkedFile, MemoryChunkedFile, Message,
                  iter_time_ordered, merge_bags, partition_bag)
from .binpipe import (BinaryPartition, decode, deserialize, encode, frame,
                      serialize, unframe)
from .executors import (ExecutorBackend, ProcessBackend, ThreadBackend,
                        Worker)
from .playback import BusBridge, MessageBus, RosPlay, RosRecord
from .scheduler import Scheduler, Task, WorkerError
from .simulation import (DistributedSimulation, Scenario, ScenarioSuite,
                         SimulationReport, bag_to_partitions,
                         resolve_logic_ref)

__all__ = [
    "Bag", "ChunkedFile", "MemoryChunkedFile", "Message", "partition_bag",
    "iter_time_ordered", "merge_bags",
    "BinaryPartition", "encode", "decode", "serialize", "deserialize",
    "frame", "unframe",
    "BusBridge", "MessageBus", "RosPlay", "RosRecord",
    "ExecutorBackend", "ThreadBackend", "ProcessBackend",
    "Scheduler", "Task", "Worker", "WorkerError",
    "Scenario", "ScenarioSuite", "resolve_logic_ref",
    "DistributedSimulation", "SimulationReport", "bag_to_partitions",
    "Aggregator", "Diff", "MetricsTap", "TopicMetrics", "Verdict",
    "combine_digests", "combine_metrics",
]
