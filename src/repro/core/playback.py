"""Playback engine: the ROS side of the platform (paper §2, Fig 5).

ROS is "a message pool architecture: the sending node advertises to a Topic,
the receiving node subscribes to a Topic".  We reproduce those semantics —
ordering and timing, which is what simulation correctness depends on — with
an in-process bus rather than TCPROS (see DESIGN.md §8).

``RosPlay``   reads a Bag (disk- or memory-backed) and publishes its
              messages in timestamp order, optionally paced by wall clock.
              ``run_batched(n)`` delivers timestamp-ordered micro-batches
              through ``MessageBus.publish_batch`` so user logic can be a
              jitted array step instead of a per-message Python call.
``RosRecord`` subscribes to topics and writes everything to a Bag.

Together with :mod:`repro.core.bag`'s ``MemoryChunkedFile`` these are the two
"missing links" of §3.2: play-from-memory and record-to-memory.
"""

from __future__ import annotations

import threading
import time
from collections import defaultdict
from typing import Callable, Iterable, Optional, Sequence

from .bag import Bag, Message, iter_time_ordered

Callback = Callable[[Message], None]
BatchCallback = Callable[[list[Message]], None]


class Publisher:
    def __init__(self, bus: "MessageBus", topic: str):
        self._bus = bus
        self.topic = topic

    def publish(self, timestamp: int, data: bytes) -> None:
        self._bus._dispatch(Message(self.topic, timestamp, data))

    def publish_message(self, msg: Message) -> None:
        if msg.topic != self.topic:
            raise ValueError(f"publisher for {self.topic}, got {msg.topic}")
        self._bus._dispatch(msg)


class MessageBus:
    """Topic pub/sub message pool. Thread-safe; delivery is synchronous and
    in publish order (deterministic for tests and replay)."""

    def __init__(self):
        self._subs: dict[str, list[Callback]] = defaultdict(list)
        self._all: list[Callback] = []
        self._batch_subs: dict[str, list[BatchCallback]] = defaultdict(list)
        self._batch_all: list[BatchCallback] = []
        self._lock = threading.Lock()
        self.published = 0

    def advertise(self, topic: str) -> Publisher:
        return Publisher(self, topic)

    def subscribe(self, topic: Optional[str], callback: Callback) -> None:
        """``topic=None`` subscribes to every topic (rosbag record -a)."""
        with self._lock:
            if topic is None:
                self._all.append(callback)
            else:
                self._subs[topic].append(callback)

    def unsubscribe(self, topic: Optional[str], callback: Callback) -> None:
        with self._lock:
            if topic is None:
                self._all.remove(callback)
            else:
                self._subs[topic].remove(callback)

    def subscribe_batch(self, topic: Optional[str],
                        callback: BatchCallback) -> None:
        """Batch subscription: receives ``list[Message]`` micro-batches from
        :meth:`publish_batch`.  Per-topic subscribers get the batch split by
        topic (uniform payload shape for array assembly); ``topic=None``
        receives the whole mixed-topic batch."""
        with self._lock:
            if topic is None:
                self._batch_all.append(callback)
            else:
                self._batch_subs[topic].append(callback)

    def unsubscribe_batch(self, topic: Optional[str],
                          callback: BatchCallback) -> None:
        with self._lock:
            if topic is None:
                self._batch_all.remove(callback)
            else:
                self._batch_subs[topic].remove(callback)

    def _dispatch(self, msg: Message) -> None:
        with self._lock:
            cbs = list(self._subs.get(msg.topic, ())) + list(self._all)
            self.published += 1
        for cb in cbs:
            cb(msg)

    def publish_batch(self, messages: Sequence[Message]) -> int:
        """Deliver a micro-batch with one lock acquisition and one callback
        invocation per batch subscriber (vs one per message) — the bus half
        of the batched replay hot path.  Per-message subscribers still see
        every message individually, so recorders need no changes."""
        msgs = list(messages)
        if not msgs:
            return 0
        with self._lock:
            self.published += len(msgs)
            per_msg = {t: list(self._subs.get(t, ()))
                       for t in {m.topic for m in msgs}}
            all_cbs = list(self._all)
            per_batch = {t: list(self._batch_subs.get(t, ()))
                         for t in {m.topic for m in msgs}}
            batch_all = list(self._batch_all)
        if all_cbs or any(per_msg.values()):
            for m in msgs:
                for cb in per_msg[m.topic]:
                    cb(m)
                for cb in all_cbs:
                    cb(m)
        if any(per_batch.values()):
            groups: dict[str, list[Message]] = defaultdict(list)
            for m in msgs:
                groups[m.topic].append(m)
            for t, group in groups.items():
                for cb in per_batch[t]:
                    cb(group)
        for cb in batch_all:
            cb(msgs)
        return len(msgs)


class RosPlay:
    """Publish a bag's messages to the bus in global timestamp order.

    ``rate``: None = as fast as possible (simulation mode); otherwise a
    real-time factor (1.0 = original timing) — timing is derived from message
    timestamps like ``rosbag play``.
    """

    def __init__(self, bag: Bag, bus: MessageBus,
                 topics: Optional[Sequence[str]] = None,
                 rate: Optional[float] = None,
                 chunk_range: Optional[tuple[int, int]] = None,
                 start: Optional[int] = None,
                 end: Optional[int] = None):
        self._bag = bag
        self._bus = bus
        self._topics = topics
        self._rate = rate
        self._chunk_range = chunk_range
        self._start = start
        self._end = end
        self.messages_played = 0

    def _time_ordered(self) -> Iterable[Message]:
        """Bag chunks are time-ordered per-chunk but may interleave across
        topic boundaries; :func:`repro.core.bag.iter_time_ordered` merge-sorts
        on a small heap window to keep global order without materialising
        the partition."""
        return iter_time_ordered(self._bag, topics=self._topics,
                                 chunk_range=self._chunk_range,
                                 start=self._start, end=self._end)

    def run(self) -> int:
        pubs: dict[str, Publisher] = {}
        t0_msg: Optional[int] = None
        t0_wall = time.monotonic()
        for msg in self._time_ordered():
            if self._rate is not None:
                if t0_msg is None:
                    t0_msg = msg.timestamp
                target = (msg.timestamp - t0_msg) / 1e9 / self._rate
                delay = target - (time.monotonic() - t0_wall)
                if delay > 0:
                    time.sleep(delay)
            pub = pubs.get(msg.topic)
            if pub is None:
                pub = pubs[msg.topic] = self._bus.advertise(msg.topic)
            pub.publish_message(msg)
            self.messages_played += 1
        return self.messages_played

    def run_batched(self, batch_size: int) -> int:
        """Vectorized replay: publish timestamp-ordered micro-batches of up
        to ``batch_size`` messages via :meth:`MessageBus.publish_batch`.

        Wall-clock pacing (``rate``) applies at batch boundaries, keyed on
        the first timestamp of each batch — the array-step analogue of
        per-message pacing.
        """
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        t0_msg: Optional[int] = None
        t0_wall = time.monotonic()
        batch: list[Message] = []

        def flush() -> None:
            nonlocal t0_msg
            if not batch:
                return
            if self._rate is not None:
                if t0_msg is None:
                    t0_msg = batch[0].timestamp
                target = (batch[0].timestamp - t0_msg) / 1e9 / self._rate
                delay = target - (time.monotonic() - t0_wall)
                if delay > 0:
                    time.sleep(delay)
            self.messages_played += self._bus.publish_batch(batch)
            batch.clear()

        for msg in self._time_ordered():
            batch.append(msg)
            if len(batch) >= batch_size:
                flush()
        flush()
        return self.messages_played


class RosRecord:
    """Subscribe to topics and persist every message to a Bag.

    ``batch=True`` records through the batch subscription instead: one
    callback + one lock acquisition per micro-batch rather than per
    message, keeping the recorder off the per-message hot path of batched
    replay.  (Don't combine with per-message mode on the same bus — batched
    publishes would be recorded twice.)
    """

    def __init__(self, bus: MessageBus, bag: Bag,
                 topics: Optional[Sequence[str]] = None,
                 exclude_topics: Optional[Sequence[str]] = None,
                 batch: bool = False):
        self._bus = bus
        self._bag = bag
        self._topics = list(topics) if topics is not None else None
        self._exclude = set(exclude_topics or ())
        self._batch = batch
        self._cbs: list[tuple[Optional[str], Callback]] = []
        self._batch_cbs: list[tuple[Optional[str], BatchCallback]] = []
        self.messages_recorded = 0
        self._lock = threading.Lock()

    def start(self) -> None:
        if self._batch:
            def bcb(msgs: list[Message]) -> None:
                kept = [m for m in msgs if m.topic not in self._exclude]
                if not kept:
                    return
                with self._lock:
                    for m in kept:
                        self._bag.write_message(m)
                    self.messages_recorded += len(kept)
            for t in (self._topics if self._topics is not None else [None]):
                self._bus.subscribe_batch(t, bcb)
                self._batch_cbs.append((t, bcb))
            return

        def cb(msg: Message) -> None:
            if msg.topic in self._exclude:
                return
            with self._lock:
                self._bag.write_message(msg)
                self.messages_recorded += 1
        if self._topics is None:
            self._bus.subscribe(None, cb)
            self._cbs.append((None, cb))
        else:
            for t in self._topics:
                self._bus.subscribe(t, cb)
                self._cbs.append((t, cb))

    def stop(self) -> None:
        for t, cb in self._cbs:
            self._bus.unsubscribe(t, cb)
        self._cbs.clear()
        for t, bcb in self._batch_cbs:
            self._bus.unsubscribe_batch(t, bcb)
        self._batch_cbs.clear()

    def __enter__(self) -> "RosRecord":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
