"""Playback engine: the ROS side of the platform (paper §2, Fig 5).

ROS is "a message pool architecture: the sending node advertises to a Topic,
the receiving node subscribes to a Topic".  We reproduce those semantics —
ordering and timing, which is what simulation correctness depends on — with
an in-process bus rather than TCPROS (see DESIGN.md §8).

``RosPlay``   reads a Bag (disk- or memory-backed) and publishes its
              messages in timestamp order, optionally paced by wall clock.
              ``run_batched(n)`` delivers timestamp-ordered micro-batches
              through ``MessageBus.publish_batch`` so user logic can be a
              jitted array step instead of a per-message Python call.
              ``prefetch`` moves bag reading (chunk decode + time-order
              merge) onto a background reader thread.
``RosRecord`` subscribes to topics and writes everything to a Bag.

Together with :mod:`repro.core.bag`'s ``MemoryChunkedFile`` these are the two
"missing links" of §3.2: play-from-memory and record-to-memory.

Delivery modes
--------------

The bus delivers each subscription either **synchronously** (the seed
model: ``publish`` returns after every callback ran — deterministic, but a
slow subscriber stalls the publisher and the whole replay partition) or
**queued** (``subscribe(..., mode="queued", maxsize=N)``): the
subscription gets a bounded FIFO *lane* drained by a dedicated worker
thread.  Publishers enqueue and move on; a full lane blocks the publisher
(backpressure), so memory stays bounded and a hopelessly slow consumer
still paces the pipeline instead of being silently left behind.

Lane depth is fixed (``maxsize=N``), unbounded (``0``) or **adaptive**
(``None``): adaptive lanes observe the producer/consumer rate — every time
a producer finds the FIFO full the depth doubles, up to a memory cap —
so bursty sinks converge to a deeper lane while tight-memory workers keep
shallow ones.  Depth only moves *when* a publisher blocks, never delivery
order.

Determinism is preserved per lane: one worker thread drains one FIFO, so a
subscription sees exactly the synchronous delivery sequence, just later.
Subscriptions that must share one ordered stream (e.g. user logic attached
to several input topics, whose fault-injection RNG draws must happen in
publish order) pass the same ``group=`` name and share a single lane.
``drain()`` is the end-of-replay barrier: it blocks until every lane has
fully flushed — including work enqueued *by* queued callbacks into other
lanes — and re-raises the first callback error.  ``close()`` flushes and
stops the lane workers.  Callback exceptions never kill a lane worker
mid-replay; they are recorded and surface at the ``drain()`` barrier, like
the synchronous mode's immediate propagation but deferred to the join.
"""

from __future__ import annotations

import itertools
import queue
import threading
import time
from collections import defaultdict
from typing import Callable, Iterable, NamedTuple, Optional, Sequence

from repro import chaos
from repro.obs import metrics as obs_metrics
from repro.obs import trace as otrace

from .bag import Bag, Message, iter_time_ordered

Callback = Callable[[Message], None]
BatchCallback = Callable[[list[Message]], None]

#: per-message prefetch depth ``RosPlay.run(prefetch=True)`` defaults to
MESSAGE_PREFETCH = 256

#: messages per ``play.read`` trace span in per-message replay — spans are
#: chunked so tracing stays off the per-message hot path
TRACE_CHUNK = 256

# process-wide lane metrics (adaptive growth, producer stalls), folded
# into the repro.obs.metrics registry snapshot
_LANE_METRICS = obs_metrics.scope("lane")
_M_LANE_GROWN = _LANE_METRICS.counter("grown")
_M_LANE_STALLS = _LANE_METRICS.counter("enqueue_stalls")


class Publisher:
    def __init__(self, bus: "MessageBus", topic: str):
        self._bus = bus
        self.topic = topic

    def publish(self, timestamp: int, data: bytes) -> None:
        self._bus._dispatch(Message(self.topic, timestamp, data))

    def publish_message(self, msg: Message) -> None:
        if msg.topic != self.topic:
            raise ValueError(f"publisher for {self.topic}, got {msg.topic}")
        self._bus._dispatch(msg)


class _Lane:
    """One bounded-FIFO delivery lane drained by its own worker thread.

    Items are ``(callback, payload)`` pairs so several subscriptions (a
    ``group=``) can share the lane and keep their relative delivery order.
    ``put`` blocks while the queue is full — the bus's backpressure.
    Callback errors are recorded (never swallowed silently, never fatal to
    the worker; bounded — see ``MAX_ERRORS``) and re-raised at the
    ``drain()``/unsubscribe barrier.

    ``maxsize=None`` makes the lane **adaptive**: it starts at
    ``ADAPTIVE_START`` and doubles its depth every time a producer
    observes it full — a sink that keeps falling behind (bursty consumer,
    slow serializer) converges to a deeper lane instead of rate-limiting
    the publisher — bounded by ``ADAPTIVE_MAX`` items (the memory cap), at
    which point backpressure applies exactly as with a fixed depth.
    Adapting only ever changes *when* a publisher blocks, never FIFO
    delivery order, so results stay bit-identical.

    A publish racing lane shutdown (unsubscribe/close from another thread)
    must never silently lose a message: after the worker is gone, ``put``
    delivers inline, and both ``put`` and ``close`` sweep any straggler
    that slipped into the queue during the race window — the worst case is
    the old synchronous bus's (a late inline callback), not a drop.
    """

    #: deferred errors kept per lane; beyond this only a count is kept, so
    #: a subscriber failing on every message of a huge replay can't pin
    #: one traceback (and its message payload) per delivery until drain
    MAX_ERRORS = 8

    #: adaptive lanes start here (= the old fixed default) ...
    ADAPTIVE_START = 8
    #: ... and never grow beyond this many queued items ...
    ADAPTIVE_MAX = 1024
    #: ... nor past roughly this many queued payload *bytes* — the item
    #: cap alone would let MB-scale sensor messages balloon a lane, so
    #: deepening also respects the observed item size (largest payload
    #: seen; items whose size we can't read count as 0)
    ADAPTIVE_MAX_BYTES = 64 << 20

    __slots__ = ("key", "queue", "errors", "errors_dropped", "refs",
                 "closed", "adaptive", "grown", "_item_bytes", "_thread")

    def __init__(self, key: str, maxsize: Optional[int]):
        self.key = key
        self.adaptive = maxsize is None
        self.queue: "queue.Queue" = queue.Queue(
            maxsize=self.ADAPTIVE_START if self.adaptive else maxsize)
        self.errors: list[BaseException] = []
        self.errors_dropped = 0
        self.refs = 0                  # subscriptions sharing this lane
        self.closed = False
        self.grown = 0                 # adaptive depth doublings so far
        self._item_bytes = 0           # largest queued payload observed
        self._thread = threading.Thread(target=self._run,
                                        name=f"bus-lane-{key}", daemon=True)
        self._thread.start()

    @property
    def depth(self) -> int:
        """Current FIFO bound (0 = unbounded)."""
        return self.queue.maxsize

    @staticmethod
    def _payload_bytes(item) -> int:
        """Approximate payload size of one queued item (a Message or a
        micro-batch of them); 0 when unreadable."""
        data = getattr(item, "data", None)
        if data is not None:
            return len(data)
        if isinstance(item, (list, tuple)):
            return sum(len(getattr(m, "data", b"")) for m in item)
        return 0

    def _deepen(self, item) -> None:
        """Double an adaptive lane's depth (producer observed it full),
        capped at ``ADAPTIVE_MAX`` items *and* ``ADAPTIVE_MAX_BYTES`` of
        observed payload (largest item seen sizes the byte bound).
        Waiting producers are woken so they re-check the new bound."""
        self._item_bytes = max(self._item_bytes, self._payload_bytes(item))
        cap = self.ADAPTIVE_MAX
        if self._item_bytes:
            cap = min(cap, max(self.ADAPTIVE_START,
                               self.ADAPTIVE_MAX_BYTES // self._item_bytes))
        q = self.queue
        with q.mutex:
            if 0 < q.maxsize < cap:
                q.maxsize = min(q.maxsize * 2, cap)
                self.grown += 1
                _M_LANE_GROWN.inc()
                q.not_full.notify_all()

    def _record_error(self, e: BaseException) -> None:
        if len(self.errors) < self.MAX_ERRORS:
            self.errors.append(e)
        else:
            self.errors_dropped += 1

    def put(self, callback: Callable, item) -> None:
        if self.closed:
            # worker stopping/stopped: deliver inline with synchronous
            # semantics — errors propagate to the publisher, since this
            # lane may already be detached from the bus and its deferred
            # error list unread
            callback(item)
            return
        if self.adaptive and self.queue.full():
            # the producer is outrunning the sink: grow the window before
            # blocking (up to the caps; beyond them this is plain
            # backpressure)
            self._deepen(item)
        tr = otrace.TRACER
        if tr is not None and self.queue.full():
            # the producer is about to block — bill the stall to a span
            # (only probed under tracing: full() takes the queue mutex)
            _M_LANE_STALLS.inc()
            t0 = time.perf_counter_ns()
            self.queue.put((callback, item))    # blocks when full
            tr.emit("lane.enqueue_stall", "lane", t0, time.perf_counter_ns(),
                    attrs={"lane": self.key})
        else:
            self.queue.put((callback, item))    # blocks when full
        if self.closed and not self._thread.is_alive():
            # shutdown raced the enqueue and the worker is already gone —
            # sweep so the item is never stranded.  (While the worker is
            # still alive it either drains the item itself or close()'s
            # post-join sweep does; sweeping only after worker exit means
            # the stop sentinel can never be stolen from the worker.)
            self._sweep(record=False)

    def _run(self) -> None:
        # tracing is burst-granular: one ``lane.deliver`` span covers a
        # contiguous drain burst (first get after idle -> queue empty), so
        # the per-message cost is one global read + two cheap checks
        slot: Optional[list] = None
        n_burst = 0
        while True:
            callback, item = self.queue.get()
            tr = otrace.TRACER
            if tr is not None and slot is None and callback is not None:
                slot = tr.begin("lane.deliver", "lane")
                n_burst = 0
            try:
                if callback is None:            # stop sentinel
                    if slot is not None:
                        otrace.Tracer.set_attrs(
                            slot, {"lane": self.key, "n": n_burst})
                        otrace.Tracer.end(slot)
                    return
                plan = chaos.active_plan()
                if plan is not None:
                    fault = plan.probe("lane_stall", self.key)
                    if fault is not None:
                        # an injected slow consumer: delivery stalls, the
                        # lane backs up, publishers feel the backpressure
                        time.sleep(fault.param or 0.05)
                callback(item)
            except BaseException as e:          # noqa: BLE001 - defer to drain
                self._record_error(e)
            finally:
                self.queue.task_done()
            if slot is not None:
                n_burst += 1
                if self.queue.empty():
                    otrace.Tracer.set_attrs(
                        slot, {"lane": self.key, "n": n_burst})
                    otrace.Tracer.end(slot)
                    slot = None

    def _sweep(self, record: bool) -> None:
        """Deliver (inline) anything still queued after the worker exited.
        ``record=True`` defers callback errors to the lane's error list
        (shutdown paths that must not raise); ``record=False`` re-raises
        the first error to the sweeping publisher after finishing."""
        first: Optional[BaseException] = None
        while True:
            try:
                callback, item = self.queue.get_nowait()
            except queue.Empty:
                break
            try:
                if callback is not None:
                    callback(item)
            except BaseException as e:   # noqa: BLE001 - collect, finish
                if record:
                    self._record_error(e)
                elif first is None:
                    first = e
            finally:
                self.queue.task_done()   # keep flush()/idle bookkeeping sane
        if first is not None:
            raise first

    @property
    def idle(self) -> bool:
        return self.queue.unfinished_tasks == 0

    def flush(self) -> None:
        """Block until every item enqueued so far has been processed."""
        self.queue.join()

    def close(self) -> None:
        """Flush the backlog, then stop and join the worker; stragglers
        from a racing publish are delivered inline, never dropped."""
        if self.closed:
            return
        self.closed = True
        self.queue.put((None, None))
        self._thread.join()
        self._sweep(record=True)


class _Sub(NamedTuple):
    """One subscription entry: a callback, its delivery lane (``None`` lane
    = synchronous delivery), and an optional bus-side topic exclusion set
    (messages of excluded topics are skipped *before* any enqueue, so
    uninterested sinks cost the hot path nothing)."""
    callback: Callable
    lane: Optional[_Lane]
    exclude: Optional[frozenset] = None

    def wants(self, topic: str) -> bool:
        """The single exclusion predicate — every dispatch path (per-message
        and batched) must filter through this so the semantics can't
        diverge between publish shapes."""
        return self.exclude is None or topic not in self.exclude

    def deliver(self, item) -> None:
        if self.lane is None:
            self.callback(item)
        else:
            self.lane.put(self.callback, item)


class MessageBus:
    """Topic pub/sub message pool.  Thread-safe.  Synchronous subscriptions
    are delivered in publish order before ``publish`` returns (the seed
    contract); queued subscriptions decouple the subscriber onto its own
    bounded FIFO + worker thread — see the module docstring."""

    #: default bounded-FIFO depth for queued subscriptions
    DEFAULT_MAXSIZE = 8

    def __init__(self):
        self._subs: dict[str, list[_Sub]] = defaultdict(list)
        self._all: list[_Sub] = []
        self._batch_subs: dict[str, list[_Sub]] = defaultdict(list)
        self._batch_all: list[_Sub] = []
        self._lanes: dict[str, _Lane] = {}
        self._anon = itertools.count()
        self._lock = threading.Lock()
        self.published = 0

    def advertise(self, topic: str) -> Publisher:
        return Publisher(self, topic)

    # -- subscription management -------------------------------------------

    def _make_sub(self, callback: Callable, mode: str,
                  maxsize: Optional[int], group: Optional[str],
                  exclude_topics: Optional[Sequence[str]]) -> _Sub:
        """Build a subscription entry; caller holds ``self._lock``."""
        exclude = frozenset(exclude_topics) if exclude_topics else None
        if mode == "sync":
            return _Sub(callback, None, exclude)
        if mode != "queued":
            raise ValueError(f"unknown delivery mode {mode!r}")
        key = group if group is not None else f"anon-{next(self._anon)}"
        lane = self._lanes.get(key)
        if lane is None:
            lane = self._lanes[key] = _Lane(key, maxsize)
        lane.refs += 1
        return _Sub(callback, lane, exclude)

    @staticmethod
    def _check_duplicate(entries: list[_Sub], callback: Callable,
                         where: str) -> None:
        """Double-subscribing the same callback to the same topic is an
        error: ``unsubscribe`` removes exactly one registration, so a silent
        duplicate would leave a phantom subscription behind (the seed-era
        footgun) — fail at subscribe time instead."""
        if any(s.callback == callback for s in entries):
            raise ValueError(
                f"callback {callback!r} is already subscribed to {where}; "
                "double subscription would make unsubscribe ambiguous")

    def subscribe(self, topic: Optional[str], callback: Callback, *,
                  mode: str = "sync",
                  maxsize: Optional[int] = DEFAULT_MAXSIZE,
                  group: Optional[str] = None,
                  exclude_topics: Optional[Sequence[str]] = None) -> None:
        """``topic=None`` subscribes to every topic (rosbag record -a).

        ``mode="queued"`` hands the subscription a bounded FIFO
        (``maxsize``; 0 = unbounded; ``None`` = adaptive — the lane starts
        at ``_Lane.ADAPTIVE_START`` and deepens itself toward
        ``_Lane.ADAPTIVE_MAX`` while the producer outruns the sink)
        drained by a worker thread;
        subscriptions sharing a ``group`` name share one FIFO + worker, so
        their combined delivery order is the publish order.
        ``exclude_topics`` filters *at dispatch*: excluded messages are
        never delivered — and in queued mode never enqueued, keeping
        uninterested sinks (a recorder excluding replay inputs) entirely
        off the hot path and out of the backpressure budget."""
        with self._lock:
            entries = self._all if topic is None else self._subs[topic]
            self._check_duplicate(entries, callback,
                                  "all topics" if topic is None else topic)
            entries.append(self._make_sub(callback, mode, maxsize, group,
                                          exclude_topics))

    def unsubscribe(self, topic: Optional[str], callback: Callback) -> None:
        """Remove a subscription.  A queued subscription's lane is flushed
        first (pending deliveries complete — end-of-replay determinism) and
        its worker stopped once no other subscription shares it; deferred
        callback errors re-raise here."""
        self._remove(self._all if topic is None else self._subs[topic],
                     callback)

    def subscribe_batch(self, topic: Optional[str], callback: BatchCallback,
                        *, mode: str = "sync",
                        maxsize: Optional[int] = DEFAULT_MAXSIZE,
                        group: Optional[str] = None,
                        exclude_topics: Optional[Sequence[str]] = None,
                        ) -> None:
        """Batch subscription: receives ``list[Message]`` micro-batches from
        :meth:`publish_batch`.  Per-topic subscribers get the batch split by
        topic (uniform payload shape for array assembly); ``topic=None``
        receives the whole mixed-topic batch, minus any ``exclude_topics``
        (filtered at dispatch — an all-excluded batch is not delivered or
        enqueued at all).  ``mode="queued"`` enqueues whole micro-batches
        into the subscription's lane."""
        with self._lock:
            entries = (self._batch_all if topic is None
                       else self._batch_subs[topic])
            self._check_duplicate(
                entries, callback,
                "all topics (batch)" if topic is None else f"{topic} (batch)")
            entries.append(self._make_sub(callback, mode, maxsize, group,
                                          exclude_topics))

    def unsubscribe_batch(self, topic: Optional[str],
                          callback: BatchCallback) -> None:
        self._remove(self._batch_all if topic is None
                     else self._batch_subs[topic], callback)

    def _remove(self, entries: list[_Sub], callback: Callable) -> None:
        with self._lock:
            for i, s in enumerate(entries):
                if s.callback == callback:
                    del entries[i]
                    lane = s.lane
                    break
            else:
                raise ValueError(f"callback {callback!r} is not subscribed")
            if lane is not None:
                lane.refs -= 1
                if lane.refs > 0:
                    lane = None          # shared lane lives on
                else:
                    self._lanes.pop(lane.key, None)
        if lane is not None:
            lane.close()
            if lane.errors:
                raise lane.errors[0]

    # -- bridging (cross-process topic transport) ---------------------------

    def bridge(self, topics: "str | Sequence[str] | None", transport, *,
               batch: bool = False, maxsize: Optional[int] = None,
               group: Optional[str] = None) -> "BusBridge":
        """Forward ``topics`` (one topic, a sequence, or ``None`` for every
        topic) into a transport — the sending half of the distributed
        message pool (:mod:`repro.net`).

        The bridge is one queued subscription per topic sharing a single
        lane, whose callback is ``transport.send_message`` — so the remote
        end observes exactly this bus's publish order across all bridged
        topics, the transport's socket write runs on the lane worker (off
        the publish hot path), and a full lane or an exhausted credit
        window blocks the publisher: remote backpressure propagates to the
        local publisher through the standard lane mechanics.  ``maxsize``
        defaults to adaptive (``None``).

        ``transport`` is duck-typed (``send_message`` / ``send_batch`` /
        ``drain`` / ``close``) so the core layer never imports
        :mod:`repro.net`; pass a
        :class:`repro.net.transport.LaneTransport`.

        ``batch=True`` rides the batch subscription instead — one lane
        handoff and one ``send_batch`` per published micro-batch, the
        right shape for ``publish_batch`` buses (like ``RosRecord``'s
        ``batch`` flag, don't mix with per-message publishes of the same
        topics).  Note batch delivery is grouped per topic, so the remote
        end preserves per-topic order and batch order, not the exact
        cross-topic interleaving within one micro-batch — use the
        per-message bridge where that interleaving is contractual.

        Returns a :class:`BusBridge`: ``drain()`` is the cross-wire
        barrier, ``close()`` unsubscribes and releases the transport.
        Transport failures raise from the lane's deferred-error machinery
        — at :meth:`drain`/:meth:`BusBridge.close`/unsubscribe — never
        silently drop frames.
        """
        if isinstance(topics, str):
            topic_list: list[Optional[str]] = [topics]
        elif topics is None:
            topic_list = [None]
        else:
            topic_list = list(topics)
            if not topic_list:
                raise ValueError("bridge needs at least one topic")
        if group is None:
            group = f"bridge-{next(self._anon)}"
        callback = transport.send_batch if batch else transport.send_message
        sub = self.subscribe_batch if batch else self.subscribe
        for t in topic_list:
            sub(t, callback, mode="queued", maxsize=maxsize, group=group)
        return BusBridge(self, topic_list, transport, group, batch=batch)

    # -- barriers -----------------------------------------------------------

    def drain(self) -> None:
        """End-of-replay barrier: block until every queued lane is empty and
        idle — including deliveries enqueued *by* queued callbacks into
        other lanes while draining (a flush pass repeats until a pass finds
        everything already idle).  Re-raises the first deferred callback
        error.  A no-op on a bus with only synchronous subscriptions."""
        while True:
            with self._lock:
                lanes = list(self._lanes.values())
            if all(lane.idle for lane in lanes):
                break
            for lane in lanes:
                lane.flush()
        errors = [e for lane in lanes for e in lane.errors]
        if errors:
            raise errors[0]

    def close(self) -> None:
        """Flush and stop every queued lane worker and drop their
        subscriptions.  Never raises for deferred callback errors (shutdown
        path) — call :meth:`drain` first when errors must surface.  The bus
        stays usable for synchronous subscriptions afterwards."""
        with self._lock:
            lanes = list(self._lanes.values())
            self._lanes.clear()
            self._all = [s for s in self._all if s.lane is None]
            self._batch_all = [s for s in self._batch_all if s.lane is None]
            for reg in (self._subs, self._batch_subs):
                for topic in list(reg):
                    reg[topic] = [s for s in reg[topic] if s.lane is None]
        for lane in lanes:
            lane.close()

    # -- dispatch -----------------------------------------------------------

    def _dispatch(self, msg: Message) -> None:
        with self._lock:
            subs = list(self._subs.get(msg.topic, ())) + list(self._all)
            self.published += 1
        for s in subs:
            if s.wants(msg.topic):
                s.deliver(msg)

    def publish_batch(self, messages: Sequence[Message]) -> int:
        """Deliver a micro-batch with one lock acquisition and one callback
        invocation (or lane enqueue) per batch subscriber — the bus half of
        the batched replay hot path.  Per-message subscribers still see
        every message individually, so recorders need no changes."""
        msgs = list(messages)
        if not msgs:
            return 0
        with self._lock:
            self.published += len(msgs)
            per_msg = {t: list(self._subs.get(t, ()))
                       for t in {m.topic for m in msgs}}
            all_subs = list(self._all)
            per_batch = {t: list(self._batch_subs.get(t, ()))
                         for t in {m.topic for m in msgs}}
            batch_all = list(self._batch_all)
        if all_subs or any(per_msg.values()):
            for m in msgs:
                for s in per_msg[m.topic]:
                    if s.wants(m.topic):
                        s.deliver(m)
                for s in all_subs:
                    if s.wants(m.topic):
                        s.deliver(m)
        if any(per_batch.values()):
            groups: dict[str, list[Message]] = defaultdict(list)
            for m in msgs:
                groups[m.topic].append(m)
            for t, group in groups.items():
                for s in per_batch[t]:
                    if s.wants(t):
                        s.deliver(group)
        for s in batch_all:
            if s.exclude is not None:
                kept = [m for m in msgs if s.wants(m.topic)]
                if kept:
                    s.deliver(kept)
            else:
                s.deliver(msgs)
        return len(msgs)


class BusBridge:
    """Handle for one :meth:`MessageBus.bridge` — the local face of a
    cross-process topic link.

    ``drain()`` is the end-to-end barrier: it flushes the bridge's lane
    (everything published so far has reached the transport) and then the
    transport itself (everything sent has been republished/committed on
    the remote end) — the cross-wire extension of ``MessageBus.drain``.
    ``close()`` unsubscribes, surfaces any deferred lane errors (transport
    send failures recorded mid-replay), and releases the transport.
    """

    def __init__(self, bus: "MessageBus", topics: Sequence[Optional[str]],
                 transport, group: str, batch: bool = False):
        self._bus = bus
        self._topics = list(topics)
        self._transport = transport
        self._group = group
        self._batch = batch
        self._open = True

    @property
    def transport(self):
        return self._transport

    def drain(self) -> None:
        with self._bus._lock:
            lane = self._bus._lanes.get(self._group)
        if lane is not None:
            lane.flush()
            if lane.errors:
                raise lane.errors[0]
        self._transport.drain()

    def close(self) -> None:
        """Unsubscribe and release the transport.  Deferred lane errors
        (a transport that died mid-replay) re-raise here — after every
        subscription is removed and the transport is closed, so a failed
        bridge never leaks a lane worker or a socket."""
        if not self._open:
            return
        self._open = False
        unsub = (self._bus.unsubscribe_batch if self._batch
                 else self._bus.unsubscribe)
        callback = (self._transport.send_batch if self._batch
                    else self._transport.send_message)
        errors: list[BaseException] = []
        for t in self._topics:
            try:
                unsub(t, callback)
            except ValueError:
                pass        # bus.close() already dropped the subscription
            except BaseException as e:  # noqa: BLE001 - collect, finish
                errors.append(e)
        try:
            self._transport.close()
        except BaseException as e:      # noqa: BLE001 - collect, finish
            errors.append(e)
        if errors:
            raise errors[0]

    def __enter__(self) -> "BusBridge":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class RosPlay:
    """Publish a bag's messages to the bus in global timestamp order.

    ``rate``: None = as fast as possible (simulation mode); otherwise a
    real-time factor (1.0 = original timing) — timing is derived from message
    timestamps like ``rosbag play``.
    """

    def __init__(self, bag: Bag, bus: MessageBus,
                 topics: Optional[Sequence[str]] = None,
                 rate: Optional[float] = None,
                 chunk_range: Optional[tuple[int, int]] = None,
                 start: Optional[int] = None,
                 end: Optional[int] = None):
        self._bag = bag
        self._bus = bus
        self._topics = topics
        self._rate = rate
        self._chunk_range = chunk_range
        self._start = start
        self._end = end
        self.messages_played = 0

    def _time_ordered(self) -> Iterable[Message]:
        """Bag chunks are time-ordered per-chunk but may interleave across
        topic boundaries; :func:`repro.core.bag.iter_time_ordered` merge-sorts
        on a small heap window to keep global order without materialising
        the partition."""
        return iter_time_ordered(self._bag, topics=self._topics,
                                 chunk_range=self._chunk_range,
                                 start=self._start, end=self._end)

    def run(self, prefetch: int = 0) -> int:
        """Per-message replay.  ``prefetch > 0`` moves bag reading (chunk
        decode + heap-window ordering) onto a background reader thread
        buffering up to ``prefetch`` messages ahead of the publish loop —
        the read stage of the staged pipeline."""
        it: Iterable[Message] = self._time_ordered()
        if prefetch:
            from repro.data.pipeline import PrefetchIterator
            it = PrefetchIterator(iter(it), depth=prefetch)
        pubs: dict[str, Publisher] = {}
        t0_msg: Optional[int] = None
        t0_wall = time.monotonic()
        # tracing is chunk-granular: one ``play.read`` span per
        # TRACE_CHUNK messages covers read+decode+publish of the chunk
        tr = otrace.TRACER
        slot: Optional[list] = None
        chunk = 0
        try:
            for msg in it:
                if tr is not None and slot is None:
                    slot = tr.begin("play.read", "play")
                if self._rate is not None:
                    if t0_msg is None:
                        t0_msg = msg.timestamp
                    target = (msg.timestamp - t0_msg) / 1e9 / self._rate
                    delay = target - (time.monotonic() - t0_wall)
                    if delay > 0:
                        time.sleep(delay)
                pub = pubs.get(msg.topic)
                if pub is None:
                    pub = pubs[msg.topic] = self._bus.advertise(msg.topic)
                pub.publish_message(msg)
                self.messages_played += 1
                if slot is not None:
                    chunk += 1
                    if chunk >= TRACE_CHUNK:
                        otrace.Tracer.set_attrs(slot, {"n": chunk})
                        otrace.Tracer.end(slot)
                        slot = None
                        chunk = 0
        finally:
            if slot is not None:
                otrace.Tracer.set_attrs(slot, {"n": chunk})
                otrace.Tracer.end(slot)
            close = getattr(it, "close", None)
            if close is not None:       # stop an abandoned reader thread
                close()
        return self.messages_played

    def run_batched(self, batch_size: int, prefetch: int = 0) -> int:
        """Vectorized replay: publish timestamp-ordered micro-batches of up
        to ``batch_size`` messages via :meth:`MessageBus.publish_batch`.

        Wall-clock pacing (``rate``) applies at batch boundaries, keyed on
        the first timestamp of each batch — the array-step analogue of
        per-message pacing.  ``prefetch > 0`` double-buffers the framing:
        a background reader thread keeps up to ``prefetch`` micro-batches
        assembled ahead of the publish loop, so bag I/O overlaps the
        consumers (``prefetch=2`` is classic double buffering).
        """
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        from repro.data.pipeline import iter_message_batches
        t0_msg: Optional[int] = None
        t0_wall = time.monotonic()
        it = iter_message_batches(self._time_ordered(), batch_size,
                                  prefetch=prefetch)
        tr = otrace.TRACER
        try:
            it_ = iter(it)
            while True:
                # traced at batch granularity: ``play.read`` bills framing
                # (bag read + decode + heap ordering), ``play.publish``
                # bills bus dispatch — the two halves of the replay stage
                if tr is not None:
                    r_slot = tr.begin("play.read", "play")
                    batch = next(it_, None)
                    otrace.Tracer.end(r_slot)
                else:
                    batch = next(it_, None)
                if batch is None:
                    break
                if self._rate is not None:
                    if t0_msg is None:
                        t0_msg = batch[0].timestamp
                    target = (batch[0].timestamp - t0_msg) / 1e9 / self._rate
                    delay = target - (time.monotonic() - t0_wall)
                    if delay > 0:
                        time.sleep(delay)
                if tr is not None:
                    p_slot = tr.begin("play.publish", "play",
                                      attrs={"n": len(batch)})
                    self.messages_played += self._bus.publish_batch(batch)
                    otrace.Tracer.end(p_slot)
                else:
                    self.messages_played += self._bus.publish_batch(batch)
        finally:
            close = getattr(it, "close", None)
            if close is not None:       # stop an abandoned reader thread
                close()
        return self.messages_played


class RosRecord:
    """Subscribe to topics and persist every message to a Bag.

    ``batch=True`` records through the batch subscription instead: one
    callback + one lock acquisition per micro-batch rather than per
    message, keeping the recorder off the per-message hot path of batched
    replay.  (Don't combine with per-message mode on the same bus — batched
    publishes would be recorded twice.)

    ``mode="queued"`` makes the recorder the sink stage of the staged
    pipeline: bag serialization runs on the recorder's own lane worker and
    overlaps replay/user logic instead of stalling them.  All of one
    recorder's subscriptions share a single lane (one writer thread), so
    the write order — and hence the recorded image — is exactly the
    synchronous one.  :meth:`stop` flushes the lane before unsubscribing,
    so every message published before ``stop()`` is in the bag when it
    returns.
    """

    def __init__(self, bus: MessageBus, bag: Bag,
                 topics: Optional[Sequence[str]] = None,
                 exclude_topics: Optional[Sequence[str]] = None,
                 batch: bool = False, mode: str = "sync",
                 queue_maxsize: Optional[int] = MessageBus.DEFAULT_MAXSIZE):
        self._bus = bus
        self._bag = bag
        self._topics = list(topics) if topics is not None else None
        self._exclude = set(exclude_topics or ())
        self._batch = batch
        self._mode = mode
        self._maxsize = queue_maxsize
        self._group = f"record-{id(self)}"
        self._cbs: list[tuple[Optional[str], Callback]] = []
        self._batch_cbs: list[tuple[Optional[str], BatchCallback]] = []
        self.messages_recorded = 0
        self._lock = threading.Lock()

    def start(self) -> None:
        # exclusion is enforced bus-side for the record-everything
        # subscription: excluded (replay input) traffic is never delivered
        # or enqueued, so it costs the hot path and the lane budget nothing;
        # the callback filter stays as backstop for per-topic subscriptions
        sub_kw = dict(mode=self._mode, maxsize=self._maxsize,
                      group=self._group)
        none_kw = dict(sub_kw, exclude_topics=self._exclude or None)
        if self._batch:
            def bcb(msgs: list[Message]) -> None:
                kept = [m for m in msgs if m.topic not in self._exclude]
                if not kept:
                    return
                tr = otrace.TRACER
                slot = (tr.begin("record.write", "record",
                                 attrs={"n": len(kept)})
                        if tr is not None else None)
                with self._lock:
                    for m in kept:
                        self._bag.write_message(m)
                    self.messages_recorded += len(kept)
                if slot is not None:
                    otrace.Tracer.end(slot)
            if self._topics is None:
                self._bus.subscribe_batch(None, bcb, **none_kw)
                self._batch_cbs.append((None, bcb))
            else:
                for t in self._topics:
                    self._bus.subscribe_batch(t, bcb, **sub_kw)
                    self._batch_cbs.append((t, bcb))
            return

        def cb(msg: Message) -> None:
            if msg.topic in self._exclude:
                return
            with self._lock:
                self._bag.write_message(msg)
                self.messages_recorded += 1
        if self._topics is None:
            self._bus.subscribe(None, cb, **none_kw)
            self._cbs.append((None, cb))
        else:
            for t in self._topics:
                self._bus.subscribe(t, cb, **sub_kw)
                self._cbs.append((t, cb))

    def stop(self) -> None:
        # bookkeeping first: a deferred lane error re-raised by unsubscribe
        # must not leave stale entries behind (a retried stop() would then
        # mask the real error with "not subscribed")
        cbs, self._cbs = self._cbs, []
        batch_cbs, self._batch_cbs = self._batch_cbs, []
        errors: list[BaseException] = []
        for t, cb in cbs:
            try:
                self._bus.unsubscribe(t, cb)
            except BaseException as e:      # noqa: BLE001 - collect, finish
                errors.append(e)
        for t, bcb in batch_cbs:
            try:
                self._bus.unsubscribe_batch(t, bcb)
            except BaseException as e:      # noqa: BLE001 - collect, finish
                errors.append(e)
        if errors:
            raise errors[0]

    def __enter__(self) -> "RosRecord":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
