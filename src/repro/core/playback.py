"""Playback engine: the ROS side of the platform (paper §2, Fig 5).

ROS is "a message pool architecture: the sending node advertises to a Topic,
the receiving node subscribes to a Topic".  We reproduce those semantics —
ordering and timing, which is what simulation correctness depends on — with
an in-process bus rather than TCPROS (see DESIGN.md §8).

``RosPlay``   reads a Bag (disk- or memory-backed) and publishes its
              messages in timestamp order, optionally paced by wall clock.
``RosRecord`` subscribes to topics and writes everything to a Bag.

Together with :mod:`repro.core.bag`'s ``MemoryChunkedFile`` these are the two
"missing links" of §3.2: play-from-memory and record-to-memory.
"""

from __future__ import annotations

import heapq
import threading
import time
from collections import defaultdict
from typing import Callable, Iterable, Optional, Sequence

from .bag import Bag, Message

Callback = Callable[[Message], None]


class Publisher:
    def __init__(self, bus: "MessageBus", topic: str):
        self._bus = bus
        self.topic = topic

    def publish(self, timestamp: int, data: bytes) -> None:
        self._bus._dispatch(Message(self.topic, timestamp, data))

    def publish_message(self, msg: Message) -> None:
        if msg.topic != self.topic:
            raise ValueError(f"publisher for {self.topic}, got {msg.topic}")
        self._bus._dispatch(msg)


class MessageBus:
    """Topic pub/sub message pool. Thread-safe; delivery is synchronous and
    in publish order (deterministic for tests and replay)."""

    def __init__(self):
        self._subs: dict[str, list[Callback]] = defaultdict(list)
        self._all: list[Callback] = []
        self._lock = threading.Lock()
        self.published = 0

    def advertise(self, topic: str) -> Publisher:
        return Publisher(self, topic)

    def subscribe(self, topic: Optional[str], callback: Callback) -> None:
        """``topic=None`` subscribes to every topic (rosbag record -a)."""
        with self._lock:
            if topic is None:
                self._all.append(callback)
            else:
                self._subs[topic].append(callback)

    def unsubscribe(self, topic: Optional[str], callback: Callback) -> None:
        with self._lock:
            if topic is None:
                self._all.remove(callback)
            else:
                self._subs[topic].remove(callback)

    def _dispatch(self, msg: Message) -> None:
        with self._lock:
            cbs = list(self._subs.get(msg.topic, ())) + list(self._all)
            self.published += 1
        for cb in cbs:
            cb(msg)


class RosPlay:
    """Publish a bag's messages to the bus in global timestamp order.

    ``rate``: None = as fast as possible (simulation mode); otherwise a
    real-time factor (1.0 = original timing) — timing is derived from message
    timestamps like ``rosbag play``.
    """

    def __init__(self, bag: Bag, bus: MessageBus,
                 topics: Optional[Sequence[str]] = None,
                 rate: Optional[float] = None,
                 chunk_range: Optional[tuple[int, int]] = None):
        self._bag = bag
        self._bus = bus
        self._topics = topics
        self._rate = rate
        self._chunk_range = chunk_range
        self.messages_played = 0

    def _time_ordered(self) -> Iterable[Message]:
        """Bag chunks are time-ordered per-chunk but may interleave across
        topic boundaries; merge-sort on a small heap window keeps global
        order without materialising the partition."""
        it = self._bag.read_messages(topics=self._topics,
                                     chunk_range=self._chunk_range)
        heap: list[tuple[int, int, Message]] = []
        seq = 0
        WINDOW = 4096
        for msg in it:
            heapq.heappush(heap, (msg.timestamp, seq, msg))
            seq += 1
            if len(heap) > WINDOW:
                yield heapq.heappop(heap)[2]
        while heap:
            yield heapq.heappop(heap)[2]

    def run(self) -> int:
        pubs: dict[str, Publisher] = {}
        t0_msg: Optional[int] = None
        t0_wall = time.monotonic()
        for msg in self._time_ordered():
            if self._rate is not None:
                if t0_msg is None:
                    t0_msg = msg.timestamp
                target = (msg.timestamp - t0_msg) / 1e9 / self._rate
                delay = target - (time.monotonic() - t0_wall)
                if delay > 0:
                    time.sleep(delay)
            pub = pubs.get(msg.topic)
            if pub is None:
                pub = pubs[msg.topic] = self._bus.advertise(msg.topic)
            pub.publish_message(msg)
            self.messages_played += 1
        return self.messages_played


class RosRecord:
    """Subscribe to topics and persist every message to a Bag."""

    def __init__(self, bus: MessageBus, bag: Bag,
                 topics: Optional[Sequence[str]] = None,
                 exclude_topics: Optional[Sequence[str]] = None):
        self._bus = bus
        self._bag = bag
        self._topics = list(topics) if topics is not None else None
        self._exclude = set(exclude_topics or ())
        self._cbs: list[tuple[Optional[str], Callback]] = []
        self.messages_recorded = 0
        self._lock = threading.Lock()

    def start(self) -> None:
        def cb(msg: Message) -> None:
            if msg.topic in self._exclude:
                return
            with self._lock:
                self._bag.write_message(msg)
                self.messages_recorded += 1
        if self._topics is None:
            self._bus.subscribe(None, cb)
            self._cbs.append((None, cb))
        else:
            for t in self._topics:
                self._bus.subscribe(t, cb)
                self._cbs.append((t, cb))

    def stop(self) -> None:
        for t, cb in self._cbs:
            self._bus.unsubscribe(t, cb)
        self._cbs.clear()

    def __enter__(self) -> "RosRecord":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
