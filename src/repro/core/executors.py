"""Pluggable executor backends for the scheduler (paper §3, Fig 3).

The :class:`~repro.core.scheduler.Scheduler` owns *scheduling semantics* —
retries, heartbeat fault detection, speculative re-execution, lineage — while
an :class:`ExecutorBackend` owns the *execution substrate*: where worker
loops actually run and how task payloads and reports move between them.

Two backends ship:

``ThreadBackend``
    The original in-process worker pool (one Python thread per worker,
    shared FIFO inbox).  Zero serialization cost; concurrency is limited by
    the GIL, so it shines for I/O- or latency-bound user logic (accelerator
    offload, simulated perception latency).

``ProcessBackend``
    One OS process per worker, each with a private duplex pipe to the
    driver.  CPU-bound user logic actually parallelizes; task functions,
    arguments and results must be picklable (use module-level functions, or
    a ``"module:attr"`` logic ref — see :mod:`repro.core.simulation`).

Both expose the same fault surface the scheduler's tests exercise:
``fail_after`` (crash on the Nth task, no report, no more heartbeats),
``slow_factor`` (straggler), ``kill_worker`` (node loss).  A backend also
reports ``lost_assignments`` — payloads shipped to a worker that died before
reporting — so the scheduler can requeue them immediately instead of waiting
for the heartbeat staleness sweep.
"""

from __future__ import annotations

import multiprocessing
import multiprocessing.connection
import os
import pickle
import queue
import secrets
import shutil
import tempfile
import threading
import time
from typing import Any, Callable, List, Optional, Union

from repro import chaos
from repro.obs import metrics as obs_metrics
from repro.obs import trace as otrace
from repro.shm import (
    SegmentHandle,
    SegmentPool,
    leaked_segments,
    read_segment,
    shm_available,
    unlink_segment,
    write_segment,
)

# payload shipped to a worker: (task_id, fn, args, attempt, trace_ctx)
# — trace_ctx is the driver-side dispatch span id (0 = tracing off);
# workers tolerate legacy 4-tuples
TaskPayload = tuple[int, Callable[..., Any], tuple, int, int]
# report(worker_id, task_id, attempt, result, error)
ReportFn = Callable[[str, int, int, Any, Optional[BaseException]], None]
# heartbeat(worker_id)
BeatFn = Callable[[str], None]

_POLL_S = 0.05


def _wants_worker_id(fn: Callable) -> bool:
    try:
        import inspect
        return "worker_id" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


def _execute(fn: Callable, args: tuple, worker_id: str) -> Any:
    if _wants_worker_id(fn):
        return fn(*args, worker_id=worker_id)
    return fn(*args)


class ExecutorBackend:
    """Interface the Scheduler drives.  Subclasses own the worker substrate."""

    name = "abstract"

    def start(self, report: ReportFn, heartbeat: BeatFn) -> None:
        """Wire driver callbacks; called once by the Scheduler before use."""
        raise NotImplementedError

    def submit(self, payload: TaskPayload) -> None:
        """Enqueue one task payload for any alive worker."""
        raise NotImplementedError

    def add_worker(self, worker_id: str, fail_after: Optional[int] = None,
                   slow_factor: float = 1.0) -> None:
        raise NotImplementedError

    def kill_worker(self, worker_id: str) -> None:
        """Simulate node loss: stop heartbeats; in-flight work is lost."""
        raise NotImplementedError

    def remove_worker(self, worker_id: str) -> None:
        """Drop a worker from the pool (also how the scheduler reaps the
        dead); its unreported payloads stay visible via lost_assignments."""
        raise NotImplementedError

    def worker_ids(self) -> list[str]:
        raise NotImplementedError

    def worker_alive(self, worker_id: str) -> bool:
        raise NotImplementedError

    def num_alive(self) -> int:
        return sum(1 for w in self.worker_ids() if self.worker_alive(w))

    def lost_assignments(self, worker_id: str) -> list[tuple[int, int]]:
        """(task_id, attempt) pairs shipped to ``worker_id`` and never
        reported — recompute candidates after its death."""
        raise NotImplementedError

    def shutdown(self, join_timeout: float = 5.0) -> None:
        """Stop the pool; wait up to ``join_timeout`` for quiesce."""
        raise NotImplementedError

    # -- driver-hosted transport endpoints ---------------------------------

    @property
    def endpoints(self) -> list:
        """RemoteBus listeners this backend hosts (see
        :meth:`host_endpoint`); stopped at :meth:`shutdown`."""
        eps = getattr(self, "_endpoints", None)
        if eps is None:
            eps = []
            setattr(self, "_endpoints", eps)
        return eps

    def host_endpoint(self, bus=None, sink=None,
                      window: Optional[int] = None) -> tuple[str, int]:
        """Start a :class:`repro.net.transport.RemoteBus` listener owned
        by this backend and return its ``(host, port)``.

        The backend is the natural host: it already brokers everything
        between driver and workers (task payloads, spilled args), so the
        endpoints workers stream topic traffic back through share its
        lifecycle — :meth:`shutdown` stops them with the pool.  The suite
        hands workers the returned address alongside their (possibly
        spilled) task args; the workers connect with
        :meth:`repro.net.transport.LaneTransport.connect`.
        """
        from repro.net.transport import RemoteBus   # lazy: core never
        kw = {} if window is None else {"window": window}   # imports net
        ep = RemoteBus(bus=bus, sink=sink, **kw)            # at load time
        ep.start()
        self.endpoints.append(ep)
        return ep.address

    def stop_endpoints(self) -> None:
        eps = list(self.endpoints)
        self.endpoints.clear()
        for ep in eps:
            ep.stop()


# ---------------------------------------------------------------------------
# Thread backend (the seed Worker pool, now behind the interface)
# ---------------------------------------------------------------------------


class Worker(threading.Thread):
    """A simulated cluster worker (thread).

    Fault injection for tests/benchmarks:
      ``fail_after``  : crash on the Nth task it executes (no report),
      ``slow_factor`` : multiply user-logic sleep time (straggler),
      ``kill()``      : stop heartbeating and accepting work (node loss).
    """

    def __init__(self, worker_id: str, inbox: "queue.Queue",
                 report: ReportFn, heartbeat: BeatFn,
                 fail_after: Optional[int] = None,
                 slow_factor: float = 1.0):
        super().__init__(name=f"worker-{worker_id}", daemon=True)
        self.worker_id = worker_id
        self._inbox = inbox
        self._report = report
        self._heartbeat = heartbeat
        self._fail_after = fail_after
        self.slow_factor = slow_factor
        self._alive = True
        self._executed = 0
        self.current: Optional[tuple[int, int]] = None  # (task_id, attempt)

    def kill(self) -> None:
        self._alive = False

    @property
    def is_alive_worker(self) -> bool:
        return self._alive

    def run(self) -> None:
        while True:
            if not self._alive:
                return                # dead node: stop consuming work
            try:
                item = self._inbox.get(timeout=_POLL_S)
            except queue.Empty:
                self._heartbeat(self.worker_id)
                continue
            if item is None:          # shutdown sentinel
                return
            task_id, fn, args, attempt = item[:4]
            ctx = item[4] if len(item) > 4 else 0
            self.current = (task_id, attempt)
            if not self._alive:
                # died between get() and here: this one task is lost
                return
            self._heartbeat(self.worker_id)
            self._executed += 1
            plan = chaos.active_plan()
            if plan is not None and plan.probe("worker_crash",
                                               self.worker_id) is not None:
                self._alive = False   # injected node crash mid-task
                continue
            if self._fail_after is not None and self._executed >= self._fail_after:
                self._alive = False   # crash: no report, no more heartbeats
                continue
            if self.slow_factor > 1.0:
                # stragglers burn extra wall time before doing the work
                time.sleep(0.001 * (self.slow_factor - 1.0))
            # ``task.run`` span brackets user logic; in a thread worker the
            # records land directly in the driver tracer (task_end ships
            # nothing)
            slot = otrace.task_begin(
                ctx, attrs={"task": task_id,
                            "worker": self.worker_id}) if ctx else None
            try:
                result = _execute(fn, args, self.worker_id)
                if slot is not None:
                    otrace.task_end(slot)
                self.current = None
                self._report(self.worker_id, task_id, attempt, result, None)
            except BaseException as e:   # noqa: BLE001 - report any failure
                if slot is not None:
                    otrace.task_end(slot)
                self.current = None
                self._report(self.worker_id, task_id, attempt, None, e)


class ThreadBackend(ExecutorBackend):
    """Shared-queue thread pool: the seed execution model.

    Heartbeats are decoupled from task execution (like a real node's
    heartbeat daemon): a backend beater thread beats for every worker whose
    node is up, so a long-running task is a *straggler* (speculation's
    job), not a false node loss.  Killed/crashed workers stop beating.
    """

    name = "thread"

    def __init__(self):
        self._inbox: "queue.Queue" = queue.Queue()
        self._workers: dict[str, Worker] = {}
        self._lost: dict[str, list[tuple[int, int]]] = {}
        self._lock = threading.Lock()
        self._report: Optional[ReportFn] = None
        self._beat: Optional[BeatFn] = None
        self._stop = threading.Event()
        self._beater: Optional[threading.Thread] = None

    def start(self, report: ReportFn, heartbeat: BeatFn) -> None:
        # reset lifecycle state so a backend instance can be reused by a
        # fresh Scheduler after a previous shutdown
        self._stop = threading.Event()
        while True:          # drop stale sentinels/payloads from a past run
            try:
                self._inbox.get_nowait()
            except queue.Empty:
                break
        self._report = report
        self._beat = heartbeat
        self._beater = threading.Thread(target=self._beat_loop,
                                        name="threadbackend-beater",
                                        daemon=True)
        self._beater.start()

    def _beat_loop(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                alive = [wid for wid, w in self._workers.items()
                         if w.is_alive_worker]
            for wid in alive:
                self._beat(wid)
            self._stop.wait(_POLL_S)

    def submit(self, payload: TaskPayload) -> None:
        self._inbox.put(payload)

    def add_worker(self, worker_id: str, fail_after: Optional[int] = None,
                   slow_factor: float = 1.0) -> None:
        assert self._report is not None, "backend not started"
        w = Worker(worker_id, self._inbox, self._report, self._beat,
                   fail_after=fail_after, slow_factor=slow_factor)
        with self._lock:
            self._workers[worker_id] = w
        w.start()

    def kill_worker(self, worker_id: str) -> None:
        with self._lock:
            w = self._workers.get(worker_id)
        if w:
            w.kill()

    def remove_worker(self, worker_id: str) -> None:
        with self._lock:
            w = self._workers.pop(worker_id, None)
            # a live thread worker finishes and reports its current task
            # after a voluntary removal; only a dead one truly loses it
            if (w is not None and not w.is_alive_worker
                    and w.current is not None):
                self._lost.setdefault(worker_id, []).append(w.current)
        if w:
            w.kill()

    def worker_ids(self) -> list[str]:
        with self._lock:
            return list(self._workers)

    def worker_alive(self, worker_id: str) -> bool:
        with self._lock:
            w = self._workers.get(worker_id)
        return bool(w and w.is_alive_worker)

    def lost_assignments(self, worker_id: str) -> list[tuple[int, int]]:
        with self._lock:
            return self._lost.pop(worker_id, [])

    def shutdown(self, join_timeout: float = 5.0) -> None:
        self._stop.set()
        self.stop_endpoints()
        with self._lock:
            workers = list(self._workers.values())
            self._workers.clear()
        for w in workers:
            w.kill()
        for _ in workers:
            self._inbox.put(None)
        # quiesce: wait (bounded) for workers to finish their current task —
        # exiting the interpreter while a thread is inside native code (e.g.
        # a jitted user-logic step) aborts at teardown
        deadline = time.monotonic() + join_timeout
        for w in workers:
            w.join(timeout=max(0.0, deadline - time.monotonic()))


# ---------------------------------------------------------------------------
# Process backend
# ---------------------------------------------------------------------------


def _process_worker_main(worker_id: str, conn,
                         fail_after: Optional[int],
                         slow_factor: float,
                         spill_bytes: Optional[int] = None,
                         spill_dir: Optional[str] = None,
                         shm_prefix: Optional[str] = None) -> None:
    """Worker-process loop: recv task, execute, report.

    A daemon beater thread heartbeats continuously — like a node's
    heartbeat daemon, independent of task execution, so long tasks read as
    stragglers rather than node loss.  Crash semantics mirror the thread
    Worker: on ``fail_after`` the whole process exits without reporting
    (beater included — heartbeats stop), like a segfaulted node.

    Results whose pickle exceeds ``spill_bytes`` (partition bag images,
    merged scenario outputs) are spilled out-of-band: with ``shm_prefix``
    set the worker writes the pickle into a ``/dev/shm`` segment under
    the driver's pool prefix and ships only the
    :class:`~repro.shm.SegmentHandle` (one memcpy, no filesystem
    round-trip); when shm is unavailable or full it falls back to a temp
    file in ``spill_dir`` and ships the path.  Either way bulk payload
    bytes stay out of the result pipe.  The spill dir is created lazily
    on first file spill, so a suite that never file-spills leaves no
    empty directory behind.
    """
    send_lock = threading.Lock()

    def send(payload) -> bool:
        try:
            with send_lock:
                conn.send(payload)
            return True
        except (EOFError, OSError, BrokenPipeError):
            return False

    def beater() -> None:
        while send(("beat", worker_id)):
            time.sleep(_POLL_S)

    threading.Thread(target=beater, daemon=True).start()
    # a forked worker inherits the driver's tracer and metric values;
    # both belong to the driver timeline — drop them so this process
    # ships only its own spans and deltas
    otrace.disable()
    obs_metrics.snapshot(reset=True)
    executed = 0
    while True:
        try:
            if not conn.poll(_POLL_S):
                continue
            msg = conn.recv()
        except (EOFError, OSError):
            return                     # driver went away
        if msg is None:                # shutdown sentinel
            return
        task_id, fn, args, attempt = msg[:4]
        ctx = msg[4] if len(msg) > 4 else 0
        executed += 1
        # a forked worker inherits the driver's installed chaos plan, so
        # process-backend crash injection is deterministic per worker too
        plan = chaos.active_plan()
        if plan is not None and plan.probe("worker_crash",
                                           worker_id) is not None:
            os._exit(13)
        if fail_after is not None and executed >= fail_after:
            os._exit(13)               # crash: no report, pipe goes EOF
        if slow_factor > 1.0:
            time.sleep(0.001 * (slow_factor - 1.0))
        slot = otrace.task_begin(
            ctx, attrs={"task": task_id, "worker": worker_id}) if ctx else None
        try:
            result = _execute(fn, args, worker_id)
            error: Optional[BaseException] = None
        except BaseException as e:     # noqa: BLE001 - report any failure
            result, error = None, e
        # worker spans and metric deltas ride home with the result (and
        # through the spill path when the payload is bulky)
        records = otrace.task_end(slot) if slot is not None else []
        mdelta = obs_metrics.snapshot(reset=True)
        out = ("done", worker_id, task_id, attempt, result, error,
               records, mdelta)
        try:
            blob = pickle.dumps(out)
        except Exception as e:         # unpicklable result/exception
            send(("done", worker_id, task_id, attempt, None,
                  RuntimeError(f"unpicklable task output: {e!r}")))
            continue
        if spill_bytes is not None and len(blob) > spill_bytes:
            if shm_prefix is not None:
                # fast path: one memcpy into a segment under the driver's
                # pool prefix — a worker killed with the handle still in
                # the pipe leaves an orphan the driver's shutdown sweep
                # reaps by prefix
                try:
                    handle = write_segment(shm_prefix, blob)
                except OSError:
                    handle = None      # shm full/unavailable: temp file
                if handle is not None:
                    if send(("shm", worker_id, task_id, attempt, handle)):
                        continue
                    unlink_segment(handle)   # driver gone; don't leak
                    return
            spill_path = None
            try:
                # files live in the backend-owned spill dir, which the
                # driver removes wholesale at shutdown — a worker killed
                # with a spill message still in the pipe can't leak.
                # The dir itself is made lazily: reserved by the driver,
                # created only once something actually file-spills
                os.makedirs(spill_dir, mode=0o700, exist_ok=True)
                fd, spill_path = tempfile.mkstemp(prefix="repro-spill-",
                                                  suffix=".pkl",
                                                  dir=spill_dir)
                with os.fdopen(fd, "wb") as f:
                    f.write(blob)
                if send(("spill", worker_id, task_id, attempt, spill_path)):
                    continue
                os.unlink(spill_path)  # driver gone; don't leak the file
                return
            except OSError:            # disk trouble: fall through to pipe
                if spill_path is not None:
                    try:
                        os.unlink(spill_path)
                    except OSError:
                        pass
        try:
            with send_lock:
                conn.send_bytes(blob)
        except (EOFError, OSError, BrokenPipeError):
            return


class _ProcWorker:
    __slots__ = ("proc", "conn", "outstanding", "dead", "send_lock")

    def __init__(self, proc, conn):
        self.proc = proc
        self.conn = conn
        self.outstanding: dict[tuple[int, int], None] = {}
        self.dead = False
        # Connection.send is not safe for concurrent senders; the driver
        # thread (submit) and the pump thread both dispatch
        self.send_lock = threading.Lock()

    def send(self, payload) -> None:
        with self.send_lock:
            self.conn.send(payload)


class ProcessBackend(ExecutorBackend):
    """One OS process per worker, private duplex pipe each, driver-side pump.

    Dispatch is eager least-outstanding: a submitted payload is shipped to
    the alive worker with the fewest unreported payloads (payloads queue in
    the worker's pipe).  A pump thread multiplexes all pipes, translating
    worker messages into the scheduler's report/heartbeat callbacks.  Tasks
    must be picklable; results travel back through the pipe.
    """

    name = "process"

    #: results whose pickle exceeds this ride a temp file, not the pipe
    DEFAULT_SPILL_BYTES = 1 << 20

    def __init__(self, mp_context: Optional[str] = None,
                 spill_bytes: Optional[int] = DEFAULT_SPILL_BYTES,
                 shm: Optional[bool] = None):
        try:
            self._ctx = multiprocessing.get_context(mp_context or "fork")
        except ValueError:             # platform without fork
            self._ctx = multiprocessing.get_context()
        self.spill_bytes = spill_bytes       # None disables spilling
        self.shm = shm                       # None: auto-detect at first use
        self.spills = 0                      # result spills, any carrier
        self.arg_spills = 0                  # arg spills, any carrier
        self.shm_spills = 0                  # spills that rode /dev/shm
        self.shm_spill_bytes = 0
        self._shm_pool: Optional[SegmentPool] = None
        self._shm_last_prefix: Optional[str] = None
        self._spill_dir: Optional[str] = None
        self._last_spill_dir: Optional[str] = None
        self._workers: dict[str, _ProcWorker] = {}
        self._pending: list[TaskPayload] = []
        self._send_failures: list[tuple[TaskPayload, BaseException]] = []
        self._lock = threading.Lock()
        self._report: Optional[ReportFn] = None
        self._beat: Optional[BeatFn] = None
        self._stop = threading.Event()
        self._pump: Optional[threading.Thread] = None

    def start(self, report: ReportFn, heartbeat: BeatFn) -> None:
        # reset lifecycle state so a backend instance can be reused by a
        # fresh Scheduler after a previous shutdown
        self._stop = threading.Event()
        with self._lock:
            self._pending.clear()
            self._send_failures.clear()
        self._report = report
        self._beat = heartbeat
        self._pump = threading.Thread(target=self._pump_loop,
                                      name="procbackend-pump", daemon=True)
        self._pump.start()

    # -- argument spill ----------------------------------------------------

    def _shm_enabled(self) -> bool:
        """Resolve the ``shm`` tri-state once (None = probe the host)."""
        if self.shm is None:
            self.shm = shm_available()
        return self.shm

    def _shm_prefix(self) -> Optional[str]:
        """Lazily create the driver-owned segment pool; its prefix is
        what workers stamp their result-spill segments with, so one
        prefix sweep at shutdown reaps both sides' orphans."""
        if not self._shm_enabled():
            return None
        if self._shm_pool is None:
            self._shm_pool = SegmentPool()
        return self._shm_pool.prefix

    def _reserve_spill_dir(self) -> str:
        """Reserve a spill-dir *path* without creating the directory:
        whoever spills a file first (worker or driver) makedirs it, so a
        suite that never file-spills leaves nothing on disk."""
        if self._spill_dir is None:
            self._spill_dir = os.path.join(
                tempfile.gettempdir(),
                f"repro-spill-{os.getpid()}-{secrets.token_hex(4)}")
        return self._spill_dir

    def spill_arg(self, data: bytes) -> Union[str, SegmentHandle]:
        """Park a bulk task *argument* out-of-band; returns the reference
        to ship instead of the bytes — a :class:`~repro.shm.SegmentHandle`
        when the shared-memory pool is usable, else a temp-file path.

        The driver-side twin of the worker result spill: schedulers that
        would otherwise pickle MB-sized blobs (partition bag images bound
        for an aggregate task) through a worker pipe park them once and
        pass the reference.  On the shm path the blob is one memcpy into
        a ref-counted pool segment; on the file path it is written
        verbatim (a memory-bag image *is* the on-disk bag format, so the
        spill file doubles as an openable bag).  Either way the spill
        persists until :meth:`reclaim_spill` or the :meth:`shutdown`
        sweep, which is what makes task retry and speculation safe: a
        recomputed task re-reads the same reference.
        """
        if self._shm_enabled():
            if self._shm_pool is None:
                self._shm_pool = SegmentPool()
            try:
                handle = self._shm_pool.put(data)
            except OSError:
                pass                   # shm full/gone: temp-file fallback
            else:
                self.arg_spills += 1
                self.shm_spills += 1
                self.shm_spill_bytes += handle.size
                return handle
        path_dir = self._reserve_spill_dir()
        os.makedirs(path_dir, mode=0o700, exist_ok=True)
        fd, path = tempfile.mkstemp(prefix="repro-arg-", suffix=".bag",
                                    dir=path_dir)
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        self.arg_spills += 1
        return path

    def reclaim_spill(self, ref: Union[str, SegmentHandle]) -> None:
        """Release one spilled reference once every consumer is done.

        The shutdown-time sweep is the backstop; this is the eager path
        the scenario suite calls per scenario (after its aggregate/import
        task reports, and on the error path), so a long suite's spill
        footprint stays O(in-flight scenario) instead of growing one
        artifact per spilled image until teardown.  Tolerant by design:
        reclaiming an already-unlinked path or an unknown handle is a
        no-op, and unlinking a reference a straggling speculative attempt
        still has open is safe (POSIX) — an attempt that opens *after*
        the unlink fails, and the scheduler ignores failures of
        already-completed tasks.
        """
        if isinstance(ref, SegmentHandle):
            if self._shm_pool is not None:
                self._shm_pool.release(ref)
            else:
                unlink_segment(ref)
            return
        try:
            os.unlink(ref)
        except OSError:
            pass

    def spill_leaks(self) -> List[str]:
        """Spill artifacts still alive — the leak-check assertion hook;
        after :meth:`shutdown` this must be empty (crash-safety
        acceptance criterion), and mid-run it lists exactly the
        in-flight spill set."""
        leaks: List[str] = []
        pool = self._shm_pool
        prefix = pool.prefix if pool is not None else self._shm_last_prefix
        if prefix is not None:
            leaks += leaked_segments(prefix)
        if pool is not None:
            # free-list segments are pool-owned recycling capacity, not
            # in-flight spills; shutdown reaps them
            parked = set(pool.parked())
            leaks = [n for n in leaks if n not in parked]
        for d in (self._spill_dir, self._last_spill_dir):
            if d is not None and os.path.isdir(d):
                leaks += sorted(os.path.join(d, n) for n in os.listdir(d))
                break
        return leaks

    # -- dispatch ----------------------------------------------------------

    def submit(self, payload: TaskPayload) -> None:
        with self._lock:
            self._pending.append(payload)
        self._assign_pending()

    def _assign_pending(self) -> None:
        with self._lock:
            alive = [w for w in self._workers.values()
                     if not w.dead and w.proc.is_alive()]
            if not alive:
                return
            pending, self._pending = self._pending, []
            targets: list[tuple[_ProcWorker, TaskPayload]] = []
            for payload in pending:
                w = min(alive, key=lambda w: len(w.outstanding))
                w.outstanding[(payload[0], payload[3])] = None
                targets.append((w, payload))
        for w, payload in targets:
            try:
                w.send(payload)
            except (EOFError, OSError, BrokenPipeError):
                # worker died under us: payload stays in outstanding and is
                # recovered through lost_assignments when the scheduler reaps
                with self._lock:
                    w.dead = True
            except Exception as e:     # unpicklable fn/args: fail the task,
                with self._lock:       # not the dispatcher.  Reported from
                    # the pump thread — reporting here would re-enter the
                    # scheduler lock through retry -> dispatch -> submit
                    w.outstanding.pop((payload[0], payload[3]), None)
                    self._send_failures.append((payload, e))

    # -- pump --------------------------------------------------------------

    def _pump_loop(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                failures, self._send_failures = self._send_failures, []
                conns = {w.conn: w for w in self._workers.values()
                         if not w.dead}
            for payload, e in failures:
                self._report("driver", payload[0], payload[3], None,
                             RuntimeError(f"task not picklable for process "
                                          f"backend: {e!r}"))
            if not conns:
                time.sleep(_POLL_S / 5)
                continue
            try:
                ready = multiprocessing.connection.wait(
                    list(conns), timeout=_POLL_S / 2)
            except OSError:
                continue
            for conn in ready:
                w = conns[conn]
                try:
                    msg = conn.recv()
                except (EOFError, OSError):
                    with self._lock:
                        w.dead = True  # heartbeats stop; scheduler reaps
                    continue
                if msg[0] == "beat":
                    self._beat(msg[1])
                    continue
                if msg[0] == "shm":
                    # bulk result parked in a shared-memory segment by the
                    # worker: copy out and unlink in one attach
                    _, wid, task_id, attempt, handle = msg
                    try:
                        blob = read_segment(handle, unlink=True)
                        msg = pickle.loads(blob)
                        self.spills += 1
                        self.shm_spills += 1
                        self.shm_spill_bytes += len(blob)
                    except Exception as e:     # gone/stale segment: retry
                        msg = ("done", wid, task_id, attempt, None,
                               RuntimeError(f"shm result spill unreadable: "
                                            f"{e!r}"))
                if msg[0] == "spill":
                    # bulk result parked in a temp file: load and unlink
                    _, wid, task_id, attempt, spill_path = msg
                    try:
                        with open(spill_path, "rb") as f:
                            msg = pickle.load(f)
                        self.spills += 1
                    except Exception as e:     # lost/corrupt spill: retry
                        msg = ("done", wid, task_id, attempt, None,
                               RuntimeError(f"result spill unreadable: "
                                            f"{e!r}"))
                    finally:
                        try:
                            os.unlink(spill_path)
                        except OSError:
                            pass
                if msg[0] == "done":
                    wid, task_id, attempt, result, error = msg[1:6]
                    if len(msg) > 6:
                        # stitch worker spans into the driver timeline and
                        # fold the worker's metric delta into the registry
                        otrace.ingest(msg[6])
                        obs_metrics.absorb(msg[7])
                    with self._lock:
                        w.outstanding.pop((task_id, attempt), None)
                    self._report(wid, task_id, attempt, result, error)
            self._assign_pending()

    # -- membership --------------------------------------------------------

    def add_worker(self, worker_id: str, fail_after: Optional[int] = None,
                   slow_factor: float = 1.0) -> None:
        spill_dir = shm_prefix = None
        if self.spill_bytes is not None:
            spill_dir = self._reserve_spill_dir()   # path only, no mkdir
            shm_prefix = self._shm_prefix()
        parent, child = self._ctx.Pipe(duplex=True)
        proc = self._ctx.Process(
            target=_process_worker_main,
            args=(worker_id, child, fail_after, slow_factor,
                  self.spill_bytes, spill_dir, shm_prefix),
            name=f"worker-{worker_id}", daemon=True)
        proc.start()
        child.close()
        with self._lock:
            self._workers[worker_id] = _ProcWorker(proc, parent)
        self._assign_pending()

    def kill_worker(self, worker_id: str) -> None:
        with self._lock:
            w = self._workers.get(worker_id)
            if w is None:
                return
            w.dead = True
        w.proc.terminate()

    def remove_worker(self, worker_id: str) -> None:
        with self._lock:
            w = self._workers.get(worker_id)
            if w is None:
                return
            w.dead = True
        try:
            w.send(None)
        except (EOFError, OSError, BrokenPipeError):
            pass
        w.proc.terminate()

    def worker_ids(self) -> list[str]:
        with self._lock:
            return list(self._workers)

    def worker_alive(self, worker_id: str) -> bool:
        with self._lock:
            w = self._workers.get(worker_id)
            return bool(w and not w.dead and w.proc.is_alive())

    def lost_assignments(self, worker_id: str) -> list[tuple[int, int]]:
        with self._lock:
            w = self._workers.pop(worker_id, None)
            if w is None:
                return []
            lost = list(w.outstanding)
        try:
            w.conn.close()
        except OSError:
            pass
        return lost

    def shutdown(self, join_timeout: float = 5.0) -> None:
        self._stop.set()
        self.stop_endpoints()
        if self._pump is not None:
            self._pump.join(timeout=1.0)
        with self._lock:
            workers = list(self._workers.values())
            self._workers.clear()
        for w in workers:
            try:
                w.send(None)
            except (EOFError, OSError, BrokenPipeError):
                pass
        deadline = time.monotonic() + min(join_timeout, 1.0)
        for w in workers:
            w.proc.join(timeout=max(0.0, deadline - time.monotonic()))
        # escalation ladder for workers that ignored the sentinel (wedged
        # in user logic, blocked on a pipe): SIGTERM, then SIGKILL — a
        # shutdown must never leave live worker processes behind
        stubborn = [w for w in workers if w.proc.is_alive()]
        for w in stubborn:
            w.proc.terminate()
        for w in stubborn:
            w.proc.join(timeout=1.0)
            if w.proc.is_alive():
                w.proc.kill()
                w.proc.join(timeout=1.0)
        for w in workers:
            try:
                w.conn.close()
            except OSError:
                pass
        # crash-safe spill reaping, after every worker is provably gone so
        # no straggler re-creates an artifact behind the sweep.  Both arms
        # are idempotent: a second shutdown() finds nothing to do.
        pool, self._shm_pool = self._shm_pool, None
        if pool is not None:
            # unlinks registered segments *and* prefix-sweeps /dev/shm for
            # orphans from workers killed with a handle still in the pipe
            self._shm_last_prefix = pool.prefix
            pool.shutdown()
        if self._spill_dir is not None:
            # reap spill files orphaned by killed workers / unread pipes
            shutil.rmtree(self._spill_dir, ignore_errors=True)
            self._last_spill_dir, self._spill_dir = self._spill_dir, None


def make_backend(backend: "str | ExecutorBackend") -> ExecutorBackend:
    """Resolve a backend spec: an instance, ``"thread"``, or ``"process"``."""
    if isinstance(backend, ExecutorBackend):
        return backend
    if backend == "thread":
        return ThreadBackend()
    if backend == "process":
        return ProcessBackend()
    raise ValueError(f"unknown executor backend {backend!r}")
