"""Low-overhead span tracer with per-thread lock-free ring buffers.

Design constraints (in priority order):

1. **Strictly no-op when disabled.**  Every instrumented seam is
   ``tr = trace.TRACER`` + an ``if tr is not None`` check — one global
   read, no call, no allocation.  This mirrors the proven
   ``chaos.active_plan()`` idiom.
2. **Allocation-light when enabled.**  Each thread owns a private ring
   of **preallocated slot lists**; :meth:`Tracer.begin` claims the next
   slot and mutates it in place, :meth:`Tracer.end` stamps ``t1``.  No
   locks on the hot path (the ring is single-writer by construction),
   no per-span object churn — the ring wraps, overwriting the oldest
   records (flight-recorder semantics, ``dropped`` counts the loss).
3. **One timeline across processes.**  ``perf_counter_ns`` is
   CLOCK_MONOTONIC on Linux — the same epoch for every process on the
   host — so driver and worker timestamps interleave directly.  Span
   ids embed ``(pid, buffer index, seq)`` and are unique host-wide;
   context is just the parent span id (an int), cheap to put in a task
   payload or an 8-byte wire frame annotation.

Record layout (one slot / one drained tuple)::

    (span_id, parent_id, name, cat, t0_ns, t1_ns, pid, tid, attrs)

``cat`` is the seam taxonomy used by ``repro.tools.trace_report``:
``sched`` / ``lane`` / ``play`` / ``logic`` / ``record`` /
``transport`` / ``shm`` / ``cache`` / ``agg`` / ``suite``.

Worker processes never export: :func:`task_begin` / :func:`task_end`
bracket one task, and ``task_end`` drains the local rings so the
records ride home on the existing result/spill path, where the driver
:meth:`Tracer.ingest`-s them into the suite timeline.
"""

from __future__ import annotations

import os
import threading
from contextlib import contextmanager
from time import perf_counter_ns
from typing import Iterable, List, Optional, Tuple

__all__ = [
    "TRACER", "SpanRecord", "Tracer", "disable", "enable", "enabled",
    "get_tracer", "ingest", "span", "task_begin", "task_end",
]

#: drained/normalised span tuple (see module docstring)
SpanRecord = Tuple[int, int, str, str, int, int, int, int, Optional[dict]]

# slot indices
_ID, _PARENT, _NAME, _CAT, _T0, _T1, _ATTRS = range(7)

#: per-thread ring capacity (slots); a slot is ~200 B of list + refs
DEFAULT_CAPACITY = 1 << 14


class _Buf:
    """One thread's private span ring (single writer, drained at
    quiescent points)."""

    __slots__ = ("pid", "tid", "slots", "cap", "pos", "seq", "prefix",
                 "dropped", "stack")

    def __init__(self, pid: int, tid: int, index: int, cap: int):
        self.pid = pid
        self.tid = tid
        self.cap = cap
        # preallocated, reused in place; t0 == 0 marks an empty slot
        self.slots = [[0, 0, "", "", 0, 0, None] for _ in range(cap)]
        self.pos = 0
        self.seq = 0
        # pid/buffer-index prefix keeps ids unique across the host
        self.prefix = (pid % 1_000_000) * 10**12 + index * 10**9
        self.dropped = 0
        self.stack: List[int] = []      # ambient context (span() only)


class Tracer:
    """Process-local span recorder; install via :func:`enable`.

    ``default_parent`` roots every span begun with ``parent=None`` and
    an empty ambient stack — helper threads (lane workers, net pumps)
    thus attach to the run root instead of orphaning.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 root_name: str = "trace", root_parent: int = 0):
        self.capacity = capacity
        self.pid = os.getpid()
        self._local = threading.local()
        self._bufs: List[_Buf] = []
        self._foreign: List[SpanRecord] = []    # ingested worker records
        self._lock = threading.Lock()
        self._worker = False        # True on executor-worker tracers
        # the root span: open from construction until drain_all()
        self._root_slot = self.begin(root_name, "suite", parent=root_parent)
        self.root_id = self._root_slot[_ID]
        self.default_parent = self.root_id

    # -- buffers -------------------------------------------------------------

    def _buf(self) -> _Buf:
        buf = getattr(self._local, "buf", None)
        if buf is None:
            with self._lock:
                buf = _Buf(self.pid, threading.get_ident(),
                           len(self._bufs), self.capacity)
                self._bufs.append(buf)
            self._local.buf = buf
        return buf

    # -- hot path ------------------------------------------------------------

    def begin(self, name: str, cat: str, parent: Optional[int] = None,
              attrs: Optional[dict] = None) -> list:
        """Open a span; returns the slot to pass to :meth:`end`."""
        buf = getattr(self._local, "buf", None)
        if buf is None:
            buf = self._buf()
        i = buf.pos
        buf.pos = 0 if i + 1 == buf.cap else i + 1
        slot = buf.slots[i]
        if slot[_T0] != 0:              # ring wrapped: oldest record lost
            buf.dropped += 1
        buf.seq += 1
        if parent is None:
            parent = buf.stack[-1] if buf.stack else self.default_parent
        slot[_ID] = buf.prefix + buf.seq
        slot[_PARENT] = parent
        slot[_NAME] = name
        slot[_CAT] = cat
        slot[_T1] = 0
        slot[_ATTRS] = attrs
        slot[_T0] = perf_counter_ns()
        return slot

    @staticmethod
    def end(slot: list) -> None:
        slot[_T1] = perf_counter_ns()

    @staticmethod
    def span_id(slot: list) -> int:
        return slot[_ID]

    @staticmethod
    def set_attrs(slot: list, attrs: Optional[dict]) -> None:
        """Attach/replace a span's attrs — for burst spans whose counts
        are only known at close."""
        slot[_ATTRS] = attrs

    def instant(self, name: str, cat: str, parent: Optional[int] = None,
                attrs: Optional[dict] = None) -> int:
        """A zero-duration marker span; returns its id."""
        slot = self.begin(name, cat, parent=parent, attrs=attrs)
        slot[_T1] = slot[_T0]
        return slot[_ID]

    def emit(self, name: str, cat: str, t0: int, t1: int,
             parent: Optional[int] = None,
             attrs: Optional[dict] = None) -> int:
        """Record an already-completed span with explicit timestamps —
        for seams that only know a span happened after the fact (e.g. a
        blocking recv that should not bill its idle wait).  Returns the
        span id."""
        slot = self.begin(name, cat, parent=parent, attrs=attrs)
        slot[_T0] = t0
        slot[_T1] = t1
        return slot[_ID]

    # -- ambient context -----------------------------------------------------

    def ctx(self) -> int:
        """The current context span id — what to propagate into a task
        payload or a wire frame annotation."""
        buf = getattr(self._local, "buf", None)
        if buf is not None and buf.stack:
            return buf.stack[-1]
        return self.default_parent

    def push(self, span_id: int) -> None:
        self._buf().stack.append(span_id)

    def pop(self) -> None:
        buf = getattr(self._local, "buf", None)
        if buf is not None and buf.stack:
            buf.stack.pop()

    @contextmanager
    def span(self, name: str, cat: str = "suite",
             parent: Optional[int] = None, attrs: Optional[dict] = None):
        """Context manager for non-hot paths; nested spans on the same
        thread parent automatically."""
        slot = self.begin(name, cat, parent=parent, attrs=attrs)
        self.push(slot[_ID])
        try:
            yield slot
        finally:
            self.pop()
            self.end(slot)

    # -- collection ----------------------------------------------------------

    def ingest(self, records: Iterable[SpanRecord]) -> None:
        """Adopt records drained in another process (shipped back on the
        task result path) into this timeline."""
        with self._lock:
            self._foreign.extend(tuple(r) for r in records)

    def drain(self) -> List[SpanRecord]:
        """Collect and consume every finished (and still-open) record
        from this process's rings.  Call at quiescent points only —
        task end in a worker, suite end on the driver."""
        out: List[SpanRecord] = []
        with self._lock:
            bufs = list(self._bufs)
        for buf in bufs:
            pid, tid = buf.pid, buf.tid
            for slot in buf.slots:
                if slot[_T0] == 0:
                    continue
                if slot is self._root_slot and slot[_T1] == 0:
                    continue            # root stays open until drain_all
                out.append((slot[_ID], slot[_PARENT], slot[_NAME],
                            slot[_CAT], slot[_T0], slot[_T1], pid, tid,
                            slot[_ATTRS]))
                slot[_T0] = 0
                slot[_ATTRS] = None
        return out

    def drain_all(self) -> List[SpanRecord]:
        """Close the root span and return the full stitched timeline:
        local rings plus every ingested worker buffer."""
        if self._root_slot[_T1] == 0:
            self.end(self._root_slot)
        out = self.drain()
        with self._lock:
            out.extend(self._foreign)
            self._foreign = []
        return out

    @property
    def dropped(self) -> int:
        with self._lock:
            return sum(buf.dropped for buf in self._bufs)


#: the process-wide tracer; ``None`` = tracing disabled (the hot-path
#: check every seam performs).  Import the module, not the name:
#: ``from repro.obs import trace as otrace`` ... ``otrace.TRACER``.
TRACER: Optional[Tracer] = None

_install_lock = threading.Lock()


def enable(capacity: int = DEFAULT_CAPACITY, root_name: str = "trace",
           root_parent: int = 0) -> Tracer:
    """Install a fresh process-wide tracer (replacing any other)."""
    global TRACER
    with _install_lock:
        TRACER = Tracer(capacity=capacity, root_name=root_name,
                        root_parent=root_parent)
    return TRACER


def disable() -> None:
    global TRACER
    with _install_lock:
        TRACER = None


def enabled() -> bool:
    return TRACER is not None


def get_tracer() -> Optional[Tracer]:
    return TRACER


def ingest(records: Iterable[SpanRecord]) -> None:
    """Module-level convenience: adopt worker records if tracing is on."""
    tr = TRACER
    if tr is not None and records:
        tr.ingest(records)


@contextmanager
def span(name: str, cat: str = "suite", parent: Optional[int] = None,
         attrs: Optional[dict] = None):
    """No-op context manager when disabled; otherwise
    :meth:`Tracer.span`."""
    tr = TRACER
    if tr is None:
        yield None
        return
    with tr.span(name, cat, parent=parent, attrs=attrs) as slot:
        yield slot


# -- worker-side task bracket -------------------------------------------------

def task_begin(ctx: int, name: str = "task.run",
               attrs: Optional[dict] = None) -> Optional[list]:
    """Called by an executor worker when a payload carries trace context
    ``ctx`` (the driver-side dispatch span id).  In a thread-backend
    worker the driver tracer is already in place and the new span simply
    nests under ``ctx``.  In a process-backend worker (detected by a
    pid mismatch on the inherited tracer, or no tracer at all) a fresh
    worker tracer is installed, rooted at ``ctx``, so helper threads
    spawned during the task attach under it.
    """
    global TRACER
    tr = TRACER
    if tr is None or tr.pid != os.getpid():
        # worker tracer: no root span of its own — ctx is the root
        with _install_lock:
            tr = TRACER
            if tr is None or tr.pid != os.getpid():
                tr = Tracer.__new__(Tracer)
                tr.capacity = DEFAULT_CAPACITY
                tr.pid = os.getpid()
                tr._local = threading.local()
                tr._bufs = []
                tr._foreign = []
                tr._lock = threading.Lock()
                tr._worker = True
                tr._root_slot = [0, 0, "", "", 0, 0, None]
                tr.root_id = ctx
                tr.default_parent = ctx
                TRACER = tr
    if tr._worker:
        tr.default_parent = ctx     # one task at a time per worker
    slot = tr.begin(name, "sched", parent=ctx, attrs=attrs)
    tr.push(slot[_ID])
    return slot


def task_end(slot: Optional[list]) -> List[SpanRecord]:
    """Close the ``task.run`` span; in a process-backend worker, drain
    the local rings so the records ride back to the driver with the
    task result (a thread-backend worker's records are already in the
    driver tracer — nothing to ship)."""
    tr = TRACER
    if tr is None or slot is None:
        return []
    tr.pop()
    tr.end(slot)
    return tr.drain() if tr._worker else []
