"""Chrome/Perfetto trace export.

Emits the Trace Event JSON format (``{"traceEvents": [...]}``) that
https://ui.perfetto.dev and ``chrome://tracing`` load directly: one
complete (``"ph": "X"``) event per drained span, microsecond
timestamps, plus metadata events naming each process (driver vs
worker pids) and thread.  Span ids and parent ids ride in ``args`` so
the stitched parent/child structure survives the export — that is what
``repro.tools.trace_report`` and the stitching tests consume.
"""

from __future__ import annotations

import json
from typing import Iterable, List, Optional

from .trace import SpanRecord

__all__ = ["STAGES", "events_to_records", "stage_breakdown", "to_events",
           "write_trace"]

#: the pipeline-stage taxonomy ``stage_breakdown`` bills spans against
STAGES = ("read", "decode", "logic", "record", "transport", "cache",
          "aggregate")

_CAT_STAGE = {"play": "read", "record": "record", "transport": "transport",
              "shm": "transport", "cache": "cache", "agg": "aggregate"}


def _stage_of(name: str, cat: str, attrs: Optional[dict]) -> Optional[str]:
    """Map one span to the pipeline stage it bills.  ``sched`` / ``suite``
    spans are containers (queue wait + execution) and bill nothing."""
    if cat == "logic":
        # perception.step is the jitted decode→forward program
        return "decode" if name.startswith("perception.") else "logic"
    if cat == "lane":
        # lane spans bill the stage their consumer implements
        lane = str((attrs or {}).get("lane", ""))
        if lane.startswith("record"):
            return "record"
        if lane.startswith("bridge"):
            return "transport"
        if lane.startswith("metrics"):
            return "aggregate"
        return "logic"
    return _CAT_STAGE.get(cat)


def to_events(records: Iterable[SpanRecord],
              driver_pid: Optional[int] = None) -> List[dict]:
    """Convert drained span records to Chrome trace events."""
    events: List[dict] = []
    pids = {}
    for rec in records:
        try:
            span_id, parent, name, cat, t0, t1, pid, tid, attrs = rec
        except (TypeError, ValueError):
            continue                    # torn/foreign record: skip, don't die
        if not t0:
            continue
        args = {"id": span_id, "parent": parent}
        if attrs:
            args.update(attrs)
        if not t1:
            args["incomplete"] = True   # crash/drain caught the span open
            t1 = t0
        events.append({
            "name": name, "cat": cat or "span", "ph": "X",
            "ts": t0 / 1000.0, "dur": max(t1 - t0, 0) / 1000.0,
            "pid": pid, "tid": tid, "args": args,
        })
        pids.setdefault(pid, set()).add(tid)
    for pid, tids in sorted(pids.items()):
        role = "driver" if pid == driver_pid else "worker"
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": f"{role} {pid}"}})
        for tid in sorted(tids):
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tid, "args": {"name": f"thread-{tid}"}})
    return events


def stage_breakdown(records: Iterable[SpanRecord]) -> dict:
    """Per-scenario per-stage busy nanoseconds from drained records.

    Returns ``{scenario: {stage: ns}}``.  Scenario attribution walks each
    span's parent chain to the nearest ``sched.task`` span, whose
    ``stage`` attr carries the task lineage head (``["scenario", name]``
    or ``["aggregate", name]``); spans with no attributable ancestor land
    under ``"_suite"``.  A span whose *parent* already bills the same
    stage is skipped, so nesting (``logic.step`` inside the logic lane's
    ``lane.deliver``) never double-counts.
    """
    recs: dict = {}
    for rec in records:
        try:
            span_id, parent, name, cat, t0, t1, pid, tid, attrs = rec
        except (TypeError, ValueError):
            continue
        if not t0:
            continue
        recs[span_id] = (parent, name, cat, t0, t1, attrs)

    owner_memo: dict = {}

    def owner(sid: int) -> Optional[str]:
        chain = []
        cur, got = sid, None
        while cur and cur in recs:
            if cur in owner_memo:
                got = owner_memo[cur]
                break
            chain.append(cur)
            parent, name, _cat, _t0, _t1, attrs = recs[cur]
            stage = (attrs or {}).get("stage")
            if name == "sched.task" and stage:
                got = str(stage[1]) if len(stage) > 1 else None
                break
            cur = parent
        for s in chain:
            owner_memo[s] = got
        return got

    out: dict = {}
    for sid, (parent, name, cat, t0, t1, attrs) in recs.items():
        stage = _stage_of(name, cat, attrs)
        if stage is None:
            continue
        up = recs.get(parent)
        if up is not None and _stage_of(up[1], up[2], up[5]) == stage:
            continue                    # parent already bills this stage
        dur = max((t1 or t0) - t0, 0)
        scen = owner(sid) or "_suite"
        stages = out.setdefault(scen, {})
        stages[stage] = stages.get(stage, 0) + dur
    return out


def events_to_records(events: Iterable[dict]) -> List[SpanRecord]:
    """Rebuild span records from exported trace events — the inverse of
    :func:`to_events` (modulo µs→ns rounding), so ``trace_report`` and
    the stitching tests analyse a ``trace.json`` with the same helpers
    that analyse live drains."""
    out: List[SpanRecord] = []
    for e in events:
        if e.get("ph") != "X":
            continue
        args = dict(e.get("args") or {})
        sid = args.pop("id", 0)
        parent = args.pop("parent", 0)
        incomplete = args.pop("incomplete", False)
        t0 = int(round(e.get("ts", 0.0) * 1000.0))
        t1 = 0 if incomplete else t0 + int(round(e.get("dur", 0.0) * 1000.0))
        out.append((sid, parent, e.get("name", ""), e.get("cat", ""),
                    t0, t1, e.get("pid", 0), e.get("tid", 0), args or None))
    return out


def write_trace(path, records: Iterable[SpanRecord],
                driver_pid: Optional[int] = None,
                metadata: Optional[dict] = None) -> int:
    """Write a Perfetto-loadable ``trace.json``; returns the number of
    span events written."""
    events = to_events(records, driver_pid=driver_pid)
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    if metadata:
        doc["metadata"] = metadata
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(doc, fh, separators=(",", ":"))
    return sum(1 for e in events if e.get("ph") == "X")
