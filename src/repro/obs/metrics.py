"""Metrics registry: counters/gauges/histograms behind one API.

Components create a private :class:`Scope` from the process-wide
:data:`REGISTRY` (``metrics.scope("scheduler")``) and increment plain
metric objects on it — per-instance semantics are preserved (two
schedulers do not share counters) while :func:`snapshot` aggregates
every live scope of the same name into one suite-level view, which
``ScenarioSuite`` persists into the verdict manifest.

Scopes are weakly registered: a component that dies releases its
metrics with it, so long-lived processes (test sessions, the future
regression service) don't accumulate dead scopes.

Increments are plain ``+=`` under the GIL — the same tolerance the
pre-registry ad-hoc counters had; components that already hold a lock
on the mutating path (scheduler, transport) stay exactly as consistent
as before.

Cross-process: worker-side scopes live in the worker.  A worker ships
``snapshot(reset=True)`` deltas home with task results (dicts of
plain numbers), and the driver folds them in via :func:`absorb`.
"""

from __future__ import annotations

import threading
import weakref
from typing import Dict, List, Optional

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "Scope", "REGISTRY",
    "absorb", "scope", "snapshot",
]


class Counter:
    """Monotonic count; ``inc(n)`` / ``.value``."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0

    def snap(self):
        return self.value


class Gauge:
    """Last-set level plus high-water mark; ``set(v)`` / ``.value``."""

    __slots__ = ("value", "max")

    def __init__(self):
        self.value = 0
        self.max = 0

    def set(self, v) -> None:
        self.value = v
        if v > self.max:
            self.max = v

    def reset(self) -> None:
        self.value = 0
        self.max = 0

    def snap(self):
        return {"value": self.value, "max": self.max}


class Histogram:
    """Power-of-two bucketed distribution of non-negative samples.

    Bucket ``i`` counts samples in ``[2**(i-1), 2**i)`` (bucket 0 is
    ``< 1``); the top bucket absorbs overflow.  Fixed storage, no
    allocation per observe.
    """

    __slots__ = ("count", "total", "min", "max", "buckets")

    N_BUCKETS = 40

    def __init__(self):
        self.count = 0
        self.total = 0
        self.min = None
        self.max = None
        self.buckets = [0] * self.N_BUCKETS

    def observe(self, v) -> None:
        self.count += 1
        self.total += v
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v
        i = 0 if v < 1 else min(int(v).bit_length(), self.N_BUCKETS - 1)
        self.buckets[i] += 1

    def reset(self) -> None:
        self.count = 0
        self.total = 0
        self.min = None
        self.max = None
        self.buckets = [0] * self.N_BUCKETS

    def snap(self):
        return {"count": self.count, "total": self.total,
                "min": self.min, "max": self.max,
                "mean": (self.total / self.count) if self.count else None}


class Scope:
    """A named bag of metrics owned by one component instance."""

    __slots__ = ("name", "_metrics", "_lock", "__weakref__")

    def __init__(self, name: str):
        self.name = name
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = self._metrics[name] = cls()
        if not isinstance(m, cls):
            raise TypeError(f"metric {self.name}.{name} already registered "
                            f"as {type(m).__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self, reset: bool = False) -> Dict[str, object]:
        with self._lock:
            out = {name: m.snap() for name, m in self._metrics.items()}
            if reset:
                # reset in place: components cache metric object refs
                # (e.g. a transport's counter attributes), so swapping in
                # fresh instances would silently orphan them
                for m in self._metrics.values():
                    m.reset()
        return out


def _merge(a, b):
    """Aggregate two snapshot values of the same metric name."""
    if isinstance(a, dict) and isinstance(b, dict):
        out = dict(a)
        for k, v in b.items():
            if v is None:
                continue
            cur = out.get(k)
            if cur is None:
                out[k] = v
            elif k == "min":
                out[k] = min(cur, v)
            elif k == "max":
                out[k] = max(cur, v)
            elif k == "mean":
                pass                    # recomputed below when possible
            else:
                out[k] = cur + v
        if "count" in out and out.get("count"):
            tot = out.get("total")
            if tot is not None:
                out["mean"] = tot / out["count"]
        return out
    if isinstance(a, (int, float)) and isinstance(b, (int, float)):
        return a + b
    return b


class Registry:
    """Process-wide set of weakly-held scopes."""

    def __init__(self):
        self._lock = threading.Lock()
        self._scopes: List[weakref.ref] = []
        #: deltas absorbed from other processes, keyed by scope name
        self._absorbed: Dict[str, Dict[str, object]] = {}

    def scope(self, name: str) -> Scope:
        s = Scope(name)
        with self._lock:
            self._scopes.append(weakref.ref(s))
        return s

    def absorb(self, snap: Dict[str, Dict[str, object]]) -> None:
        """Fold a foreign ``snapshot()`` (e.g. shipped from a worker
        process with a task result) into this registry's view."""
        if not snap:
            return
        with self._lock:
            for scope_name, metrics_ in snap.items():
                cur = self._absorbed.setdefault(scope_name, {})
                for mname, val in metrics_.items():
                    prev = cur.get(mname)
                    cur[mname] = val if prev is None else _merge(prev, val)

    def snapshot(self, reset: bool = False) -> Dict[str, Dict[str, object]]:
        """Aggregate every live scope (summing same-named scopes from
        multiple component instances) plus absorbed worker deltas."""
        out: Dict[str, Dict[str, object]] = {}
        with self._lock:
            refs = list(self._scopes)
            if reset:
                self._scopes = [r for r in refs if r() is not None]
            absorbed = {k: dict(v) for k, v in self._absorbed.items()}
            if reset:
                self._absorbed = {}
        for ref in refs:
            s = ref()
            if s is None:
                continue
            snap = s.snapshot(reset=reset)
            cur = out.setdefault(s.name, {})
            for mname, val in snap.items():
                prev = cur.get(mname)
                cur[mname] = val if prev is None else _merge(prev, val)
        for scope_name, metrics_ in absorbed.items():
            cur = out.setdefault(scope_name, {})
            for mname, val in metrics_.items():
                prev = cur.get(mname)
                cur[mname] = val if prev is None else _merge(prev, val)
        return out


#: the process-wide default registry
REGISTRY = Registry()


def scope(name: str) -> Scope:
    return REGISTRY.scope(name)


def absorb(snap: Optional[Dict[str, Dict[str, object]]]) -> None:
    REGISTRY.absorb(snap or {})


def snapshot(reset: bool = False) -> Dict[str, Dict[str, object]]:
    return REGISTRY.snapshot(reset=reset)
