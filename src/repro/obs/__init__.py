"""Unified observability layer: span tracing + a metrics registry.

The platform spans five subsystems (scheduler, queued bus, wire/shm
transports, result cache, chaos) whose health used to live in ad-hoc
counters scattered across classes.  This package gives them one home:

* :mod:`repro.obs.trace` — a low-overhead span tracer.  Per-thread
  lock-free ring buffers of ``(span_id, parent, name, cat, t0, t1,
  attrs)`` records; trace context crosses the process boundary inside
  task payloads and crosses the wire/shm frame grammar as a
  frame-header annotation; worker-side buffers ship back through the
  existing result path and stitch into one driver-side timeline.
  Disabled (the default) every instrumented seam is a single module
  attribute read + ``None`` check — the same zero-cost idiom as
  :func:`repro.chaos.active_plan`.

* :mod:`repro.obs.metrics` — counters/gauges/histograms behind
  per-component :class:`~repro.obs.metrics.Scope` objects registered
  with one process-wide registry, so a suite-level ``snapshot()`` can
  be persisted into the verdict manifest.

* :mod:`repro.obs.export` — Chrome/Perfetto ``trace.json`` writer
  (load the file at https://ui.perfetto.dev) consumed by the
  ``repro.tools.trace_report`` critical-path CLI.

Entry points: ``ScenarioSuite.run(trace="trace.json")`` records a full
suite flight; :func:`repro.obs.trace.enable` / ``disable`` manage the
tracer directly for custom harnesses.
"""

from __future__ import annotations

from . import export, metrics, trace
from .metrics import Counter, Gauge, Histogram, Registry, Scope
from .trace import Tracer, disable, enable, enabled, get_tracer, span

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "Scope", "Tracer",
    "disable", "enable", "enabled", "export", "get_tracer", "metrics",
    "span", "trace",
]
