from .pipeline import (BagTokenDataset, PrefetchIterator, write_token_bag,
                       synthetic_corpus_bag)

__all__ = ["BagTokenDataset", "PrefetchIterator", "write_token_bag",
           "synthetic_corpus_bag"]
