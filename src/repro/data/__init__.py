from .pipeline import (BagTokenDataset, PrefetchIterator,
                       assemble_message_batch, batch_from_columns,
                       iter_message_batches, payload_blob, payload_matrix,
                       synthetic_corpus_bag, write_token_bag)

__all__ = ["BagTokenDataset", "PrefetchIterator", "assemble_message_batch",
           "batch_from_columns", "iter_message_batches", "payload_blob",
           "payload_matrix", "synthetic_corpus_bag", "write_token_bag"]
