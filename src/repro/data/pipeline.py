"""Training data pipeline on top of the paper's Bag substrate.

The same recorded-data machinery that replays sensor logs feeds the LM
training loop: token sequences are stored as Bag records (topic
``/tokens``, BinPipedRDD uniform format), partitioned by chunk ranges
across data-parallel ranks, replayed through the ROSBag memory cache, and
prefetched on a background thread.

This is deliberately the paper's Fig 5 workflow with "User Logic" = the
training step:   Bag -> (memory cache) -> decode -> batch -> train_step.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Iterator, Optional, Sequence

import numpy as np

from repro.core.bag import Bag, Message, partition_bag
from repro.core.binpipe import decode, encode


def assemble_message_batch(messages: Sequence[Message], align: int = 128,
                           scale: float = 1.0 / 255.0,
                           zero_point: float = 0.0) -> dict[str, np.ndarray]:
    """Fixed-layout batch assembly for jitted user logic (the BinPipedRDD
    frame stage, shaped for :func:`repro.kernels.sensor_decode.sensor_decode`).

    Packs a replay micro-batch (see ``RosPlay.run_batched``) into one
    record-per-row matrix: ``payload`` (R, Nb) uint8 with Nb = max payload
    length rounded up to ``align`` (128 = TPU lane width), plus per-record
    ``lengths`` i32, ``timestamps`` i64, and dequantization ``scale`` /
    ``zero_point`` f32 vectors.  One numpy copy per record; everything a
    TPU step needs, nothing variable-length.
    """
    if not messages:
        raise ValueError("empty message batch")
    lengths = np.fromiter((len(m.data) for m in messages),
                          dtype=np.int32, count=len(messages))
    nb = max(int(lengths.max()), 1)
    nb = (nb + align - 1) // align * align
    payload = np.zeros((len(messages), nb), dtype=np.uint8)
    for i, m in enumerate(messages):
        payload[i, :lengths[i]] = np.frombuffer(m.data, dtype=np.uint8)
    return {
        "payload": payload,
        "lengths": lengths,
        "timestamps": np.fromiter((m.timestamp for m in messages),
                                  dtype=np.int64, count=len(messages)),
        "scale": np.full(len(messages), scale, dtype=np.float32),
        "zero_point": np.full(len(messages), zero_point, dtype=np.float32),
    }


def payload_matrix(blob, lengths, align: int = 128) -> np.ndarray:
    """Record-per-row (R, Nb) uint8 matrix from a concatenated payload blob.

    The vectorized twin of :func:`assemble_message_batch`'s per-message copy
    loop: ``blob`` is the concatenation of R payloads whose byte counts are
    ``lengths`` — exactly the payload column of a wire DATA body or a
    ``binpipe`` partition.  Layout parameters (Nb = max length rounded up to
    ``align``, zero padding) are identical to ``assemble_message_batch``, so
    the two construction paths are bit-interchangeable for the decode
    kernels and the digest algebra.

    When every record is already Nb bytes (uniform, align-multiple payloads
    — the steady state of sensor streams), this is a pure ``reshape`` view
    of the blob: zero copies between the wire frame and the device feed.
    Ragged batches fall back to one vectorized scatter (no Python loop).
    """
    lengths = np.asarray(lengths)
    R = int(lengths.shape[0])
    if R == 0:
        raise ValueError("empty message batch")
    if isinstance(blob, (bytes, bytearray, memoryview)):
        blob = np.frombuffer(blob, dtype=np.uint8)
    else:
        blob = np.asarray(blob, dtype=np.uint8)
    nb = max(int(lengths.max()), 1)
    nb = (nb + align - 1) // align * align
    if int(lengths.min()) == nb:        # uniform aligned records
        return blob.reshape(R, nb)
    out = np.zeros((R, nb), dtype=np.uint8)
    l64 = lengths.astype(np.int64)
    ends = np.cumsum(l64)
    starts = ends - l64
    rows = np.repeat(np.arange(R, dtype=np.int64), l64)
    cols = np.arange(int(ends[-1]), dtype=np.int64) - np.repeat(starts, l64)
    out.reshape(-1)[rows * nb + cols] = blob
    return out


def payload_blob(payload: np.ndarray, lengths) -> np.ndarray:
    """Inverse of :func:`payload_matrix`: the concatenated valid bytes of
    each row as one flat uint8 array (a reshape view when rows are full)."""
    payload = np.ascontiguousarray(payload, dtype=np.uint8)
    R, nb = payload.shape
    l64 = np.asarray(lengths).astype(np.int64)
    if R and int(l64.min()) == nb:
        return payload.reshape(-1)
    ends = np.cumsum(l64)
    starts = ends - l64
    rows = np.repeat(np.arange(R, dtype=np.int64), l64)
    total = int(ends[-1]) if R else 0
    cols = np.arange(total, dtype=np.int64) - np.repeat(starts, l64)
    return payload.reshape(-1)[rows * nb + cols]


def batch_from_columns(topics: Sequence[str], topic_idx, timestamps,
                       lengths, blob, *, align: int = 128,
                       scale: float = 1.0 / 255.0,
                       zero_point: float = 0.0) -> dict:
    """Build the ``assemble_message_batch`` dict straight from columnar
    arrays — the zero-copy seam between the wire codec and the device path.

    Returns the usual five batch keys (bit-identical layout to
    ``assemble_message_batch`` of the equivalent ``Message`` list) plus the
    routing columns a batch-level consumer needs in place of per-message
    ``Message.topic``: ``topics`` (tuple of names) and ``topic_idx`` (R,)
    uint32 into it.  Kernels read the five core keys and ignore the extras.
    """
    lengths_i32 = np.asarray(lengths).astype(np.int32)
    return {
        "payload": payload_matrix(blob, lengths_i32, align),
        "lengths": lengths_i32,
        "timestamps": np.asarray(timestamps, dtype=np.int64),
        "scale": np.full(len(lengths_i32), scale, dtype=np.float32),
        "zero_point": np.full(len(lengths_i32), zero_point,
                              dtype=np.float32),
        "topics": tuple(topics),
        "topic_idx": np.asarray(topic_idx).astype(np.uint32),
    }


def iter_message_batches(messages: "Iterator[Message] | Sequence[Message]",
                         batch_size: int,
                         prefetch: int = 0) -> Iterator[list[Message]]:
    """Slice a message stream into non-empty lists of up to ``batch_size``
    messages — the framing step between a replayed/merged bag and
    :func:`assemble_message_batch` (used by both batched user logic and the
    aggregation layer's jitted metric reductions).

    ``prefetch > 0`` runs the framing loop — and therefore the upstream
    bag read (chunk decode + time-order merge) — on a background reader
    thread that keeps up to ``prefetch`` batches buffered ahead of the
    consumer (``prefetch=2`` is classic double buffering).  This is the
    read stage of the staged replay pipeline: disk I/O overlaps whatever
    consumes the batches downstream.
    """
    if batch_size < 1:
        raise ValueError("batch_size must be >= 1")

    def frames() -> Iterator[list[Message]]:
        batch: list[Message] = []
        for msg in messages:
            batch.append(msg)
            if len(batch) >= batch_size:
                yield batch
                batch = []
        if batch:
            yield batch

    if prefetch > 0:
        return iter(PrefetchIterator(frames(), depth=prefetch))
    return frames()


def write_token_bag(path: str, sequences: np.ndarray,
                    chunk_bytes: int = 256 * 1024) -> str:
    """sequences: (N, seq_len) int32 -> one Bag record per sequence."""
    bag = Bag.open_write(path, chunk_bytes=chunk_bytes)
    for i, seq in enumerate(sequences):
        bag.write("/tokens", i, encode([np.asarray(seq, np.int32)]))
    bag.close()
    return path


def synthetic_corpus_bag(path: str, num_sequences: int, seq_len: int,
                         vocab_size: int, seed: int = 0,
                         chunk_bytes: int = 8 * 1024) -> str:
    """Deterministic synthetic corpus with local structure (a noisy
    integer random walk mod vocab) so a trained model has signal to fit —
    loss decreasing on this corpus is a meaningful end-to-end check."""
    rng = np.random.RandomState(seed)
    start = rng.randint(0, vocab_size, size=(num_sequences, 1))
    steps = rng.randint(-3, 4, size=(num_sequences, seq_len + 1))
    seqs = np.cumsum(np.concatenate([start, steps], axis=1), axis=1)
    seqs = np.mod(seqs[:, :seq_len + 1], vocab_size).astype(np.int32)
    return write_token_bag(path, seqs, chunk_bytes=chunk_bytes)


class BagTokenDataset:
    """Sharded, epoch-shuffled batches out of a token bag.

    ``rank``/``world`` select this worker's chunk-range partition (the same
    ``partition_bag`` the simulation scheduler uses).  Sequences of length
    ``seq_len + 1`` become (tokens, labels) shifted pairs.
    """

    def __init__(self, path: str, batch_size: int, rank: int = 0,
                 world: int = 1, use_memory_cache: bool = True,
                 seed: int = 0):
        self.path = path
        self.batch_size = batch_size
        self.rank = rank
        self.world = world
        self.seed = seed
        src = Bag.open_read(path)
        parts = partition_bag(src, world)
        lo, hi = parts[min(rank, len(parts) - 1)]
        if use_memory_cache:
            # materialise this rank's partition into the ROSBag memory cache
            cache = Bag.open_write(backend="memory")
            for msg in src.read_messages(chunk_range=(lo, hi)):
                cache.write_message(msg)
            cache.close()
            self._records = [
                decode(m.data)[0] for m in Bag.open_read(
                    backend="memory",
                    image=cache.chunked_file.image()).read_messages()]
        else:
            self._records = [decode(m.data)[0] for m in
                             src.read_messages(chunk_range=(lo, hi))]
        src.close()
        if not self._records:
            raise ValueError(f"rank {rank}: empty partition")

    def __len__(self) -> int:
        return len(self._records)

    def batches(self, epochs: Optional[int] = None) -> Iterator[dict]:
        epoch = 0
        n = len(self._records)
        while epochs is None or epoch < epochs:
            order = np.random.RandomState(
                self.seed + epoch).permutation(n)
            for i in range(0, n - self.batch_size + 1, self.batch_size):
                rows = [self._records[j]
                        for j in order[i:i + self.batch_size]]
                arr = np.stack(rows)                    # (B, seq_len + 1)
                yield {"tokens": arr[:, :-1].astype(np.int32),
                       "labels": arr[:, 1:].astype(np.int32)}
            epoch += 1


class PrefetchIterator:
    """Background-thread prefetch (overlaps host data prep with device
    compute — the single-host analogue of the platform's worker pipelining).

    ``close()`` stops the reader thread even mid-stream: a consumer that
    abandons the iterator early (subscriber error, timeout) must not leave
    the reader blocked forever on the bounded queue, pinning whatever the
    source iterator holds open (a bag, its memory image).  Consumers that
    may bail early should ``close()`` in a ``finally``.
    """

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._done = object()
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()

        def worker():
            try:
                for item in it:
                    while not self._stop.is_set():
                        try:
                            self._q.put(item, timeout=0.05)
                            break
                        except queue.Full:
                            continue
                    if self._stop.is_set():
                        return
            except BaseException as e:   # noqa: BLE001
                self._err = e
            finally:
                # blocking stop-aware put: the done sentinel must reach a
                # live consumer even through a full queue, but must not
                # wedge the thread when the consumer closed us instead
                while not self._stop.is_set():
                    try:
                        self._q.put(self._done, timeout=0.05)
                        break
                    except queue.Full:
                        continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        # Never a bare blocking get: after close() — or a drain race that
        # consumed the done sentinel — nothing will ever arrive, and a
        # consumer parked in q.get() would hang forever.  Poll with a short
        # timeout and re-check the liveness facts each round; the timeout
        # only matters on an empty queue (a ready item wakes us
        # immediately).
        while True:
            if self._stop.is_set():
                raise StopIteration
            try:
                item = self._q.get(timeout=0.05)
            except queue.Empty:
                if not self._thread.is_alive():
                    # worker gone and its sentinel already consumed:
                    # surface the error once, then end the stream
                    err, self._err = self._err, None
                    if err is not None:
                        raise err
                    raise StopIteration
                continue
            if item is self._done:
                err, self._err = self._err, None
                if err is not None:
                    raise err
                raise StopIteration
            return item

    def close(self) -> None:
        """Stop the reader thread, join it, and release buffered items.

        Safe in every worker state — mid-stream, finished, or dead from a
        source-iterator exception: the drain below keeps unblocking any
        stop-aware put until the thread exits, so close() cannot wedge
        against a full queue.  Only a source iterator stuck in native code
        can outlive the join deadline; the worker is a daemon thread, so
        even that cannot pin interpreter shutdown.
        """
        self._stop.set()
        deadline = time.monotonic() + 5.0
        while self._thread.is_alive() and time.monotonic() < deadline:
            try:                         # unblock a full-queue put promptly
                self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=0.05)
        while True:                      # drop whatever remained buffered
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
