"""Critical-path breakdown of a suite ``trace.json`` flight recording.

``ScenarioSuite.run(trace=path)`` writes a Chrome/Perfetto trace of the
whole run — driver and worker spans stitched into one timeline.
Perfetto answers "what happened at t=1.38s"; this tool answers the
coarser engineering question: **where does each scenario's time go**,
stage by stage (read vs decode vs logic vs record vs transport vs cache
vs aggregate), and which stage dominates:

    PYTHONPATH=src python -m repro.tools.trace_report trace.json
    PYTHONPATH=src python -m repro.tools.trace_report trace.json --strict

Per scenario it prints each stage's busy time (double-count-free — see
:func:`repro.obs.export.stage_breakdown`), its share of the scenario's
staged total, and flags the dominant stage with ``<-- bottleneck`` when
it holds more than ``--dominant`` (default 0.5) of that total.  Spans
attributable to no scenario (suite-level cache probes, endpoint setup)
report under ``_suite``.

Integrity checks (what ``--strict`` gates on, the CI smoke shape):

* the trace contains at least one span event,
* no orphan parents — every span's parent id is either 0 (a root) or
  itself present in the trace.  A cross-process stitch that lost worker
  buffers, or a context annotation that failed to propagate, shows up
  here as orphans,
* ``incomplete`` spans (open at drain — normal for a crash recording)
  are reported, and tolerated, in both modes.

``--json out.json`` additionally writes the machine-readable analysis.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence

from repro.obs.export import events_to_records, stage_breakdown

__all__ = ["analyze", "load_events", "main", "render"]


def load_events(path: str) -> list[dict]:
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    events = doc.get("traceEvents") if isinstance(doc, dict) else doc
    if not isinstance(events, list):
        raise ValueError(f"{path}: not a Chrome trace "
                         "(no traceEvents array)")
    return events


def analyze(events: Sequence[dict], dominant: float = 0.5) -> dict:
    """Stage breakdown + integrity summary of one exported trace."""
    records = events_to_records(events)
    ids = {r[0] for r in records}
    orphans = [r for r in records if r[1] and r[1] not in ids]
    incomplete = sum(1 for r in records if not r[5])
    pids = sorted({r[6] for r in records})
    by_scenario = stage_breakdown(records)

    scenarios: dict = {}
    for name, stages in sorted(by_scenario.items()):
        total = sum(stages.values())
        ranked = sorted(stages.items(), key=lambda kv: -kv[1])
        top, top_ns = ranked[0] if ranked else (None, 0)
        scenarios[name] = {
            "total_ns": total,
            "stages": dict(ranked),
            "bottleneck": (top if total and top_ns / total >= dominant
                           else None),
        }
    return {
        "spans": len(records),
        "processes": len(pids),
        "incomplete": incomplete,
        "orphans": [{"id": r[0], "parent": r[1], "name": r[2]}
                    for r in orphans],
        "scenarios": scenarios,
    }


def _fmt_ns(ns: int) -> str:
    if ns >= 1_000_000_000:
        return f"{ns / 1e9:.3f}s"
    if ns >= 1_000_000:
        return f"{ns / 1e6:.1f}ms"
    return f"{ns / 1e3:.0f}us"


def render(report: dict) -> str:
    lines = [f"trace: {report['spans']} spans across "
             f"{report['processes']} process(es)"
             + (f", {report['incomplete']} incomplete"
                if report["incomplete"] else "")]
    for name, entry in report["scenarios"].items():
        total = entry["total_ns"]
        lines.append(f"  {name}: staged total {_fmt_ns(total)}")
        for stage, ns in entry["stages"].items():
            share = (ns / total) if total else 0.0
            mark = ("  <-- bottleneck"
                    if stage == entry["bottleneck"] else "")
            lines.append(f"    {stage:<10} {_fmt_ns(ns):>10}  "
                         f"{share:6.1%}{mark}")
    if report["orphans"]:
        lines.append(f"{len(report['orphans'])} orphan span(s) — "
                     "broken stitch:")
        for o in report["orphans"][:10]:
            lines.append(f"  {o['name']} (id {o['id']}, "
                         f"missing parent {o['parent']})")
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.tools.trace_report",
        description="Per-scenario per-stage breakdown of a "
                    "ScenarioSuite trace.json; flags the dominant "
                    "bottleneck stage.")
    parser.add_argument("trace", help="trace.json written by "
                                      "ScenarioSuite.run(trace=...)")
    parser.add_argument("--dominant", type=float, default=0.5,
                        help="flag a stage as the bottleneck when it "
                             "holds at least this share of its "
                             "scenario's staged time")
    parser.add_argument("--json", dest="json_out", default=None,
                        help="also write the analysis as JSON")
    parser.add_argument("--strict", action="store_true",
                        help="exit 1 on an empty trace or any orphan "
                             "span (CI smoke gate)")
    args = parser.parse_args(argv)
    report = analyze(load_events(args.trace), dominant=args.dominant)
    print(render(report))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
    if args.strict and (not report["spans"] or report["orphans"]):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
