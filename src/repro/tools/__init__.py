"""Operational tooling around the platform's persisted artifacts.

    verdict_report -- trend a ScenarioSuite verdict-history JSONL
                      (``python -m repro.tools.verdict_report log.jsonl``)
"""
