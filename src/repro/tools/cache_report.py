"""Result-cache introspection: what's in the store, is it healthy,
and is it earning its keep.

``ScenarioSuite.run(cache=dir)`` fills a content-addressed store of
per-scenario results (see ``repro.cache``); this tool reports on one:

    PYTHONPATH=src python -m repro.tools.cache_report .repro-result-cache
    PYTHONPATH=src python -m repro.tools.cache_report DIR --verify
    PYTHONPATH=src python -m repro.tools.cache_report DIR --evict-to 50000000

Default output is a per-entry listing (key prefix, scenario name at
record time, PASS/FAIL, entry size, age) plus hit/miss/put/evict totals
aggregated from the store's append-only event log — the cumulative view
across every suite run that touched the store, not just the last one.

``--verify`` re-reads every entry payload against its recorded SHA-256
and exits 1 if any entry is corrupt (the suite itself would silently
re-replay those; this is how you find out *that* it did).
``--evict-to BYTES`` deletes oldest-mtime entries until the store fits,
printing what went.  ``--json out.json`` writes the full report.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional, Sequence

from repro.cache import CacheStore


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.1f}GiB"


def _fmt_age(seconds: float) -> str:
    if seconds < 120:
        return f"{int(seconds)}s"
    if seconds < 7200:
        return f"{seconds / 60:.0f}m"
    if seconds < 172800:
        return f"{seconds / 3600:.1f}h"
    return f"{seconds / 86400:.1f}d"


def summarize_events(events: Sequence[dict]) -> dict:
    """Roll the store's event log up into lifetime counters."""
    out = {"gets": 0, "hits": 0, "misses": 0, "corrupt_reads": 0,
           "puts": 0, "put_bytes": 0, "evictions": 0, "evicted_bytes": 0}
    for ev in events:
        op = ev.get("op")
        if op == "get":
            out["gets"] += 1
            if ev.get("hit"):
                out["hits"] += 1
            else:
                out["misses"] += 1
                if "corrupt" in ev:
                    out["corrupt_reads"] += 1
        elif op == "put":
            out["puts"] += 1
            out["put_bytes"] += int(ev.get("bytes", 0))
        elif op == "evict":
            out["evictions"] += 1
            out["evicted_bytes"] += int(ev.get("bytes", 0))
    return out


def build_report(store: CacheStore, verify: bool = False) -> dict:
    """Entry inventory + event-log summary for one store.

    With ``verify=True`` every entry's payload hash is re-checked and
    unreadable/corrupt entries are listed under ``"corrupt"``.
    """
    entries: list[dict] = []
    corrupt: list[str] = []
    for key in store.keys():
        info = store.entry_info(key)
        if info is None:
            corrupt.append(key)
            continue
        if verify and not store.verify(key):
            corrupt.append(key)
            continue
        meta = info.get("meta", {})
        entries.append({
            "key": key,
            "scenario": meta.get("scenario", "?"),
            "passed": meta.get("passed"),
            "size": info["size"],
            "mtime": info["mtime"],
        })
    entries.sort(key=lambda e: e["mtime"])
    return {
        "root": store.root,
        "entries": entries,
        "corrupt": corrupt,
        "total_bytes": sum(e["size"] for e in entries),
        "events": summarize_events(store.events()),
        "verified": verify,
    }


def render(report: dict, now: Optional[float] = None) -> str:
    if now is None:
        now = time.time()
    entries = report["entries"]
    lines = [f"cache {report['root']}: {len(entries)} entries, "
             f"{_fmt_bytes(report['total_bytes'])}"]
    for e in entries:
        status = ("PASS" if e["passed"] else
                  "FAIL" if e["passed"] is not None else "?")
        lines.append(f"  {e['key'][:12]}  {status:<4} "
                     f"{_fmt_bytes(e['size']):>9}  "
                     f"{_fmt_age(max(0.0, now - e['mtime'])):>6}  "
                     f"{e['scenario']}")
    ev = report["events"]
    if ev["gets"] or ev["puts"]:
        rate = (100.0 * ev["hits"] / ev["gets"]) if ev["gets"] else 0.0
        lines.append(f"lifetime: {ev['hits']} hits / {ev['misses']} misses "
                     f"({rate:.0f}% hit rate), {ev['puts']} puts "
                     f"({_fmt_bytes(ev['put_bytes'])}), "
                     f"{ev['evictions']} evictions")
        if ev["corrupt_reads"]:
            lines.append(f"  {ev['corrupt_reads']} read(s) hit a corrupt "
                         "entry and fell back to replay")
    if report["corrupt"]:
        lines.append(f"{len(report['corrupt'])} CORRUPT entr"
                     f"{'y' if len(report['corrupt']) == 1 else 'ies'}:")
        for key in report["corrupt"]:
            lines.append(f"  {key}")
    elif report["verified"]:
        lines.append("all entries verified OK")
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.tools.cache_report",
        description="Inspect a ScenarioSuite result-cache directory: "
                    "entry inventory, lifetime hit/miss stats, payload "
                    "verification, size-bounded eviction.")
    parser.add_argument("root", help="cache directory passed to "
                                     "ScenarioSuite.run(cache=...)")
    parser.add_argument("--verify", action="store_true",
                        help="re-check every entry's payload hash; "
                             "exit 1 if any entry is corrupt")
    parser.add_argument("--evict-to", type=int, default=None,
                        metavar="BYTES",
                        help="delete oldest entries until the store is "
                             "at most this many bytes")
    parser.add_argument("--json", dest="json_out", default=None,
                        help="also write the report as JSON")
    args = parser.parse_args(argv)
    # record_events=False: this tool's own reads are inspection, not
    # cache traffic — they must not skew the lifetime hit/miss stats
    store = CacheStore(args.root, record_events=False)
    evicted: list[str] = []
    if args.evict_to is not None:
        evicted = store.evict_to(args.evict_to)
    report = build_report(store, verify=args.verify)
    report["evicted"] = evicted
    print(render(report))
    if evicted:
        print(f"evicted {len(evicted)} entr"
              f"{'y' if len(evicted) == 1 else 'ies'}:")
        for key in evicted:
            print(f"  {key}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
    return 1 if report["corrupt"] else 0


if __name__ == "__main__":
    sys.exit(main())
