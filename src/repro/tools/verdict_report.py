"""Verdict history trending: catch drift before it becomes a FAIL.

``ScenarioSuite.run(verdict_log=path)`` appends one JSONL record per
scenario per run — an accumulating regression history.  A hard FAIL is
loud on its own; what the history is *for* is the quiet failures: a
checksum that moved while the status stayed PASS (the golden bag was
regenerated, a kernel changed rounding, a seed leaked), an output count
that shifted, a wall time creeping up run over run.  This tool diffs each
scenario's latest record against its own history and flags exactly those:

    PYTHONPATH=src python -m repro.tools.verdict_report log.jsonl
    PYTHONPATH=src python -m repro.tools.verdict_report log.jsonl --strict

Flags raised (per scenario, comparing the latest run to the one before,
and wall time to the median of all earlier runs):

``CHECKSUM-DRIFT``  a per-topic payload checksum changed between two
                    *passing* runs (a FAIL already screams; drift between
                    passes is the silent kind)
``COUNT-DRIFT``     per-topic message count changed between passing runs
``STATUS-FLIP``     status changed (PASS -> FAIL, FAIL -> PASS,
                    PASS -> PASS(vacuous) — all worth eyes)
``WALLTIME``        latest wall time exceeds ``--wall-factor`` (default
                    1.5) x the median of earlier runs (floored at 50 ms —
                    sub-noise runs never flag).  Cache-hit rows
                    (``"cache": "hit"`` from ``run(cache=...)``) are
                    excluded on both sides: a hit's near-zero wall would
                    poison the median and a hit can never *be* a wall-time
                    regression, so hits neither flag nor count as baseline.
                    Traced runs (``run(trace=...)``) additionally carry a
                    per-stage busy-time dict (``"stages"``, from the span
                    timeline) and trend **per stage** against the same
                    factor — so "wall time is flat but the logic stage
                    doubled while read halved" still flags, attributed to
                    the stage that actually moved
``CARRIER-SHIFT``   the export transport changed between the last two
                    runs that recorded one (e.g. ``shm`` -> ``wire``:
                    the same-host ring stopped negotiating — bit-exact
                    results, but the fast path silently degraded; also
                    fires on deliberate ``wire`` -> ``shm`` upgrades so
                    the change is on the record).  Not a correctness
                    flag — carriers are bit-identical by contract — but
                    a performance-provenance one

Scenarios whose *latest* record is an ERROR verdict (the degraded-suite
outcome: a partition perma-failed, or an upstream exporter did) are
reported in their own section with the cause lineage.  ERROR runs are
excluded from checksum/count/walltime trending on both sides — an
errored run produced nothing comparable, so it can neither flag drift
nor serve as a baseline — but they DO trip ``--strict``: a degraded
suite is a red build even though the run "completed".

``--strict`` exits 1 when any flag fires or any scenario is currently
ERROR — the CI trip-wire shape.
``--json out.json`` additionally writes the full analysis.
``--metrics [manifest.json]`` appends the suite metrics snapshot the
manifest embeds (scheduler/cache/transport/lane/shm counters) — the
path defaults to ``<log>.manifest.json``.
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import OrderedDict
from typing import Optional, Sequence

#: wall times below this are scheduling noise, never a regression signal
WALL_FLOOR_S = 0.05

#: per-stage busy times below this (20 ms) never flag — a stage that
#: cheap regressing is noise, not a bottleneck shift
STAGE_FLOOR_NS = 20_000_000


def load_records(path: str) -> list[dict]:
    records = []
    with open(path) as f:
        for line_no, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{line_no}: bad JSONL record: {e}")
    return records


def _median(values: Sequence[float]) -> float:
    ordered = sorted(values)
    mid = len(ordered) // 2
    if len(ordered) % 2:
        return ordered[mid]
    return (ordered[mid - 1] + ordered[mid]) / 2.0


def analyze(records: Sequence[dict],
            wall_factor: float = 1.5) -> dict:
    """Per-scenario trend analysis over a verdict history.

    Returns ``{"scenarios": {name: {...}}, "flags": [...],
    "errors": [...], "runs": N}``; each flag is
    ``{"scenario", "flag", "detail"}``, each error
    ``{"scenario", "error", "runs"}`` (scenarios whose latest record is
    an ERROR verdict).  Records must be in append order (what the JSONL
    log guarantees).
    """
    history: "OrderedDict[str, list[dict]]" = OrderedDict()
    for rec in records:
        history.setdefault(rec["scenario"], []).append(rec)
    flags: list[dict] = []
    errors: list[dict] = []
    scenarios: dict[str, dict] = {}

    def flag(name: str, kind: str, detail: str) -> None:
        flags.append({"scenario": name, "flag": kind, "detail": detail})

    for name, runs in history.items():
        last = runs[-1]
        entry = {
            "runs": len(runs),
            "status": last.get("status"),
            "wall_time_s": last.get("wall_time_s"),
            "checksums": last.get("checksums", {}),
            "transport": last.get("transport"),
        }
        scenarios[name] = entry
        if last.get("status") == "ERROR":
            # its own section, not a drift flag: an errored scenario
            # produced nothing comparable, so there is nothing to trend
            # — but --strict still trips on it below
            errors.append({"scenario": name, "error": last.get("error"),
                           "runs": len(runs)})
        if len(runs) < 2:
            continue
        prev = runs[-2]
        if last.get("status") != prev.get("status"):
            flag(name, "STATUS-FLIP",
                 f"{prev.get('status')} -> {last.get('status')}")
        # carrier provenance: compare the last two runs that recorded a
        # transport at all (old logs predate the field; exporters only)
        carried = [r.get("transport") for r in runs
                   if r.get("transport") is not None]
        if len(carried) >= 2 and carried[-1] != carried[-2]:
            flag(name, "CARRIER-SHIFT",
                 f"export transport {carried[-2]} -> {carried[-1]}")
        if last.get("passed") and prev.get("passed"):
            a, b = prev.get("checksums", {}), last.get("checksums", {})
            for topic in sorted(set(a) | set(b)):
                if topic not in a:
                    flag(name, "CHECKSUM-DRIFT",
                         f"{topic}: topic appeared (checksum {b[topic]})")
                elif topic not in b:
                    flag(name, "CHECKSUM-DRIFT",
                         f"{topic}: topic disappeared")
                elif a[topic] != b[topic]:
                    flag(name, "CHECKSUM-DRIFT",
                         f"{topic}: {a[topic]} -> {b[topic]} "
                         "(both runs PASS)")
            for fld in ("messages_out", "messages_in"):
                if (fld in prev and fld in last
                        and prev[fld] != last[fld]):
                    flag(name, "COUNT-DRIFT",
                         f"{fld}: {prev[fld]} -> {last[fld]}")
        earlier = [r.get("wall_time_s") for r in runs[:-1]
                   if r.get("wall_time_s") is not None
                   and r.get("cache") != "hit"
                   and r.get("status") != "ERROR"]
        wall = last.get("wall_time_s")
        if last.get("cache") == "hit" or last.get("status") == "ERROR":
            # a cache hit skipped replay entirely and an errored run
            # never finished one; neither wall time is a regression nor
            # a usable baseline sample
            wall = None
        if earlier and wall is not None:
            baseline = max(_median(earlier), WALL_FLOOR_S)
            entry["wall_baseline_s"] = baseline
            if wall > wall_factor * baseline:
                flag(name, "WALLTIME",
                     f"{wall:.3f}s vs median {baseline:.3f}s "
                     f"(> {wall_factor:.2f}x)")
        # per-stage trending (traced runs only): the span-derived busy
        # times attribute a wall regression to the stage that moved
        last_stages = last.get("stages")
        if (last_stages and last.get("cache") != "hit"
                and last.get("status") != "ERROR"):
            entry["stages_ns"] = last_stages
            earlier_staged = [r["stages"] for r in runs[:-1]
                              if r.get("stages")
                              and r.get("cache") != "hit"
                              and r.get("status") != "ERROR"]
            for stage_name in sorted(last_stages):
                samples = [s[stage_name] for s in earlier_staged
                           if s.get(stage_name) is not None]
                if not samples:
                    continue
                base_ns = max(_median(samples), STAGE_FLOOR_NS)
                cur_ns = last_stages[stage_name]
                if cur_ns > wall_factor * base_ns:
                    flag(name, "WALLTIME",
                         f"stage {stage_name}: {cur_ns / 1e9:.3f}s vs "
                         f"median {base_ns / 1e9:.3f}s "
                         f"(> {wall_factor:.2f}x)")
    return {"scenarios": scenarios, "flags": flags, "errors": errors,
            "runs": len(records)}


def render(report: dict) -> str:
    lines = [f"verdict history: {report['runs']} records, "
             f"{len(report['scenarios'])} scenarios"]
    for name, entry in report["scenarios"].items():
        wall = entry.get("wall_time_s")
        wall_s = f"{wall:.3f}s" if wall is not None else "n/a"
        carrier = (f", export via {entry['transport']}"
                   if entry.get("transport") else "")
        lines.append(f"  {name}: {entry['status']} x{entry['runs']} runs, "
                     f"last wall {wall_s}{carrier}")
    if report.get("errors"):
        lines.append(f"{len(report['errors'])} ERROR verdict(s):")
        for e in report["errors"]:
            lines.append(f"  [ERROR] {e['scenario']}: {e['error']}")
    if report["flags"]:
        lines.append(f"{len(report['flags'])} flag(s):")
        for f in report["flags"]:
            lines.append(f"  [{f['flag']}] {f['scenario']}: {f['detail']}")
    else:
        lines.append("no drift flagged")
    return "\n".join(lines)


def render_metrics(manifest: dict) -> str:
    """The suite metrics snapshot a traced/verdict-logged run embedded
    in its manifest, one scope per line as ``name=value`` columns."""
    snap = manifest.get("metrics") or {}
    if not snap:
        return "no metrics snapshot in manifest"
    lines = ["metrics snapshot:"]
    for scope_name in sorted(snap):
        cols = []
        for mname in sorted(snap[scope_name]):
            val = snap[scope_name][mname]
            if isinstance(val, dict):
                # gauge {value,max} / histogram {count,...}: lead value
                val = val.get("value", val.get("count"))
            cols.append(f"{mname}={val}")
        lines.append(f"  {scope_name:<12} " + "  ".join(cols))
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.tools.verdict_report",
        description="Trend a ScenarioSuite verdict-history JSONL and flag "
                    "drift before it becomes a FAIL.")
    parser.add_argument("log", help="verdict JSONL written by "
                                    "ScenarioSuite.run(verdict_log=...)")
    parser.add_argument("--wall-factor", type=float, default=1.5,
                        help="flag when latest wall time exceeds this "
                             "multiple of the median of earlier runs")
    parser.add_argument("--json", dest="json_out", default=None,
                        help="also write the analysis as JSON")
    parser.add_argument("--strict", action="store_true",
                        help="exit 1 when any flag fires or any scenario "
                             "is currently ERROR (CI trip-wire)")
    parser.add_argument("--metrics", nargs="?", const="", default=None,
                        metavar="MANIFEST",
                        help="also print the suite metrics snapshot from "
                             "the manifest (default <log>.manifest.json)")
    args = parser.parse_args(argv)
    report = analyze(load_records(args.log), wall_factor=args.wall_factor)
    print(render(report))
    if args.metrics is not None:
        mpath = args.metrics or args.log + ".manifest.json"
        with open(mpath) as f:
            print(render_metrics(json.load(f)))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
    return 1 if (args.strict
                 and (report["flags"] or report["errors"])) else 0


if __name__ == "__main__":
    sys.exit(main())
