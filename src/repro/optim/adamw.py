"""AdamW with mixed-precision options for thousand-chip training:

* moments stored in a configurable dtype (``bf16`` halves optimizer HBM —
  the knob that lets grok-1-314b train state fit 16 GB/chip at 256 chips),
* global-norm gradient clipping,
* decoupled weight decay,
* pure pytree state => shards with the same FSDP rules as the params.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"    # "bfloat16" halves optimizer memory


class AdamWState(NamedTuple):
    count: jax.Array
    m: Any
    v: Any


def adamw_init(cfg: AdamWConfig, params: Any) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads: Any, state: AdamWState,
                 params: Any) -> tuple[Any, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    count = state.count + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else 1.0
    lr = cfg.lr(count) if callable(cfg.lr) else cfg.lr
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g) * (1 - b2)
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:       # no decay on norms/bias
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return newp, m32.astype(mdt), v32.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(count, new_m, new_v), \
        {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
