"""Deterministic fault-injection plans (the chaos layer's data model).

A :class:`ChaosPlan` is a list of :class:`Fault` specs plus a seed.  Code
under test exposes **named seams** — fixed points where a fault *could*
happen (a worker about to execute a task, a frame about to hit the wire, a
lane worker about to deliver) — and probes the installed plan there:

    plan = chaos.active_plan()
    ...
    if plan is not None and plan.probe("logic_raise", scenario.name):
        raise ChaosFault(...)

``probe(seam, key)`` matches the seam name exactly and the key against the
fault's ``target`` glob, counts matching probes *per fault*, and fires on
probes ``at <= n < at + count`` — so "crash the 3rd task on worker w1",
"corrupt the first two frames of stream X" and "always raise in scenario
Y's logic" are all one spec shape.  Everything is deterministic: the same
plan over the same execution produces the same injections, which is what
lets the chaos benchmark assert *bit-identical* unaffected verdicts.

``Fault.param`` / ``Fault.mode`` are seam-specific knobs (stall seconds,
``"bitflip"`` vs ``"truncate"``); ``plan.rng(seam, key)`` hands seams a
:class:`random.Random` seeded from ``(plan.seed, seam, key, fire count)``
so even "random" corruption replays identically.

The plan records every firing in ``plan.fired`` — the harness's ground
truth for "k faults were injected, so exactly k scenarios must degrade".
"""

from __future__ import annotations

import fnmatch
import random
import threading
import zlib
from dataclasses import dataclass, field


class ChaosFault(RuntimeError):
    """An injected failure (raised by seams whose fault *is* an exception)."""


#: the named seams the platform exposes (see the package docstring for
#: what each one's probe key is); validated at Fault construction so a
#: typo'd plan fails loudly instead of silently never firing
SEAMS = frozenset({"worker_crash", "wire_corrupt", "credit_starve",
                   "lane_stall", "logic_raise"})


@dataclass(frozen=True)
class Fault:
    """One injection spec: fire at seam ``seam`` on probes whose key
    matches ``target`` (fnmatch glob), starting at the ``at``-th matching
    probe, for ``count`` consecutive matches (``count=None`` = forever).
    """
    seam: str
    target: str = "*"
    at: int = 0
    count: "int | None" = 1
    param: float = 0.0
    mode: str = ""

    def __post_init__(self):
        if self.seam not in SEAMS:
            raise ValueError(f"unknown seam {self.seam!r}; "
                             f"one of {sorted(SEAMS)}")
        if self.at < 0:
            raise ValueError("Fault.at must be >= 0")
        if self.count is not None and self.count < 1:
            raise ValueError("Fault.count must be >= 1 (or None = forever)")


@dataclass
class _Firing:
    seam: str
    key: str
    fault: Fault


class ChaosPlan:
    """A seeded set of faults plus the per-fault probe counters.

    Thread-safe: seams probe from lane workers, transport readers and
    scheduler threads concurrently.  Counters advance only on *matching*
    probes, so unrelated traffic through the same seam never shifts when
    a targeted fault fires.
    """

    def __init__(self, faults: "list[Fault] | tuple[Fault, ...]" = (),
                 seed: int = 0):
        self.faults = tuple(faults)
        self.seed = seed
        self.fired: list[_Firing] = []
        self._counts = [0] * len(self.faults)
        self._lock = threading.Lock()

    def probe(self, seam: str, key: str = "") -> "Fault | None":
        """The fault to apply at this (seam, key) event, or ``None``.
        At most one fault fires per probe (first matching spec wins)."""
        hit: "Fault | None" = None
        with self._lock:
            for idx, f in enumerate(self.faults):
                if f.seam != seam or not fnmatch.fnmatchcase(key, f.target):
                    continue
                n = self._counts[idx]
                self._counts[idx] = n + 1
                if n < f.at or (f.count is not None
                                and n >= f.at + f.count):
                    continue
                if hit is None:
                    hit = f
                    self.fired.append(_Firing(seam, key, f))
        return hit

    def rng(self, seam: str, key: str = "") -> random.Random:
        """Deterministic per-(seam, key, firing ordinal) RNG for seams
        that need "random" corruption positions/lengths."""
        with self._lock:
            ordinal = sum(1 for f in self.fired
                          if f.seam == seam and f.key == key)
        return random.Random(self.seed * 1_000_003
                             + zlib.crc32(f"{seam}|{key}".encode()) * 131
                             + ordinal)

    def fired_count(self, seam: "str | None" = None) -> int:
        with self._lock:
            if seam is None:
                return len(self.fired)
            return sum(1 for f in self.fired if f.seam == seam)

    def summary(self) -> list[dict]:
        with self._lock:
            return [{"seam": f.seam, "key": f.key, "target": f.fault.target,
                     "mode": f.fault.mode} for f in self.fired]
