"""Seeded, deterministic fault injection for the scenario engine.

Install a :class:`ChaosPlan` process-wide and the instrumented seams in
the executor/net/playback/simulation layers consult it; with no plan
installed every seam is a single ``None`` check (the default, zero-cost
path).  See :mod:`repro.chaos.plan` for the matching semantics and
``benchmarks/chaos.py`` for the end-to-end harness that races a clean
suite against an injected one and asserts graceful degradation.

Instrumented seams (key probed at each):

====================  ====================================================
``worker_crash``      thread/process worker about to run a task
                      (key: worker name) — worker dies mid-task
``wire_corrupt``      frame about to be sent on a ``FrameSocket``
                      (key: socket's ``chaos_key``) — bitflip/truncation
``credit_starve``     receiver about to grant credit (key: stream id)
                      — credit withheld, sender must ride the backoff
``lane_stall``        playback lane about to deliver (key: lane key)
                      — delivery stalled by ``param`` seconds
``logic_raise``       user logic callback about to run
                      (key: scenario name) — callback raises ChaosFault
====================  ====================================================
"""

from __future__ import annotations

import threading

from .plan import SEAMS, ChaosFault, ChaosPlan, Fault

__all__ = ["SEAMS", "ChaosFault", "ChaosPlan", "Fault", "active_plan",
           "install", "uninstall", "probe"]

_active: "ChaosPlan | None" = None
_lock = threading.Lock()


def install(plan: ChaosPlan) -> ChaosPlan:
    """Make ``plan`` the process-wide active plan (replacing any other)."""
    global _active
    with _lock:
        _active = plan
    return plan


def uninstall() -> None:
    global _active
    with _lock:
        _active = None


def active_plan() -> "ChaosPlan | None":
    return _active


def probe(seam: str, key: str = "") -> "Fault | None":
    """Convenience one-shot probe against the active plan (if any).

    Hot paths should instead capture ``active_plan()`` once and probe the
    local reference, which keeps the no-chaos cost to one global read.
    """
    plan = _active
    return plan.probe(seam, key) if plan is not None else None
