"""Wire codec of the distributed message pool (net layer, frame grammar).

The paper's message pool spans nodes: ROS playback partitions on different
Spark workers exchange topic traffic over the network.  This module is the
byte-level contract of that fabric — deliberately *not* a third
serialization format:

* a **frame** is ``[u32 body_len][u8 type][body][u32 crc]`` — the same
  length-prefixed discipline every chunk/record of the bag format uses,
  plus a CRC32C trailer over ``type + body`` (crc32c when the optional
  accelerated module is importable, ``zlib.crc32`` otherwise — both ends
  of a link run this module, so the choice is consistent per process
  image).  A receiver verifies the trailer before interpreting the body:
  a flipped bit or truncated payload is a :class:`WireError` at the
  frame boundary, never a silently corrupt batch downstream,
* a **DATA body** is one message batch in the *batch-array layout* — the
  compact wire twin of
  :func:`repro.data.pipeline.assemble_message_batch`: a topic table
  (``binpipe.serialize`` of UTF-8 names), then per-record ``topic_idx``
  u32 / ``timestamp`` i64 / ``length`` u32 arrays, then one concatenated
  payload blob.  Encoding is a vectorized column build plus one join —
  not a per-message codec — which is what keeps the bridge within
  striking distance of the in-process bus; and because columns land as
  contiguous arrays, a receiver can hand them straight to the framed
  array pipeline (``assemble_message_batch`` / the Pallas decode sweep)
  without a per-message pass.

Frame types (the whole protocol):

``HELLO``      sender -> receiver, once, first frame: identifies the
               stream (``stream_id``, UTF-8) so a receiver that *collects*
               streams (the suite's export collector) can key them.
``DATA``       sender -> receiver: one message batch.
``CREDIT``     receiver -> sender: grants ``u32`` more messages.  The
               receiver issues the initial window right after ``HELLO`` and
               replenishes only after republishing a batch into its local
               bus — so downstream backpressure (full lanes on the remote
               bus) withholds credit and stalls the sending publisher
               across the wire.
``DRAIN``      sender -> receiver: barrier request carrying a ``u32``
               token.  The receiver finishes republishing everything
               received before it (per-connection frames are processed in
               order), drains its local bus, then acks.
``DRAIN_ACK``  receiver -> sender: echo of the token — everything sent
               before the matching ``DRAIN`` is now visible to remote
               subscribers.
``CLOSE``      sender -> receiver: orderly end of stream.
``CHALLENGE``  receiver -> sender, right after ``HELLO`` when the
               receiver holds a shared secret: a random nonce the sender
               must answer before any credit is granted.
``AUTH``       sender -> receiver: ``HMAC-SHA256(secret, nonce +
               stream_id)``.  A wrong or missing answer closes the
               connection before a single DATA frame is accepted.
``SHM_OFFER``  sender -> receiver, right after ``HELLO``: proposes the
               same-host shm fast path.  Body is ``binpipe.serialize``
               of ``[boot_id, probe_segment_name, probe_token]`` — the
               receiver accepts only if the boot id matches its own
               *and* it can attach the probe segment and read back the
               token (proof both ends share one shm namespace, not
               just one kernel image behind NAT).
``SHM_ACK``    receiver -> sender: ``serialize([ring_name])`` naming a
               freshly created SPSC ring segment, or ``serialize([])``
               to decline (different host, shm unavailable, ring
               creation failed, or shm disabled).  Declining keeps the
               stream on TCP — the fallback is always correct.
``SHM_SWITCH`` sender -> receiver, over TCP: the *last* TCP frame in
               the sender->receiver direction.  Every subsequent
               sender frame (DATA, DRAIN, CLOSE) rides the shm ring,
               preserving total order across the switch; CREDIT /
               DRAIN_ACK / CHALLENGE keep flowing receiver -> sender
               over TCP, which doubles as the liveness channel for the
               ring reader.

Credits are counted in *messages*, not frames, so a sender low on credit
can still make progress with a smaller DATA batch (adaptive framing under
backpressure) instead of deadlocking against a window narrower than its
batch size.
"""

from __future__ import annotations

import select
import socket
import struct
import threading
from typing import Optional, Sequence

import numpy as np

from repro import chaos
from repro.core.bag import Message
from repro.core.binpipe import deserialize, serialize

try:                                    # optional accelerated CRC32C
    from crc32c import crc32c as _crc  # type: ignore[import-not-found]
except ImportError:                     # stdlib fallback (CRC-32/ISO-HDLC)
    from zlib import crc32 as _crc

_FRAME_HDR = struct.Struct("<IB")    # body_len, frame_type
_U32 = struct.Struct("<I")

T_HELLO = 0
T_DATA = 1
T_CREDIT = 2
T_DRAIN = 3
T_DRAIN_ACK = 4
T_CLOSE = 5
T_CHALLENGE = 6
T_AUTH = 7
T_SHM_OFFER = 8
T_SHM_ACK = 9
T_SHM_SWITCH = 10

#: frame-header trace annotation: a type byte with this bit set means the
#: body starts with an 8-byte little-endian span id (the sender's trace
#: context) that the receiver strips before dispatching on the base type.
#: The CRC trailer covers the annotated body, so integrity is unchanged;
#: untraced peers never set the bit, so the grammar is backward-compatible.
CTX_FLAG = 0x80
CTX_PREFIX = struct.Struct("<Q")

#: refuse to allocate for frames beyond this — a corrupt length prefix must
#: fail loudly, not OOM the process
MAX_FRAME_BYTES = 256 << 20


class WireError(ConnectionError):
    """Malformed frame or a connection that died mid-frame."""


def frame_crc(ftype: int, body) -> int:
    """The integrity trailer: CRC over the type byte then the body, so a
    frame whose *type* was flipped fails exactly like a corrupt body."""
    return _crc(body, _crc(bytes((ftype,)))) & 0xFFFFFFFF


def encode_data(messages: Sequence[Message]) -> bytes:
    """One DATA body: a message batch in the batch-array layout.

    ``[u32 n][u32 table_len][topic table][topic_idx u32 x n]
    [timestamp i64 x n][length u32 x n][payload bytes]`` — columns, not
    per-message records, so the encode is one pass of appends plus array
    ``tobytes`` and a single payload join.
    """
    n = len(messages)
    table: list[bytes] = []
    index: dict[str, int] = {}
    idx = np.empty(n, dtype=np.uint32)
    ts = np.empty(n, dtype=np.int64)
    lengths = np.empty(n, dtype=np.uint32)
    for i, m in enumerate(messages):
        j = index.get(m.topic)
        if j is None:
            j = index[m.topic] = len(table)
            table.append(m.topic.encode("utf-8"))
        idx[i] = j
        ts[i] = m.timestamp
        lengths[i] = len(m.data)
    head = serialize(table)
    return b"".join((_U32.pack(n), _U32.pack(len(head)), head,
                     idx.tobytes(), ts.tobytes(), lengths.tobytes(),
                     *(m.data for m in messages)))


def decode_data(body: bytes) -> list[Message]:
    """Invert :func:`encode_data`."""
    (n,) = _U32.unpack_from(body, 0)
    (head_len,) = _U32.unpack_from(body, 4)
    pos = 8
    # bytes() so a zero-copy body (a memoryview into the shm ring) works:
    # deserialize slices its input, and only bytes slices can .decode()
    topics = [t.decode("utf-8")
              for t in deserialize(bytes(body[pos:pos + head_len]))]
    pos += head_len
    idx = np.frombuffer(body, np.uint32, n, pos).tolist()
    pos += 4 * n
    ts = np.frombuffer(body, np.int64, n, pos).tolist()
    pos += 8 * n
    lengths = np.frombuffer(body, np.uint32, n, pos)
    pos += 4 * n
    ends = (np.cumsum(lengths, dtype=np.int64) + pos).tolist()
    # corrupt frames must fail loudly at the boundary, not as silently
    # truncated payloads that only surface later as a checksum mismatch
    if n and (ends[-1] != len(body) or max(idx) >= len(topics)):
        raise WireError(
            f"corrupt DATA frame: payload columns claim {ends[-1]} bytes "
            f"of a {len(body)}-byte body / topic table of {len(topics)}")
    if not n and len(body) != pos:
        raise WireError("corrupt DATA frame: trailing bytes after an "
                        "empty batch")
    mv = memoryview(body)
    return [Message(topics[j], t, bytes(mv[s:e]))
            for j, t, s, e in zip(idx, ts, [pos] + ends[:-1], ends)]


def frame_to_batch(body, *, align: int = 128, scale: float = 1.0 / 255.0,
                   zero_point: float = 0.0) -> dict:
    """Reinterpret a DATA body as the ``assemble_message_batch`` dict —
    the zero-copy device path (no per-message ``Message`` objects).

    The columnar body already *is* the batch: ``timestamps`` and the
    payload blob become numpy views over the frame bytes, and for uniform
    align-multiple payloads the (R, Nb) payload matrix is a pure reshape
    of that view — the received frame feeds the Pallas decode without a
    single per-record copy (see ``repro.data.pipeline.payload_matrix`` for
    the ragged fallback).  Returns the five batch keys bit-identical to
    ``assemble_message_batch(decode_data(body))`` plus ``topics`` /
    ``topic_idx`` routing columns; :func:`batch_to_frame` is the inverse,
    so republishing a batch over another hop is column-to-column too.

    Validation matches :func:`decode_data`: corrupt column lengths or topic
    indices raise :class:`WireError` at the boundary.
    """
    from repro.data.pipeline import batch_from_columns

    (n,) = _U32.unpack_from(body, 0)
    (head_len,) = _U32.unpack_from(body, 4)
    pos = 8
    topics = [t.decode("utf-8")
              for t in deserialize(bytes(body[pos:pos + head_len]))]
    pos += head_len
    idx = np.frombuffer(body, np.uint32, n, pos)
    pos += 4 * n
    ts = np.frombuffer(body, np.int64, n, pos)
    pos += 8 * n
    lengths = np.frombuffer(body, np.uint32, n, pos)
    pos += 4 * n
    total = int(lengths.sum(dtype=np.int64))
    if n and (pos + total != len(body)
              or (topics and int(idx.max()) >= len(topics))
              or (not topics)):
        raise WireError(
            f"corrupt DATA frame: payload columns claim {pos + total} bytes "
            f"of a {len(body)}-byte body / topic table of {len(topics)}")
    if not n:
        raise WireError("empty DATA frame has no batch form")
    blob = np.frombuffer(body, np.uint8, total, pos)
    return batch_from_columns(topics, idx, ts, lengths, blob, align=align,
                              scale=scale, zero_point=zero_point)


def batch_to_frame(batch: dict) -> bytes:
    """Inverse of :func:`frame_to_batch`: one DATA body from a batch dict
    carrying ``topics``/``topic_idx`` routing columns.

    Byte-exact roundtrip — ``batch_to_frame(frame_to_batch(b)) == b`` —
    because every column is written back in wire order and the payload blob
    is regathered by the same length column that framed it.  Re-exporting a
    received batch to another node is therefore column-to-column: no
    ``Message`` materialization on either side of the hop.
    """
    topics = batch["topics"]
    idx = np.asarray(batch["topic_idx"]).astype(np.uint32)
    ts = np.asarray(batch["timestamps"]).astype(np.int64)
    lengths = np.asarray(batch["lengths"]).astype(np.uint32)
    from repro.data.pipeline import payload_blob
    blob = payload_blob(np.asarray(batch["payload"]),
                        np.asarray(batch["lengths"]))
    head = serialize([t.encode("utf-8") for t in topics])
    return b"".join((_U32.pack(len(idx)), _U32.pack(len(head)), head,
                     idx.tobytes(), ts.tobytes(), lengths.tobytes(),
                     blob.tobytes()))


def encode_u32(value: int) -> bytes:
    return _U32.pack(value)


def decode_u32(body: bytes) -> int:
    (value,) = _U32.unpack(body)
    return value


class FrameSocket:
    """Frame-at-a-time view of a connected stream socket.

    ``send_frame`` is serialized by an internal lock (the sender's lane
    worker and its drain/close caller may both write); ``recv_frame`` is
    single-consumer by construction (one reader thread per connection).
    A clean EOF *between* frames returns ``(None, b"")``; EOF *inside* a
    frame — the peer died mid-message — raises :class:`WireError`.

    ``chaos_key`` names this socket at the ``wire_corrupt`` chaos seam
    (see :mod:`repro.chaos`); the default empty key still matches the
    ``"*"`` target, so untagged sockets are injectable too.
    """

    def __init__(self, sock: socket.socket, chaos_key: str = ""):
        self._sock = sock
        self._send_lock = threading.Lock()
        self.bytes_sent = 0
        self.bytes_received = 0
        self.chaos_key = chaos_key
        #: trace context stripped from the last annotated frame received
        #: (``None`` when the sender was untraced) — single-consumer, like
        #: ``recv_frame`` itself
        self.last_trace_ctx: Optional[int] = None

    def send_frame(self, ftype: int, body: bytes = b"",
                   trace_ctx: Optional[int] = None) -> None:
        if trace_ctx is not None:
            ftype |= CTX_FLAG
            body = b"".join((CTX_PREFIX.pack(trace_ctx), body))
        frame = b"".join((_FRAME_HDR.pack(len(body), ftype), body,
                          _U32.pack(frame_crc(ftype, body))))
        plan = chaos.active_plan()
        if plan is not None:
            fault = plan.probe("wire_corrupt", self.chaos_key)
            if fault is not None:
                self._send_tampered(frame, fault, plan)
                return
        with self._send_lock:
            self._sock.sendall(frame)
            self.bytes_sent += len(frame)

    def _send_tampered(self, frame: bytes, fault, plan) -> None:
        """Apply a ``wire_corrupt`` fault: emit damaged bytes the receiver
        must reject.  ``truncate`` sends a prefix then kills the socket (a
        peer dying mid-frame — EOF inside a frame, never a hang); the
        default ``bitflip`` flips one bit past the length prefix, so
        framing survives and the CRC trailer catches it."""
        rng = plan.rng("wire_corrupt", self.chaos_key)
        with self._send_lock:
            if fault.mode == "truncate":
                keep = rng.randrange(1, len(frame))
                try:
                    self._sock.sendall(frame[:keep])
                except OSError:
                    pass
                self.bytes_sent += keep
            else:
                dmg = bytearray(frame)
                pos = rng.randrange(_U32.size, len(dmg))
                dmg[pos] ^= 1 << rng.randrange(8)
                try:
                    self._sock.sendall(dmg)
                except OSError:
                    pass
                self.bytes_sent += len(dmg)
        if fault.mode == "truncate":
            self.close()

    def _recv_exact(self, n: int, mid_frame: bool) -> Optional[bytearray]:
        buf = bytearray(n)
        view = memoryview(buf)
        got = 0
        while got < n:
            try:
                r = self._sock.recv_into(view[got:], n - got)
            except OSError as e:
                raise WireError(f"connection lost mid-frame: {e!r}") from e
            if not r:
                if got or mid_frame:
                    raise WireError("peer closed the connection mid-frame")
                return None
            got += r
        view.release()
        return buf          # bytearray: callers only read; skip the copy

    def recv_frame(self) -> tuple[Optional[int], "bytes | bytearray"]:
        """Next ``(frame_type, body)``; ``(None, b"")`` on clean EOF."""
        hdr = self._recv_exact(_FRAME_HDR.size, mid_frame=False)
        if hdr is None:
            return None, b""
        body_len, ftype = _FRAME_HDR.unpack(hdr)
        if body_len > MAX_FRAME_BYTES:
            raise WireError(f"frame of {body_len} bytes exceeds "
                            f"MAX_FRAME_BYTES ({MAX_FRAME_BYTES})")
        body = self._recv_exact(body_len, mid_frame=True) if body_len else b""
        trailer = self._recv_exact(_U32.size, mid_frame=True)
        (crc,) = _U32.unpack(trailer)
        if crc != frame_crc(ftype, body):
            raise WireError(f"CRC mismatch on a type-{ftype} frame of "
                            f"{body_len} bytes: corrupt on the wire")
        self.bytes_received += _FRAME_HDR.size + body_len + _U32.size
        if ftype & CTX_FLAG:
            if body_len < CTX_PREFIX.size:
                raise WireError("annotated frame too short for a trace "
                                "context prefix")
            (self.last_trace_ctx,) = CTX_PREFIX.unpack_from(body, 0)
            ftype &= ~CTX_FLAG
            body = bytes(memoryview(body)[CTX_PREFIX.size:])
        else:
            self.last_trace_ctx = None
        return ftype, body

    def eof_seen(self) -> bool:
        """Non-blocking liveness poll: has the peer closed (or reset)
        this socket?  After a shm SWITCH the sender goes silent on TCP,
        so a readable socket that peeks zero bytes *is* EOF — the ring
        reader polls this to unblock when the peer dies without setting
        the ring's closed flag."""
        try:
            r, _, _ = select.select([self._sock], [], [], 0)
            if not r:
                return False
            return not self._sock.recv(1, socket.MSG_PEEK)
        except (OSError, ValueError):
            return True

    def close(self) -> None:
        # shutdown() first: close() alone does not wake a thread blocked
        # in recv() on the same socket — the reader must see EOF now
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
