"""Distributed message pool: cross-process topic transport (net layer).

The in-process :class:`~repro.core.playback.MessageBus` reproduces ROS
topic semantics inside one replay partition; this package extends them
across processes and hosts — the multi-node message pool of the paper's
platform.  A queued bus lane's FIFO + worker is exactly the shape of a
socket writer, so the bridge is thin:

    local MessageBus --bridge (queued lane)--> LaneTransport
        ==[length-prefixed frames, credit-window flow control]==>
    RemoteBus endpoint --publish_batch--> remote MessageBus subscribers

Layers:
    wire        -- frame grammar + DATA codec (BinPipedRDD uniform format)
    transport   -- LaneTransport (sender), RemoteBus (listener endpoint)

Determinism contract: per connection, frames are processed in order, so a
remote subscriber observes exactly the sender's publish order; credit
grants follow republish, so backpressure propagates across the wire; and
``drain()`` acks only after the remote bus has fully drained — the
end-of-replay barrier spans process boundaries.
"""

from .transport import LaneTransport, RemoteBus, TransportError
from .wire import (FrameSocket, WireError, decode_data, encode_data,
                   MAX_FRAME_BYTES)

__all__ = [
    "LaneTransport", "RemoteBus", "TransportError",
    "FrameSocket", "WireError", "decode_data", "encode_data",
    "MAX_FRAME_BYTES",
]
