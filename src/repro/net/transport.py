"""Cross-process topic transport: the distributed half of the message pool.

Two endpoints make a bridge:

``LaneTransport`` (sender)
    Drains a queued bus lane into a socket.  ``send_message`` — the
    callback :meth:`repro.core.playback.MessageBus.bridge` subscribes —
    buffers messages and flushes them as DATA frames sized by the credit
    window, so wire framing adapts to backpressure instead of deadlocking
    against it.  A reader thread consumes CREDIT grants and DRAIN acks.
    Any transport failure (peer gone, credit starvation past ``timeout``)
    raises from ``send_message``/``drain`` — through the lane's deferred
    error machinery that means *the replay task fails*; nothing ever
    blocks forever or drops a frame silently.

``RemoteBus`` (receiver)
    A listener endpoint that accepts any number of sender connections.
    Each connection gets its own handler thread, so one stream's frames
    are processed strictly in order — the remote subscribers observe
    exactly the sender's publish order.  Received batches are republished
    into a local :class:`~repro.core.playback.MessageBus`
    (``bus=``-mode) and/or buffered per stream and committed to a
    ``sink(stream_id, messages)`` callback at each DRAIN barrier
    (``sink=``-mode, what the scenario suite's export collector uses —
    committing at the barrier is what makes "the sender's ``drain()``
    returned" imply "the collector has the full stream").

Credit-based flow control (see :mod:`repro.net.wire`) propagates
backpressure across the wire: the receiver replenishes credit only after
its local republish returns, and a republish into a full queued lane
blocks — so a slow subscriber three hops away still paces the original
publisher, the same contract the in-process bus gives.
"""

from __future__ import annotations

import itertools
import socket
import threading
import time
from typing import Callable, Optional, Sequence

from repro.core.bag import Message

from .wire import (T_CLOSE, T_CREDIT, T_DATA, T_DRAIN, T_DRAIN_ACK, T_HELLO,
                   FrameSocket, WireError, decode_data, decode_u32,
                   encode_data, encode_u32)


class TransportError(ConnectionError):
    """The bridge to the peer is gone (or starved past its timeout)."""


class _CreditGate:
    """Blocking message-credit counter shared by sender threads.

    ``acquire_up_to(n)`` blocks until at least one credit is available and
    takes up to ``n`` — partial grants shrink the DATA batch rather than
    stall it, so a window narrower than the sender's flush batch can never
    deadlock.  ``abort`` wakes every waiter with the transport's death.
    """

    def __init__(self) -> None:
        self._avail = 0
        self._err: Optional[BaseException] = None
        self._cond = threading.Condition()
        self.stalls = 0                # acquires that had to wait

    def grant(self, n: int) -> None:
        with self._cond:
            self._avail += n
            self._cond.notify_all()

    def abort(self, err: BaseException) -> None:
        with self._cond:
            if self._err is None:
                self._err = err
            self._cond.notify_all()

    def acquire_up_to(self, n: int, timeout: float) -> int:
        deadline = time.monotonic() + timeout
        with self._cond:
            waited = False
            while self._avail == 0:
                if self._err is not None:
                    raise TransportError(
                        f"transport closed while awaiting credit: "
                        f"{self._err!r}") from self._err
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TransportError(
                        f"no credit from peer within {timeout}s "
                        "(remote bus stalled or unreachable)")
                waited = True
                self._cond.wait(remaining)
            if waited:
                self.stalls += 1
            take = min(n, self._avail)
            self._avail -= take
            return take


class LaneTransport:
    """Socket writer end of a bridged lane (see module docstring).

    ``flush_batch`` bounds how many buffered messages one DATA frame
    carries; the credit window may shrink a frame further, and so does
    ``FRAME_BYTES_TARGET`` — frames are also cut by payload size, so
    MB-scale sensor messages can never assemble a frame the receiver's
    ``MAX_FRAME_BYTES`` sanity cap would (deterministically, on every
    retry) reject.  ``timeout`` bounds every wait against the peer
    (credit, drain ack) — a dead or wedged peer fails the bridge instead
    of hanging it.
    """

    #: cut a DATA frame once its payload reaches this many bytes (always
    #: at least one message per frame) — far under wire.MAX_FRAME_BYTES
    FRAME_BYTES_TARGET = 8 << 20

    def __init__(self, sock: socket.socket, stream_id: str = "",
                 flush_batch: int = 128, timeout: float = 30.0):
        if flush_batch < 1:
            raise ValueError("flush_batch must be >= 1")
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass                        # not TCP (e.g. a unix socketpair)
        self.stream_id = stream_id
        self._fs = FrameSocket(sock)
        self._flush_batch = flush_batch
        self._timeout = timeout
        self._credits = _CreditGate()
        self._buffer: list[Message] = []
        self._send_lock = threading.Lock()   # buffer + frame-write order
        self._acks: set[int] = set()
        self._ack_cond = threading.Condition()
        self._drain_token = itertools.count(1)
        self._error: Optional[BaseException] = None
        self._closed = False
        self.messages_sent = 0
        self.frames_sent = 0
        self._fs.send_frame(T_HELLO, stream_id.encode("utf-8"))
        self._reader = threading.Thread(
            target=self._read_loop, name=f"transport-rx-{stream_id or id(self)}",
            daemon=True)
        self._reader.start()

    @classmethod
    def connect(cls, address: tuple[str, int], stream_id: str = "",
                flush_batch: int = 128, timeout: float = 30.0,
                ) -> "LaneTransport":
        sock = socket.create_connection(address, timeout=timeout)
        sock.settimeout(None)
        return cls(sock, stream_id=stream_id, flush_batch=flush_batch,
                   timeout=timeout)

    @property
    def bytes_sent(self) -> int:
        return self._fs.bytes_sent

    @property
    def credit_stalls(self) -> int:
        return self._credits.stalls

    # -- receive side (reader thread) --------------------------------------

    def _read_loop(self) -> None:
        err: BaseException = TransportError("peer closed the connection")
        try:
            while True:
                ftype, body = self._fs.recv_frame()
                if ftype is None:
                    break
                if ftype == T_CREDIT:
                    self._credits.grant(decode_u32(body))
                elif ftype == T_DRAIN_ACK:
                    with self._ack_cond:
                        self._acks.add(decode_u32(body))
                        self._ack_cond.notify_all()
        except (WireError, OSError) as e:
            err = e
        finally:
            if not self._closed:
                self._error = err
            # wake anything blocked on the dead peer — credit waiters raise
            # from acquire, drain waiters re-check _error
            self._credits.abort(err)
            with self._ack_cond:
                self._ack_cond.notify_all()

    # -- send side ----------------------------------------------------------

    def _check_alive(self) -> None:
        if self._closed:
            raise TransportError("transport is closed")
        if self._error is not None:
            raise TransportError(
                f"transport failed: {self._error!r}") from self._error

    def send_message(self, msg: Message) -> None:
        """Buffer one message; flush when the batch threshold is reached.
        This is the callback a bus bridge's lane delivers into."""
        with self._send_lock:
            self._check_alive()
            self._buffer.append(msg)
            if len(self._buffer) >= self._flush_batch:
                self._flush_locked()

    def send_batch(self, msgs: Sequence[Message]) -> None:
        with self._send_lock:
            self._check_alive()
            self._buffer.extend(msgs)
            if len(self._buffer) >= self._flush_batch:
                self._flush_locked()

    def _flush_locked(self) -> None:
        while self._buffer:
            self._check_alive()
            n = self._credits.acquire_up_to(
                min(len(self._buffer), self._flush_batch), self._timeout)
            size = 0
            for i in range(n):          # byte-bound the frame as well
                size += len(self._buffer[i].data)
                if size >= self.FRAME_BYTES_TARGET:
                    unused = n - (i + 1)
                    if unused:          # return the credits we won't use
                        self._credits.grant(unused)
                    n = i + 1
                    break
            batch, self._buffer = self._buffer[:n], self._buffer[n:]
            try:
                self._fs.send_frame(T_DATA, encode_data(batch))
            except OSError as e:
                raise TransportError(f"send failed: {e!r}") from e
            self.messages_sent += len(batch)
            self.frames_sent += 1

    def flush(self) -> None:
        """Push every buffered message onto the wire (credit-gated)."""
        with self._send_lock:
            self._flush_locked()

    def drain(self) -> None:
        """Barrier: returns once everything sent so far has been
        republished on (and committed by) the remote end."""
        token = next(self._drain_token)
        with self._send_lock:
            self._flush_locked()
            try:
                self._fs.send_frame(T_DRAIN, encode_u32(token))
            except OSError as e:
                raise TransportError(f"drain send failed: {e!r}") from e
        deadline = time.monotonic() + self._timeout
        with self._ack_cond:
            while token not in self._acks:
                if self._error is not None:
                    raise TransportError(
                        f"peer lost before drain ack: {self._error!r}"
                    ) from self._error
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TransportError(
                        f"no drain ack within {self._timeout}s")
                self._ack_cond.wait(remaining)
            self._acks.discard(token)

    def close(self) -> None:
        """Best-effort orderly shutdown: flush, CLOSE, close the socket.
        Never raises for a peer that is already gone — ``drain()`` is the
        call that *verifies* delivery; ``close()`` only releases."""
        if self._closed:
            return
        try:
            # flush before marking closed: _check_alive() inside the
            # flush loop treats a closed transport as dead, so the other
            # order would silently drop the buffered tail
            with self._send_lock:
                if self._buffer and self._error is None:
                    self._flush_locked()
                self._closed = True
                self._fs.send_frame(T_CLOSE)
        except (TransportError, OSError):
            pass
        finally:
            self._closed = True
        self._fs.close()
        self._reader.join(timeout=5.0)


class RemoteBus:
    """Listener endpoint: receives bridged streams and republishes them.

    ``bus``  — every DATA batch is republished into this local
    :class:`MessageBus` via ``publish_batch`` (per-message subscribers see
    the sender's publish order; batch subscribers see wire framing).
    ``sink`` — per-stream collection: messages buffer per connection and
    ``sink(stream_id, messages)`` is called with a full snapshot at every
    DRAIN barrier, *before* the ack is sent.  A stream that dies without
    reaching a barrier is never committed — a crashed sender's partial
    stream can't contaminate a collector (its retry commits the complete
    one).  At least one of the two must be given; both may be.

    ``window`` is the per-connection credit window in messages — the
    remote analogue of a lane's ``maxsize``.
    """

    def __init__(self, bus=None, sink: Optional[Callable[[str, list[Message]],
                                                         None]] = None,
                 host: str = "127.0.0.1", port: int = 0, window: int = 256):
        if bus is None and sink is None:
            raise ValueError("RemoteBus needs a bus and/or a sink")
        if window < 1:
            raise ValueError("window must be >= 1")
        self._bus = bus
        self._sink = sink
        self._host = host
        self._port = port
        self._window = window
        self._listener: Optional[socket.socket] = None
        self._accept_thread: Optional[threading.Thread] = None
        self._conns: list[FrameSocket] = []
        self._threads: list[threading.Thread] = []
        self._lock = threading.Lock()
        self._stopped = False
        self.messages_received = 0
        self.frames_received = 0
        self.errors: list[BaseException] = []

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> tuple[str, int]:
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind((self._host, self._port))
        listener.listen(64)
        self._listener = listener
        self._port = listener.getsockname()[1]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"remotebus-{self._port}",
            daemon=True)
        self._accept_thread.start()
        return self.address

    @property
    def address(self) -> tuple[str, int]:
        if self._listener is None:
            raise RuntimeError("RemoteBus is not started")
        return (self._host, self._port)

    def stop(self) -> None:
        """Close the listener and every live connection; join handlers."""
        self._stopped = True
        if self._listener is not None:
            # shutdown() first: close() alone does not wake the accept()
            # blocked in the accept thread
            try:
                self._listener.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._listener.close()
            except OSError:
                pass
        with self._lock:
            conns, self._conns = self._conns, []
            threads, self._threads = self._threads, []
        for fs in conns:
            fs.close()
        for t in threads:
            t.join(timeout=5.0)
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)

    def __enter__(self) -> "RemoteBus":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- serving -------------------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._stopped:
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return                   # listener closed
            try:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:
                pass
            fs = FrameSocket(sock)
            t = threading.Thread(target=self._handle, args=(fs,),
                                 name=f"remotebus-conn-{self._port}",
                                 daemon=True)
            with self._lock:
                if self._stopped:
                    # stop() already swapped the registries: a connection
                    # accepted in this race window must not leak past it
                    fs.close()
                    return
                self._conns.append(fs)
                self._threads.append(t)
            t.start()

    def _handle(self, fs: FrameSocket) -> None:
        stream_id = ""
        stream: list[Message] = []
        try:
            ftype, body = fs.recv_frame()
            if ftype is None:
                return
            if ftype != T_HELLO:
                raise WireError(f"expected HELLO, got frame type {ftype}")
            stream_id = body.decode("utf-8")
            fs.send_frame(T_CREDIT, encode_u32(self._window))
            while True:
                ftype, body = fs.recv_frame()
                if ftype is None or ftype == T_CLOSE:
                    return
                if ftype == T_DATA:
                    msgs = decode_data(body)
                    self.frames_received += 1
                    self.messages_received += len(msgs)
                    if self._bus is not None:
                        # blocks while downstream lanes are full — credit
                        # is withheld and the sender stalls: backpressure
                        # has crossed the wire
                        self._bus.publish_batch(msgs)
                    if self._sink is not None:
                        stream.extend(msgs)
                    fs.send_frame(T_CREDIT, encode_u32(len(msgs)))
                elif ftype == T_DRAIN:
                    if self._bus is not None:
                        try:
                            self._bus.drain()
                        except BaseException as e:  # noqa: BLE001
                            # a *remote subscriber's* deferred error is the
                            # remote side's bookkeeping; the barrier (all
                            # deliveries done) still holds
                            self.errors.append(e)
                    if self._sink is not None:
                        # commit-before-ack: when the sender's drain()
                        # returns, the collector verifiably has the stream
                        self._sink(stream_id, list(stream))
                    fs.send_frame(T_DRAIN_ACK, body)
                else:
                    raise WireError(f"unexpected frame type {ftype}")
        except (WireError, OSError) as e:
            if not self._stopped:
                self.errors.append(e)
        except BaseException as e:      # noqa: BLE001 - a local subscriber
            # raised during republish: record it and drop the connection —
            # the sender sees TransportError (credit stops), never a
            # silent stall
            self.errors.append(e)
        finally:
            fs.close()
