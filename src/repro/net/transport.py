"""Cross-process topic transport: the distributed half of the message pool.

Two endpoints make a bridge:

``LaneTransport`` (sender)
    Drains a queued bus lane into a socket.  ``send_message`` — the
    callback :meth:`repro.core.playback.MessageBus.bridge` subscribes —
    buffers messages and flushes them as DATA frames sized by the credit
    window, so wire framing adapts to backpressure instead of deadlocking
    against it.  A reader thread consumes CREDIT grants and DRAIN acks.
    Any transport failure (peer gone, credit starvation past ``timeout``)
    raises from ``send_message``/``drain`` — through the lane's deferred
    error machinery that means *the replay task fails*; nothing ever
    blocks forever or drops a frame silently.

``RemoteBus`` (receiver)
    A listener endpoint that accepts any number of sender connections.
    Each connection gets its own handler thread, so one stream's frames
    are processed strictly in order — the remote subscribers observe
    exactly the sender's publish order.  Received batches are republished
    into a local :class:`~repro.core.playback.MessageBus`
    (``bus=``-mode) and/or buffered per stream and committed to a
    ``sink(stream_id, messages)`` callback at each DRAIN barrier
    (``sink=``-mode, what the scenario suite's export collector uses —
    committing at the barrier is what makes "the sender's ``drain()``
    returned" imply "the collector has the full stream").

Credit-based flow control (see :mod:`repro.net.wire`) propagates
backpressure across the wire: the receiver replenishes credit only after
its local republish returns, and a republish into a full queued lane
blocks — so a slow subscriber three hops away still paces the original
publisher, the same contract the in-process bus gives.

Robustness (both optional, off by default for raw-socket endpoints):

**Authentication** — give both ends a shared ``secret`` and every HELLO is
challenged: the receiver sends a random nonce, the sender answers with
``HMAC-SHA256(secret, nonce + stream_id)``, and a wrong or missing answer
closes the connection before any credit is granted — an unauthenticated
peer can never feed a DATA frame into the pool.

**Reconnect** — a ``LaneTransport`` built via :meth:`LaneTransport.connect`
(it knows its address) rides out transient connection loss: bounded
exponential-backoff redial, re-handshake, then a full resend of the
stream's send history on the fresh connection.  Full-history resend is
what makes reconnect *correct* here: the receiver's sink commits a
per-connection snapshot at each DRAIN (replacing the stream's previous
commit), so the fresh connection must carry the complete stream, and a
``drain()`` interrupted by the loss retries its token on the new
connection.  Bus-mode republish stays exactly-once for named streams via
a per-stream delivered-count (resent prefixes are skipped); credit
starvation is *not* a reconnect trigger — a stalled peer is alive, just
slow, and redialing it would only duplicate pressure.

**Same-host shm fast path** (opt-in: ``LaneTransport(shm=True)``) — right
after HELLO the sender offers a shared-memory upgrade: a ``SHM_OFFER``
carrying its kernel boot id plus the name of a probe segment holding a
random token.  The receiver accepts only if the boot id matches *and* it
can read the token back out of the probe (both ends demonstrably share
one ``/dev/shm`` namespace); it then creates an SPSC ring segment
(:class:`repro.shm.ring.ShmRing`) and answers ``SHM_ACK`` with its name.
The sender's next frame is ``SHM_SWITCH`` — the last sender->receiver
TCP frame — after which every DATA/DRAIN/CLOSE frame rides the ring with
the identical frame grammar (CRC trailer included), zero syscalls and
zero kernel copies; CREDIT/DRAIN_ACK/CHALLENGE keep to TCP, which also
serves as the receiver's liveness check on the ring.  Any failure at any
step (other host, no shm, tiny ``/dev/shm``, stale ring) just declines
and the stream stays on TCP — the fallback is always the proven path.
Reconnects renegotiate from scratch on the fresh connection; chaos
``wire_corrupt`` faults tamper ring frames exactly as they would TCP
frames, and the receiver's CRC check drops the connection identically.
"""

from __future__ import annotations

import hashlib
import hmac
import itertools
import os
import socket
import threading
import time
from typing import Callable, Optional, Sequence

from repro import chaos
from repro.core.bag import Message
from repro.core.binpipe import deserialize, serialize
from repro.obs import metrics as obs_metrics
from repro.obs import trace as otrace
from repro.shm import (SegmentHandle, new_prefix, read_segment, shm_available,
                       unlink_segment, write_segment)
from repro.shm.ring import RING_BYTES, ShmRing, boot_id

from .wire import (T_AUTH, T_CHALLENGE, T_CLOSE, T_CREDIT, T_DATA, T_DRAIN,
                   T_DRAIN_ACK, T_HELLO, T_SHM_ACK, T_SHM_OFFER, T_SHM_SWITCH,
                   FrameSocket, WireError, decode_data, decode_u32,
                   encode_data, encode_u32)


class TransportError(ConnectionError):
    """The bridge to the peer is gone (or starved past its timeout)."""


def _as_secret(secret: "str | bytes | None") -> Optional[bytes]:
    if secret is None or isinstance(secret, bytes):
        return secret
    return secret.encode("utf-8")


def _auth_mac(secret: bytes, nonce: bytes, stream_id: str) -> bytes:
    return hmac.new(secret, bytes(nonce) + stream_id.encode("utf-8"),
                    hashlib.sha256).digest()


class _CreditGate:
    """Blocking message-credit counter shared by sender threads.

    ``acquire_up_to(n)`` blocks until at least one credit is available and
    takes up to ``n`` — partial grants shrink the DATA batch rather than
    stall it, so a window narrower than the sender's flush batch can never
    deadlock.  ``abort`` wakes every waiter with the transport's death.
    """

    def __init__(self, stall_counter=None) -> None:
        self._avail = 0
        self._err: Optional[BaseException] = None
        self._cond = threading.Condition()
        self.stalls = 0                # acquires that had to wait
        self.granted = 0               # lifetime total for this connection
        self._stall_counter = stall_counter    # metrics mirror (optional)

    def grant(self, n: int) -> None:
        with self._cond:
            self._avail += n
            self.granted += n
            self._cond.notify_all()

    def wait_granted(self, timeout: float) -> None:
        """Block until the peer has granted at least once — its proof of
        accepting this connection (credit is only ever sent post-auth)."""
        deadline = time.monotonic() + timeout
        with self._cond:
            while self.granted == 0:
                if self._err is not None:
                    raise TransportError(
                        f"transport closed while awaiting first credit: "
                        f"{self._err!r}") from self._err
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TransportError(
                        f"no credit from peer within {timeout}s of "
                        "(re)connecting — handshake rejected or stalled")
                self._cond.wait(remaining)

    def abort(self, err: BaseException) -> None:
        with self._cond:
            if self._err is None:
                self._err = err
            self._cond.notify_all()

    def acquire_up_to(self, n: int, timeout: float) -> int:
        deadline = time.monotonic() + timeout
        t_wait0 = 0
        with self._cond:
            waited = False
            while self._avail == 0:
                if self._err is not None:
                    raise TransportError(
                        f"transport closed while awaiting credit: "
                        f"{self._err!r}") from self._err
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TransportError(
                        f"no credit from peer within {timeout}s "
                        "(remote bus stalled or unreachable)")
                if not waited:
                    waited = True
                    t_wait0 = time.perf_counter_ns()
                self._cond.wait(remaining)
            if waited:
                self.stalls += 1
                if self._stall_counter is not None:
                    self._stall_counter.inc()
                tr = otrace.TRACER
                if tr is not None:
                    tr.emit("transport.credit_stall", "transport", t_wait0,
                            time.perf_counter_ns())
            take = min(n, self._avail)
            self._avail -= take
            return take


class LaneTransport:
    """Socket writer end of a bridged lane (see module docstring).

    ``flush_batch`` bounds how many buffered messages one DATA frame
    carries; the credit window may shrink a frame further, and so does
    ``FRAME_BYTES_TARGET`` — frames are also cut by payload size, so
    MB-scale sensor messages can never assemble a frame the receiver's
    ``MAX_FRAME_BYTES`` sanity cap would (deterministically, on every
    retry) reject.  ``timeout`` bounds every wait against the peer
    (credit, drain ack) — a dead or wedged peer fails the bridge instead
    of hanging it.

    With an ``address`` (what :meth:`connect` provides), connection loss
    triggers up to ``reconnect_attempts`` redials with exponential backoff
    (``reconnect_backoff`` doubling per try), after which the transport is
    permanently failed.  Reconnect re-handshakes (HELLO, auth if
    ``secret``) and resends the whole send history — see the module
    docstring for why that is the correct recovery under snapshot-commit
    sinks.  ``secret`` enables answering the receiver's HMAC challenge.
    """

    #: cut a DATA frame once its payload reaches this many bytes (always
    #: at least one message per frame) — far under wire.MAX_FRAME_BYTES
    FRAME_BYTES_TARGET = 8 << 20

    def __init__(self, sock: socket.socket, stream_id: str = "",
                 flush_batch: int = 128, timeout: float = 30.0,
                 secret: "str | bytes | None" = None,
                 address: Optional[tuple[str, int]] = None,
                 reconnect_attempts: int = 4,
                 reconnect_backoff: float = 0.05,
                 shm: bool = False):
        if flush_batch < 1:
            raise ValueError("flush_batch must be >= 1")
        self.stream_id = stream_id
        self._flush_batch = flush_batch
        self._timeout = timeout
        self._secret = _as_secret(secret)
        self._address = address
        self._reconnect_attempts = reconnect_attempts
        self._reconnect_backoff = reconnect_backoff
        self._shm_wanted = shm
        self._ring: Optional[ShmRing] = None
        self._pending_ring: Optional[ShmRing] = None
        self._probe: Optional[SegmentHandle] = None
        self._frame_target = self.FRAME_BYTES_TARGET
        # per-instance metrics scope; the old counter attributes survive
        # as read-only properties below (deprecated shims)
        self._metrics = obs_metrics.scope("transport")
        self._m_messages = self._metrics.counter("messages_sent")
        self._m_frames = self._metrics.counter("frames_sent")
        self._m_reconnects = self._metrics.counter("reconnects")
        self._m_shm_switches = self._metrics.counter("shm_switches")
        self._m_credit_stalls = self._metrics.counter("credit_stalls")
        self._buffer: list[Message] = []
        self._send_lock = threading.Lock()   # buffer + frame-write order
        self._state_lock = threading.Lock()  # _gen / _conn_lost / _error
        self._acks: set[int] = set()
        self._ack_cond = threading.Condition()
        self._drain_token = itertools.count(1)
        self._error: Optional[BaseException] = None
        self._conn_lost: Optional[BaseException] = None
        self._closed = False
        self._gen = 0
        self._flaps = 0
        # resend source on reconnect; disabled (None) when redialing is
        # impossible/off, so socketpair-style endpoints pay no memory
        self._history: Optional[list[Message]] = (
            [] if address is not None and reconnect_attempts > 0 else None)
        self._attach(sock)

    @classmethod
    def connect(cls, address: tuple[str, int], stream_id: str = "",
                flush_batch: int = 128, timeout: float = 30.0,
                secret: "str | bytes | None" = None,
                reconnect_attempts: int = 4,
                reconnect_backoff: float = 0.05,
                shm: bool = False) -> "LaneTransport":
        sock = socket.create_connection(address, timeout=timeout)
        sock.settimeout(None)
        return cls(sock, stream_id=stream_id, flush_batch=flush_batch,
                   timeout=timeout, secret=secret, address=address,
                   reconnect_attempts=reconnect_attempts,
                   reconnect_backoff=reconnect_backoff, shm=shm)

    def _attach(self, sock: socket.socket) -> None:
        """Adopt ``sock`` as the live connection: fresh framer, fresh
        credit gate, new reader generation, then HELLO."""
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass                        # not TCP (e.g. a unix socketpair)
        fs = FrameSocket(sock, chaos_key=self.stream_id)
        gate = _CreditGate(stall_counter=self._m_credit_stalls)
        old = getattr(self, "_fs", None)
        if old is not None:
            self._bytes_prior += old.bytes_sent
        else:
            self._bytes_prior = 0
        self._teardown_shm()            # a reconnect renegotiates the ring
        with self._state_lock:
            self._gen += 1
            gen = self._gen
            self._fs = fs
            self._credits = gate
            self._conn_lost = None
        fs.send_frame(T_HELLO, self.stream_id.encode("utf-8"))
        # the ack event gates the first post-HELLO frame: the receiver
        # always answers an offer (accept or decline), so waiting for it
        # makes the carrier deterministic even for one-message streams —
        # conn loss and wait timeout also release it (TCP always works)
        self._shm_ack_evt = threading.Event()
        if not (self._shm_wanted and self._offer_shm(fs)):
            self._shm_ack_evt.set()
        self._reader = threading.Thread(
            target=self._read_loop, args=(fs, gate, gen),
            name=f"transport-rx-{self.stream_id or id(self)}", daemon=True)
        self._reader.start()

    def _teardown_shm(self) -> None:
        """Drop every shm artifact of the previous connection: active and
        pending rings (receiver owns/unlinks the segments) plus our probe
        if the peer never consumed it."""
        with self._state_lock:
            ring, self._ring = self._ring, None
            pending, self._pending_ring = self._pending_ring, None
            probe, self._probe = self._probe, None
        self._frame_target = self.FRAME_BYTES_TARGET
        for r in (ring, pending):
            if r is not None:
                r.close(unlink=False)
        if probe is not None:
            unlink_segment(probe)

    def _offer_shm(self, fs: FrameSocket) -> bool:
        """Propose the same-host upgrade: write a random token into a
        probe segment and name it (plus our boot id) in a SHM_OFFER.  Any
        local shm trouble silently skips the offer — TCP always works."""
        if not shm_available():
            return False
        token = os.urandom(16)
        try:
            probe = write_segment(new_prefix("q"), token)
        except OSError:
            return False
        self._probe = probe
        fs.send_frame(T_SHM_OFFER, serialize([
            boot_id().encode("utf-8"), probe.name.encode("utf-8"), token]))
        return True

    def _on_shm_ack(self, body, gen: int) -> None:
        """(Reader thread.)  The peer answered our offer: attach the ring
        it named and stage it; the *sending* side performs the actual
        switch at the next frame boundary so total order is preserved."""
        probe, self._probe = self._probe, None
        if probe is not None:           # peer normally unlinks it; be sure
            unlink_segment(probe)
        try:
            names = deserialize(bytes(body))
        except Exception:
            return
        if not names or not names[0]:
            return                      # declined: stay on TCP
        try:
            ring = ShmRing.attach(names[0].decode("utf-8"),
                                  chaos_key=self.stream_id)
        except (WireError, OSError):
            return
        with self._state_lock:
            if gen != self._gen or self._closed:
                ring.close(unlink=False)
                return
            self._pending_ring = ring

    def _on_shm_ack_done(self, gen: int) -> None:
        with self._state_lock:
            if gen == self._gen:
                self._shm_ack_evt.set()

    @property
    def bytes_sent(self) -> int:
        ring = self._ring
        return (self._bytes_prior + self._fs.bytes_sent
                + (ring.bytes_sent if ring is not None else 0))

    @property
    def credit_stalls(self) -> int:
        return self._credits.stalls

    # deprecated counter shims — the counters now live on the transport's
    # ``repro.obs.metrics`` scope; these properties keep every existing
    # caller working
    @property
    def messages_sent(self) -> int:
        return self._m_messages.value

    @property
    def frames_sent(self) -> int:
        return self._m_frames.value

    @property
    def reconnects(self) -> int:
        return self._m_reconnects.value

    @property
    def shm_switches(self) -> int:
        return self._m_shm_switches.value

    @property
    def carrier(self) -> str:
        """What DATA frames currently ride: ``"shm"`` once switched,
        else ``"wire"``."""
        return "shm" if self._ring is not None else "wire"

    # -- receive side (reader thread) --------------------------------------

    def _read_loop(self, fs: FrameSocket, gate: _CreditGate,
                   gen: int) -> None:
        """Reader for connection generation ``gen``.  Grants go to *this*
        connection's gate; a stale reader (its generation superseded by a
        reconnect) must never mark the new connection lost."""
        err: BaseException = TransportError("peer closed the connection")
        try:
            while True:
                ftype, body = fs.recv_frame()
                if ftype is None:
                    break
                if ftype == T_CREDIT:
                    gate.grant(decode_u32(body))
                elif ftype == T_DRAIN_ACK:
                    self._flaps = 0     # a full barrier: the link is good
                    with self._ack_cond:
                        self._acks.add(decode_u32(body))
                        self._ack_cond.notify_all()
                elif ftype == T_CHALLENGE:
                    if self._secret is None:
                        raise WireError(
                            "peer demands authentication but this "
                            "transport has no shared secret")
                    fs.send_frame(
                        T_AUTH, _auth_mac(self._secret, body, self.stream_id))
                elif ftype == T_SHM_ACK:
                    try:
                        self._on_shm_ack(body, gen)
                    finally:
                        self._on_shm_ack_done(gen)
        except (WireError, OSError) as e:
            err = e
        finally:
            with self._state_lock:
                stale = gen != self._gen or self._closed
                if not stale:
                    self._conn_lost = err
            # wake anything blocked on the dead peer — credit waiters raise
            # from acquire, drain waiters re-check the loss and reconnect
            gate.abort(err)
            if not stale:
                self._shm_ack_evt.set()     # never gate sends on a dead conn
                with self._ack_cond:
                    self._ack_cond.notify_all()

    # -- send side ----------------------------------------------------------

    def _check_alive(self) -> None:
        if self._closed:
            raise TransportError("transport is closed")
        if self._error is not None:
            raise TransportError(
                f"transport failed: {self._error!r}") from self._error

    def _note_conn_lost(self, err: BaseException) -> None:
        with self._state_lock:
            if self._conn_lost is None:
                self._conn_lost = err

    def _ensure_conn_locked(self) -> None:
        """(Holding ``_send_lock``.)  If the current connection is gone,
        redial with bounded exponential backoff, re-handshake and resend
        the full history; exhausting the budget permanently fails the
        transport."""
        with self._state_lock:
            cause = self._conn_lost
        if cause is None:
            return
        self._fs.close()                # stale reader unblocks on EOF
        attempts = (self._reconnect_attempts
                    if self._address is not None and self._history is not None
                    and not self._closed
                    # flapping guard: a link that keeps dying right after
                    # each "successful" redial must converge to failure,
                    # not redial forever (the counter resets at drain acks)
                    and self._flaps < self._reconnect_attempts * 4 else 0)
        for attempt in range(attempts):
            time.sleep(min(self._reconnect_backoff * (2 ** attempt), 2.0))
            try:
                sock = socket.create_connection(self._address,
                                                timeout=self._timeout)
                sock.settimeout(None)
                self._attach(sock)
                self._resend_history_locked()
                # a redial only counts once the peer grants credit — that
                # happens strictly after auth, so a rejected peer can't
                # loop on instantly-"successful" empty-history reconnects
                self._credits.wait_granted(self._timeout)
                self._m_reconnects.inc()
                self._flaps += 1
                return
            except (TransportError, OSError) as e:
                cause = e
                self._note_conn_lost(e)
        err = TransportError(
            f"connection lost and not recovered after {attempts} "
            f"reconnect attempts: {cause!r}")
        err.__cause__ = cause
        with self._state_lock:
            if self._error is None:
                self._error = err
        raise err

    def _send_frame(self, ftype: int, body: bytes = b"",
                    trace_ctx: Optional[int] = None) -> None:
        """(Holding ``_send_lock``.)  Emit one sender->receiver frame on
        the active carrier.  A staged ring becomes active *here*: the
        SHM_SWITCH marker is the last TCP frame in this direction, so the
        receiver observes one totally-ordered frame sequence across the
        carrier change.  Raises ``OSError`` on either carrier's death —
        the caller's reconnect handling is carrier-agnostic.
        ``trace_ctx`` rides the frame-header annotation to the receiver
        (see :mod:`repro.net.wire`)."""
        ring = self._ring
        if ring is None:
            if not self._shm_ack_evt.is_set():
                # an offer is outstanding: give the answer a moment so
                # even a one-frame stream gets its negotiated carrier
                self._shm_ack_evt.wait(min(self._timeout, 5.0))
                self._shm_ack_evt.set()
            with self._state_lock:
                pending, self._pending_ring = self._pending_ring, None
            if pending is not None:
                try:
                    self._fs.send_frame(T_SHM_SWITCH)
                except OSError:
                    pending.close(unlink=False)
                    raise
                self._ring = ring = pending
                # ring frames must fit max_frame; shrink the flush cut so
                # a one-message overshoot still has headroom
                self._frame_target = min(self.FRAME_BYTES_TARGET,
                                         ring.max_frame // 2)
                self._m_shm_switches.inc()
        if ring is not None:
            ring.send_frame(ftype, body, timeout=self._timeout,
                            trace_ctx=trace_ctx)
        else:
            self._fs.send_frame(ftype, body, trace_ctx=trace_ctx)

    def _resend_history_locked(self) -> None:
        """Replay every previously-sent message on the fresh connection
        (credit-gated).  The receiver's snapshot sink needs the complete
        stream on this connection; bus-mode receivers dedup the replayed
        prefix by delivered-count."""
        pos = 0
        while pos < len(self._history):
            left = len(self._history) - pos
            n = self._credits.acquire_up_to(min(left, self._flush_batch),
                                            self._timeout)
            batch = self._history[pos:pos + n]
            self._send_frame(T_DATA, encode_data(batch))
            self._m_frames.inc()
            pos += n

    def send_message(self, msg: Message) -> None:
        """Buffer one message; flush when the batch threshold is reached.
        This is the callback a bus bridge's lane delivers into."""
        with self._send_lock:
            self._check_alive()
            self._buffer.append(msg)
            if len(self._buffer) >= self._flush_batch:
                self._flush_locked()

    def send_batch(self, msgs: Sequence[Message]) -> None:
        with self._send_lock:
            self._check_alive()
            self._buffer.extend(msgs)
            if len(self._buffer) >= self._flush_batch:
                self._flush_locked()

    def _flush_locked(self) -> None:
        while self._buffer:
            self._check_alive()
            self._ensure_conn_locked()
            try:
                n = self._credits.acquire_up_to(
                    min(len(self._buffer), self._flush_batch), self._timeout)
            except TransportError:
                if self._conn_lost is not None and not self._closed:
                    continue        # connection died under us — redial
                raise
            size = 0
            for i in range(n):          # byte-bound the frame as well
                size += len(self._buffer[i].data)
                if size >= self._frame_target:
                    unused = n - (i + 1)
                    if unused:          # return the credits we won't use
                        self._credits.grant(unused)
                    n = i + 1
                    break
            batch, self._buffer = self._buffer[:n], self._buffer[n:]
            if self._history is not None:
                # into history *before* the send: if the frame dies on the
                # wire the reconnect resend already covers this batch
                self._history.extend(batch)
            tr = otrace.TRACER
            slot = None
            if tr is not None:
                slot = tr.begin("transport.send", "transport",
                                attrs={"n": len(batch),
                                       "stream": self.stream_id})
            try:
                self._send_frame(T_DATA, encode_data(batch),
                                 trace_ctx=slot[0] if slot else None)
            except OSError as e:
                if slot is not None:
                    tr.end(slot)
                if self._history is not None:
                    self._note_conn_lost(e)
                    continue        # redial at the top of the loop
                raise TransportError(f"send failed: {e!r}") from e
            if slot is not None:
                tr.end(slot)
            self._m_messages.inc(len(batch))
            self._m_frames.inc()

    def flush(self) -> None:
        """Push every buffered message onto the wire (credit-gated)."""
        with self._send_lock:
            self._flush_locked()

    def drain(self) -> None:
        """Barrier: returns once everything sent so far has been
        republished on (and committed by) the remote end.

        A connection lost mid-barrier retries the *same* token on the
        reconnected stream (after the history resend), so a returned
        ``drain()`` always means the receiver committed the complete
        stream — ack'd tokens are only ever sent commit-first."""
        tr = otrace.TRACER
        if tr is None:
            self._drain_impl(None)
            return
        with tr.span("transport.drain", "transport",
                     attrs={"stream": self.stream_id}) as slot:
            self._drain_impl(slot[0])

    def _drain_impl(self, trace_ctx: Optional[int]) -> None:
        token = next(self._drain_token)
        retries = 0
        while True:
            with self._send_lock:
                self._flush_locked()
                try:
                    self._send_frame(T_DRAIN, encode_u32(token),
                                     trace_ctx=trace_ctx)
                except OSError as e:
                    if self._history is not None \
                            and retries <= self._reconnect_attempts:
                        self._note_conn_lost(e)
                        retries += 1
                        continue
                    raise TransportError(f"drain send failed: {e!r}") from e
            deadline = time.monotonic() + self._timeout
            lost = False
            with self._ack_cond:
                while token not in self._acks:
                    if self._error is not None:
                        raise TransportError(
                            f"peer lost before drain ack: {self._error!r}"
                        ) from self._error
                    if self._conn_lost is not None:
                        lost = True     # redial + resend, then retry token
                        break
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TransportError(
                            f"no drain ack within {self._timeout}s")
                    self._ack_cond.wait(remaining)
                else:
                    self._acks.discard(token)
                    return
            if not lost or self._history is None \
                    or retries > self._reconnect_attempts:
                with self._state_lock:
                    cause = self._conn_lost
                raise TransportError(
                    f"peer lost before drain ack: {cause!r}") from cause
            retries += 1

    def close(self) -> None:
        """Best-effort orderly shutdown: flush, CLOSE, close the socket.
        Never raises for a peer that is already gone — ``drain()`` is the
        call that *verifies* delivery; ``close()`` only releases."""
        if self._closed:
            return
        try:
            # flush before marking closed: _check_alive() inside the
            # flush loop treats a closed transport as dead, so the other
            # order would silently drop the buffered tail
            with self._send_lock:
                if self._buffer and self._error is None:
                    self._flush_locked()
                with self._state_lock:
                    self._closed = True
                self._send_frame(T_CLOSE)
        except (TransportError, OSError):
            pass
        finally:
            self._closed = True
        ring = self._ring
        if ring is not None:
            ring.close_write()          # reader drains, then clean EOF
        self._fs.close()
        self._reader.join(timeout=5.0)
        self._teardown_shm()


class RemoteBus:
    """Listener endpoint: receives bridged streams and republishes them.

    ``bus``  — every DATA batch is republished into this local
    :class:`MessageBus` via ``publish_batch`` (per-message subscribers see
    the sender's publish order; batch subscribers see wire framing).
    ``sink`` — per-stream collection: messages buffer per connection and
    ``sink(stream_id, messages)`` is called with a full snapshot at every
    DRAIN barrier, *before* the ack is sent.  A stream that dies without
    reaching a barrier is never committed — a crashed sender's partial
    stream can't contaminate a collector (its retry commits the complete
    one).  At least one of the two must be given; both may be.

    ``window`` is the per-connection credit window in messages — the
    remote analogue of a lane's ``maxsize``.

    ``secret`` arms the HELLO challenge: every connection must answer
    ``HMAC-SHA256(secret, nonce + stream_id)`` before its first credit —
    failures are recorded in ``auth_failures`` and the socket is closed
    without ever accepting a DATA frame.  For *named* streams the bus
    republish is reconnect-idempotent: a per-stream delivered-count skips
    the prefix a reconnecting sender replays (unnamed streams can't be
    told apart across connections, so they get at-least-once on redial).
    """

    def __init__(self, bus=None, sink: Optional[Callable[[str, list[Message]],
                                                         None]] = None,
                 host: str = "127.0.0.1", port: int = 0, window: int = 256,
                 secret: "str | bytes | None" = None, shm: bool = True,
                 shm_ring_bytes: int = RING_BYTES):
        if bus is None and sink is None:
            raise ValueError("RemoteBus needs a bus and/or a sink")
        if window < 1:
            raise ValueError("window must be >= 1")
        self._bus = bus
        self._sink = sink
        self._host = host
        self._port = port
        self._window = window
        self._secret = _as_secret(secret)
        self._shm = shm
        self._shm_ring_bytes = shm_ring_bytes
        self._listener: Optional[socket.socket] = None
        self._accept_thread: Optional[threading.Thread] = None
        self._conns: list[FrameSocket] = []
        self._threads: list[threading.Thread] = []
        self._rings: list[ShmRing] = []        # live, receiver-owned
        self._lock = threading.Lock()
        self._stopped = False
        self._delivered: dict[str, int] = {}   # per named stream, bus-mode
        #: per named stream: what its DATA frames last rode ("wire"/"shm")
        self.stream_carriers: dict[str, str] = {}
        self.messages_received = 0
        self.frames_received = 0
        self.shm_streams = 0
        self.auth_failures = 0
        self.errors: list[BaseException] = []

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> tuple[str, int]:
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind((self._host, self._port))
        listener.listen(64)
        self._listener = listener
        self._port = listener.getsockname()[1]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"remotebus-{self._port}",
            daemon=True)
        self._accept_thread.start()
        return self.address

    @property
    def address(self) -> tuple[str, int]:
        if self._listener is None:
            raise RuntimeError("RemoteBus is not started")
        return (self._host, self._port)

    def stop(self) -> None:
        """Close the listener and every live connection; join handlers."""
        self._stopped = True
        if self._listener is not None:
            # shutdown() first: close() alone does not wake the accept()
            # blocked in the accept thread
            try:
                self._listener.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._listener.close()
            except OSError:
                pass
        with self._lock:
            conns, self._conns = self._conns, []
            threads, self._threads = self._threads, []
        for fs in conns:
            fs.close()
        for t in threads:
            t.join(timeout=5.0)
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
        # handlers unlink their rings on exit; reap any a wedged handler
        # (join timeout above) left behind — stop() must never leak shm
        with self._lock:
            rings, self._rings = self._rings, []
        for r in rings:
            r.close(unlink=True)

    def __enter__(self) -> "RemoteBus":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- serving -------------------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._stopped:
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return                   # listener closed
            try:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:
                pass
            fs = FrameSocket(sock)
            t = threading.Thread(target=self._handle, args=(fs,),
                                 name=f"remotebus-conn-{self._port}",
                                 daemon=True)
            with self._lock:
                if self._stopped:
                    # stop() already swapped the registries: a connection
                    # accepted in this race window must not leak past it
                    fs.close()
                    return
                self._conns.append(fs)
                self._threads.append(t)
            t.start()

    def _grant(self, fs: FrameSocket, stream_id: str, n: int) -> None:
        """Send a credit grant — unless a ``credit_starve`` fault withholds
        it, in which case the sender must ride out its credit timeout."""
        plan = chaos.active_plan()
        if plan is not None \
                and plan.probe("credit_starve", stream_id) is not None:
            return
        fs.send_frame(T_CREDIT, encode_u32(n))

    def _authenticate(self, fs: FrameSocket,
                      stream_id: str) -> tuple[bool, Optional[bytes]]:
        """Challenge the fresh connection; ``(ok, stashed_offer)``.  The
        sender fires its SHM_OFFER right after HELLO — before it can see
        our challenge — so an offer arriving while we await AUTH is
        stashed and processed after a *successful* handshake (an
        unauthenticated peer gets no ring, same as no credit)."""
        if self._secret is None:
            return True, None
        nonce = os.urandom(16)
        fs.send_frame(T_CHALLENGE, nonce)
        offer: Optional[bytes] = None
        while True:
            ftype, body = fs.recv_frame()
            if ftype == T_SHM_OFFER and offer is None:
                offer = bytes(body)
                continue
            break
        if ftype != T_AUTH or not hmac.compare_digest(
                bytes(body), _auth_mac(self._secret, nonce, stream_id)):
            self.auth_failures += 1
            self.errors.append(WireError(
                f"authentication failed for stream {stream_id!r}"))
            return False, None
        return True, offer

    def _shm_accept(self, fs: FrameSocket, stream_id: str,
                    body) -> Optional[ShmRing]:
        """Answer a SHM_OFFER.  Accept only with same-host *proof* — the
        peer's boot id equals ours and its probe segment is attachable
        with the advertised token inside — then create the ring, register
        it for reaping, and SHM_ACK its name.  Every failure path ACKs a
        decline: the stream just stays on TCP."""
        ring: Optional[ShmRing] = None
        try:
            if self._shm and shm_available():
                peer_boot, probe_name, token = deserialize(bytes(body))[:3]
                local = boot_id()
                if local and peer_boot.decode("utf-8") == local:
                    probe = SegmentHandle(probe_name.decode("utf-8"), 0,
                                          len(token))
                    if read_segment(probe, unlink=True) == token:
                        ring = ShmRing.create(
                            new_prefix("r"), capacity=self._shm_ring_bytes,
                            chaos_key=stream_id)
        except (WireError, OSError, ValueError, IndexError):
            ring = None
        if ring is not None:
            with self._lock:
                if self._stopped:
                    ring.close(unlink=True)
                    ring = None
                else:
                    self._rings.append(ring)
        try:
            fs.send_frame(T_SHM_ACK, serialize(
                [ring.name.encode("utf-8")] if ring is not None else []))
        except OSError:
            self._drop_ring(ring)
            raise
        return ring

    def _drop_ring(self, ring: Optional[ShmRing]) -> None:
        if ring is None:
            return
        with self._lock:
            if ring in self._rings:
                self._rings.remove(ring)
        ring.close(unlink=True)

    def _handle(self, fs: FrameSocket) -> None:
        stream_id = ""
        stream: list[Message] = []
        seen = 0                 # messages received on THIS connection
        ring: Optional[ShmRing] = None          # active shm carrier
        staged: Optional[ShmRing] = None        # ack'd, awaiting SWITCH
        try:
            ftype, body = fs.recv_frame()
            if ftype is None:
                return
            if ftype != T_HELLO:
                raise WireError(f"expected HELLO, got frame type {ftype}")
            stream_id = body.decode("utf-8")
            fs.chaos_key = stream_id or fs.chaos_key
            ok, offer = self._authenticate(fs, stream_id)
            if not ok:
                return          # finally: closes before any DATA/credit
            with self._lock:
                already = self._delivered.get(stream_id, 0) \
                    if stream_id else 0
                if stream_id:
                    self.stream_carriers[stream_id] = "wire"
            self._grant(fs, stream_id, self._window)
            if offer is not None:
                staged = self._shm_accept(fs, stream_id, offer)
            while True:
                if ring is not None:
                    ftype, body = ring.recv_frame(eof_check=fs.eof_seen)
                else:
                    ftype, body = fs.recv_frame()
                if ftype is None or ftype == T_CLOSE:
                    return
                if ftype == T_SHM_OFFER:
                    staged = self._shm_accept(fs, stream_id, body)
                elif ftype == T_SHM_SWITCH:
                    if staged is None:
                        raise WireError("SHM_SWITCH without an ack'd ring")
                    ring, staged = staged, None
                    self.shm_streams += 1
                    with self._lock:
                        if stream_id:
                            self.stream_carriers[stream_id] = "shm"
                elif ftype == T_DATA:
                    tr = otrace.TRACER
                    t_rx0 = time.perf_counter_ns() if tr is not None else 0
                    msgs = decode_data(body)
                    self.frames_received += 1
                    self.messages_received += len(msgs)
                    if self._bus is not None:
                        # skip the prefix a reconnecting sender replays
                        # (already republished by its previous connection)
                        skip = min(max(already - seen, 0), len(msgs))
                        if len(msgs) > skip:
                            # blocks while downstream lanes are full —
                            # credit is withheld and the sender stalls:
                            # backpressure has crossed the wire
                            self._bus.publish_batch(msgs[skip:])
                    if self._sink is not None:
                        stream.extend(msgs)
                    seen += len(msgs)
                    if stream_id and seen > already:
                        with self._lock:
                            self._delivered[stream_id] = max(
                                self._delivered.get(stream_id, 0), seen)
                    self._grant(fs, stream_id, len(msgs))
                    if tr is not None:
                        # parent = the sender-side span id the frame-header
                        # annotation carried, so the recv stitches under it
                        carrier = ring if ring is not None else fs
                        tr.emit("transport.recv", "transport", t_rx0,
                                time.perf_counter_ns(),
                                parent=carrier.last_trace_ctx,
                                attrs={"n": len(msgs), "stream": stream_id})
                elif ftype == T_DRAIN:
                    if self._bus is not None:
                        try:
                            self._bus.drain()
                        except BaseException as e:  # noqa: BLE001
                            # a *remote subscriber's* deferred error is the
                            # remote side's bookkeeping; the barrier (all
                            # deliveries done) still holds
                            self.errors.append(e)
                    if self._sink is not None:
                        # commit-before-ack: when the sender's drain()
                        # returns, the collector verifiably has the stream
                        self._sink(stream_id, list(stream))
                    fs.send_frame(T_DRAIN_ACK, body)
                else:
                    raise WireError(f"unexpected frame type {ftype}")
        except (WireError, OSError) as e:
            if not self._stopped:
                self.errors.append(e)
        except BaseException as e:      # noqa: BLE001 - a local subscriber
            # raised during republish: record it and drop the connection —
            # the sender sees TransportError (credit stops), never a
            # silent stall
            self.errors.append(e)
        finally:
            fs.close()
            self._drop_ring(ring)
            self._drop_ring(staged)
