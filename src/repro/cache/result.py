"""Result-level cache: scenario keys, entry codec, hit/miss accounting.

:class:`ResultCache` is what ``ScenarioSuite.run(cache=...)`` talks to.
It owns three things:

* **key derivation** — :meth:`ResultCache.scenario_key` folds every term
  that can move a verdict into one SHA-256: the store format version, the
  logic version (``REPRO_LOGIC_VERSION`` env or constructor arg — bump it
  when user-logic *code* changes under an unchanged ref), the resolved
  Pallas interpret mode (``REPRO_PALLAS_INTERPRET``), the aggregator
  tolerance, the scenario's canonical parameter fingerprint
  (:meth:`repro.core.simulation.Scenario.fingerprint`), the content
  digests of every bag shard and of the golden bag, and — for importing
  scenarios — the keys of every provider, so a change anywhere upstream
  in the routing DAG invalidates every scenario downstream of it.

* **entry codec** — :class:`CachedResult` round-trips a scenario's full
  outcome: verdict (status/diffs), per-topic :class:`TopicMetrics`
  including their timestamp multisets (bit-identical checksums and gap
  percentiles on rehydrate), the merged output bag image, replay counts,
  and — when the scenario exports topics — its committed export stream,
  so an importer downstream of a cached exporter replays exactly the
  stream a live run would have fed it.

* **bag digesting** — memoized per ``(path, size, mtime)`` so one warm
  suite run digests each shard once even when many scenarios share it.

Loads are corruption-safe end to end: a store-level miss, a garbled
entry, or a codec mismatch all return ``None`` (replay), never raise.
"""

from __future__ import annotations

import hashlib
import json
import os
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.core.aggregation import Diff, TopicMetrics
from repro.core.bag import Bag, Message, bag_content_digest
from repro.obs import metrics as obs_metrics
from repro.obs import trace as otrace

from .store import CacheStore

#: bump when user-logic *code* changes under an unchanged module:attr ref
LOGIC_VERSION_ENV = "REPRO_LOGIC_VERSION"

#: entry/key format version — part of every key, so a codec change never
#: rehydrates against a stale layout
FORMAT = 1


# -- message-stream codec -----------------------------------------------------

def encode_message_stream(msgs: Sequence[Message]) -> bytes:
    """Order-preserving bag-image encoding of an export stream.  Bags
    write and read chunks (and records within them) sequentially, so the
    round-trip reproduces the stream byte- and order-exactly."""
    bag = Bag.open_write(backend="memory")
    for m in msgs:
        bag.write_message(m)
    bag.close()
    return bag.chunked_file.image()


def decode_message_stream(image: bytes) -> list[Message]:
    bag = Bag.open_read(backend="memory", image=image)
    try:
        return list(bag.read_messages())
    finally:
        bag.close()


# -- metrics codec ------------------------------------------------------------

_METRIC_FIELDS = ("count", "bytes_total", "t_min", "t_max", "gap_p50_ns",
                  "gap_p90_ns", "gap_p99_ns", "checksum", "sketch", "theta")


def _metrics_encode(metrics: dict[str, TopicMetrics],
                    ) -> tuple[list[dict], dict[str, bytes]]:
    rows: list[dict] = []
    blobs: dict[str, bytes] = {}
    for k, topic in enumerate(sorted(metrics)):
        m = metrics[topic]
        row = {"topic": topic}
        row.update({f: getattr(m, f) for f in _METRIC_FIELDS})
        row["has_ts"] = m.timestamps is not None
        rows.append(row)
        if m.timestamps is not None:
            blobs[f"ts{k}"] = np.ascontiguousarray(
                m.timestamps, dtype=np.int64).tobytes()
    return rows, blobs


def _metrics_decode(rows: list[dict],
                    blobs: dict[str, bytes]) -> dict[str, TopicMetrics]:
    out: dict[str, TopicMetrics] = {}
    for k, row in enumerate(rows):
        ts = (np.frombuffer(blobs[f"ts{k}"], dtype=np.int64)
              if row.get("has_ts") else None)
        out[row["topic"]] = TopicMetrics(
            topic=row["topic"], timestamps=ts,
            **{f: row[f] for f in _METRIC_FIELDS})
    return out


# -- the cached outcome -------------------------------------------------------

@dataclass
class CachedResult:
    """Everything a hit must rehydrate — see module docstring."""
    scenario: str                       # name at record time (informational)
    passed: bool
    vacuous: bool
    diffs: list[dict] = field(default_factory=list)
    metrics: dict[str, TopicMetrics] = field(default_factory=dict)
    output_image: bytes = b""
    export_image: Optional[bytes] = None   # committed export stream, if any
    messages_in: int = 0
    messages_out: int = 0
    messages_dropped: int = 0
    partitions: int = 0
    shards: int = 1
    wall_time_s: float = 0.0            # the *recorded* (cold) wall time

    def rebuild_diffs(self) -> list[Diff]:
        return [Diff(topic=d["topic"], field=d["field"],
                     expected=d.get("expected"), actual=d.get("actual"),
                     detail=d.get("detail", "")) for d in self.diffs]


def _interpret_token() -> str:
    """The resolved Pallas interpret mode as a key term.  Uses the same
    policy point every kernel entry honors (explicit env > platform
    default), so an ``REPRO_PALLAS_INTERPRET`` flip — which can move
    compiled-vs-interpreted numerics — forces a clean re-replay."""
    from repro.kernels.compat import resolve_interpret
    return "interpret" if resolve_interpret(None) else "compiled"


class ResultCache:
    """High-level cache face over a :class:`CacheStore` (see module doc).

    ``logic_version`` defaults to ``$REPRO_LOGIC_VERSION`` (or ``"0"``);
    it is the escape hatch for the one thing content addressing cannot
    see — the *code* behind an unchanged ``module:attr`` logic ref.
    """

    def __init__(self, store: "CacheStore | str",
                 logic_version: Optional[str] = None):
        self.store = (store if isinstance(store, CacheStore)
                      else CacheStore(store))
        self.logic_version = (logic_version if logic_version is not None
                              else os.environ.get(LOGIC_VERSION_ENV, "0"))
        # counters live in the repro.obs.metrics registry; the attribute
        # names below stay readable as deprecated property shims
        self._metrics = obs_metrics.scope("cache")
        self._m_hits = self._metrics.counter("hits")
        self._m_misses = self._metrics.counter("misses")
        self._m_puts = self._metrics.counter("puts")
        self._m_put_errors = self._metrics.counter("put_errors")
        self._digest_memo: dict[tuple, str] = {}

    @property
    def hits(self) -> int:
        return self._m_hits.value

    @property
    def misses(self) -> int:
        return self._m_misses.value

    @property
    def puts(self) -> int:
        return self._m_puts.value

    @property
    def put_errors(self) -> int:
        return self._m_put_errors.value

    # -- key derivation ------------------------------------------------------

    def bag_digest(self, path: str) -> str:
        """Streaming content digest of a disk bag, memoized per
        ``(path, size, mtime_ns)`` — a touched file re-digests, an
        untouched one is a stat call."""
        st = os.stat(path)
        memo_key = (os.path.abspath(path), st.st_size, st.st_mtime_ns)
        got = self._digest_memo.get(memo_key)
        if got is None:
            got = bag_content_digest(path)
            self._digest_memo[memo_key] = got
        return got

    def scenario_key(self, fingerprint: str, bag_digests: Sequence[str],
                     golden_digest: Optional[str],
                     provider_keys: Sequence[str] = (),
                     tolerance: int = 0) -> str:
        h = hashlib.sha256()
        h.update(json.dumps({
            "format": FORMAT,
            "logic_version": self.logic_version,
            "kernel": _interpret_token(),
            "tolerance": tolerance,
            "fingerprint": fingerprint,
            "bags": list(bag_digests),
            "golden": golden_digest,
            "providers": list(provider_keys),
        }, sort_keys=True).encode())
        return h.hexdigest()

    # -- load / store --------------------------------------------------------

    def load(self, key: str,
             require_exports: bool = False) -> Optional[CachedResult]:
        """Rehydrate one entry; ``None`` is a miss (absent, corrupt, or a
        codec mismatch).  ``require_exports=True`` additionally treats an
        entry recorded *without* a committed export stream as a miss —
        the shape a suite needs when this scenario's exports are routed
        to importers this run but weren't when the entry was written."""
        tr = otrace.TRACER
        if tr is None:
            return self._load_impl(key, require_exports)
        slot = tr.begin("cache.load", "cache")
        out = self._load_impl(key, require_exports)
        otrace.Tracer.set_attrs(slot, {"key": key[:12],
                                       "hit": out is not None})
        otrace.Tracer.end(slot)
        return out

    def _load_impl(self, key: str,
                   require_exports: bool = False) -> Optional[CachedResult]:
        got = self.store.get(key)
        if got is None:
            self._m_misses.inc()
            return None
        meta, blobs = got
        try:
            result = CachedResult(
                scenario=meta["scenario"],
                passed=bool(meta["passed"]),
                vacuous=bool(meta["vacuous"]),
                diffs=list(meta.get("diffs", [])),
                metrics=_metrics_decode(meta.get("metrics", []), blobs),
                output_image=blobs["output"],
                export_image=blobs.get("exports"),
                messages_in=int(meta["messages_in"]),
                messages_out=int(meta["messages_out"]),
                messages_dropped=int(meta["messages_dropped"]),
                partitions=int(meta["partitions"]),
                shards=int(meta["shards"]),
                wall_time_s=float(meta.get("wall_time_s", 0.0)),
            )
        except (KeyError, TypeError, ValueError):
            # codec mismatch reads as a miss, exactly like corruption
            self._m_misses.inc()
            return None
        if require_exports and result.export_image is None:
            self._m_misses.inc()
            return None
        self._m_hits.inc()
        return result

    def put(self, key: str, result: CachedResult) -> bool:
        """Write one entry; returns False (and counts) instead of raising
        on I/O failure — a full disk costs cache coverage, not the suite."""
        rows, blobs = _metrics_encode(result.metrics)
        blobs["output"] = result.output_image
        if result.export_image is not None:
            blobs["exports"] = result.export_image
        meta = {
            "scenario": result.scenario,
            "passed": result.passed,
            "vacuous": result.vacuous,
            "diffs": result.diffs,
            "metrics": rows,
            "messages_in": result.messages_in,
            "messages_out": result.messages_out,
            "messages_dropped": result.messages_dropped,
            "partitions": result.partitions,
            "shards": result.shards,
            "wall_time_s": result.wall_time_s,
        }
        tr = otrace.TRACER
        slot = tr.begin("cache.put", "cache") if tr is not None else None
        try:
            self.store.put(key, meta, blobs)
        except (OSError, ValueError):
            self._m_put_errors.inc()
            if slot is not None:
                otrace.Tracer.set_attrs(slot, {"key": key[:12], "ok": False})
                otrace.Tracer.end(slot)
            return False
        self._m_puts.inc()
        if slot is not None:
            otrace.Tracer.set_attrs(slot, {"key": key[:12], "ok": True})
            otrace.Tracer.end(slot)
        return True

    @property
    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "puts": self.puts, "put_errors": self.put_errors}
