"""Content-addressed result cache: incremental suite re-execution.

The platform's dominant workload at fleet scale is the *unchanged re-run*:
regression suites replayed against mostly-unchanged recorded data, where
almost every scenario recomputes a verdict that is provably identical to
the last one.  Replay here is deterministic and bit-identical across
backends, carriers and replay shapes (ARCHITECTURE.md §5–8), which makes
a cached result *substitutable* for a recomputed one — so the hot path of
a warm suite collapses from full replay to a metadata read.

Key derivation (see :meth:`ResultCache.scenario_key`)::

    key = H(format, logic version, kernel/interpret config,
            aggregator tolerance, Scenario.fingerprint(),
            per-shard bag content digests, golden bag digest,
            provider keys of every imported-from scenario)

Every term is content-addressed: a single flipped byte in a bag, any
scenario parameter change, a logic-version bump, or an interpret-mode
flip produces a different key and a clean re-replay.  Store entries are
written atomically and read corruption-safely — a truncated or garbled
entry is a *miss* (fall back to replay), never a suite failure.
"""

from .result import (LOGIC_VERSION_ENV, CachedResult, ResultCache,
                     decode_message_stream, encode_message_stream)
from .store import CacheStore, StoreCorruption

__all__ = [
    "CacheStore", "StoreCorruption",
    "CachedResult", "ResultCache", "LOGIC_VERSION_ENV",
    "encode_message_stream", "decode_message_stream",
]
