"""File-backed content-addressed store with atomic writes and
corruption-safe reads.

One entry per key, one file per entry::

    <root>/<key[:2]>/<key>.rpc      the entry container
    <root>/events.jsonl             append-only get/put/evict event log
                                    (what ``repro.tools.cache_report``
                                    aggregates into hit/miss stats)
    <root>/events.jsonl.1           previous event-log generation: the
                                    log rotates once it passes
                                    ``events_max_bytes``, so a long-lived
                                    store is bounded at ~2x the cap
                                    instead of growing without limit

Entry container layout::

    [8s magic "RPCACHE1"][u32 header_len][header JSON][blob section]

The header JSON carries the caller's ``meta`` dict, a blob table
(``name -> [offset, length]`` relative to the blob section), and a
SHA-256 of the blob section.  :meth:`CacheStore.get` validates magic,
header parse, blob-table bounds and the payload hash; *any* failure —
truncation, bit rot, a concurrent writer's partial file — surfaces as a
miss (``None``), never an exception, so a corrupt store can only cost a
re-replay, not a suite.  Writes land on a temp file in the same
directory and :func:`os.replace` into place, so readers never observe a
half-written entry.
"""

from __future__ import annotations

import hashlib
import json
import os
import struct
import tempfile
import time
from typing import Iterator, Optional

_MAGIC = b"RPCACHE1"
_LEN = struct.Struct("<I")
_SUFFIX = ".rpc"
_EVENTS = "events.jsonl"


class StoreCorruption(Exception):
    """Internal marker for an unreadable entry; never escapes ``get``."""


class CacheStore:
    """Keyed blob store under one root directory (see module docstring).

    ``record_events=False`` turns off the event log (tests that assert
    exact directory contents).  ``events_max_bytes`` caps the log: once
    the current file reaches the cap it is renamed to ``events.jsonl.1``
    (replacing the previous generation) and appending starts over, so
    the store carries at most ~2x the cap of observability data.
    """

    #: default event-log rotation threshold (bytes)
    EVENTS_MAX_BYTES = 4 << 20

    def __init__(self, root: str, record_events: bool = True,
                 events_max_bytes: Optional[int] = None):
        self.root = root
        self.record_events = record_events
        self.events_max_bytes = (self.EVENTS_MAX_BYTES
                                 if events_max_bytes is None
                                 else events_max_bytes)
        os.makedirs(root, exist_ok=True)

    # -- paths ---------------------------------------------------------------

    def path_for(self, key: str) -> str:
        if not key or any(c in key for c in "/\\."):
            raise ValueError(f"bad cache key {key!r}")
        return os.path.join(self.root, key[:2], key + _SUFFIX)

    def _event(self, op: str, key: str, **extra) -> None:
        if not self.record_events:
            return
        rec = {"op": op, "key": key, "t": time.time(), **extra}
        path = os.path.join(self.root, _EVENTS)
        try:
            if self.events_max_bytes:
                try:
                    if os.path.getsize(path) >= self.events_max_bytes:
                        # keep exactly one prior generation; os.replace
                        # is atomic, so a concurrent reader sees either
                        # the old or the new file, never a half-rotation
                        os.replace(path, path + ".1")
                except OSError:
                    pass        # no log yet
            with open(path, "a") as f:
                f.write(json.dumps(rec, sort_keys=True) + "\n")
        except OSError:
            pass            # the event log is observability, never load-bearing

    # -- write path ----------------------------------------------------------

    def put(self, key: str, meta: dict, blobs: dict[str, bytes]) -> str:
        """Atomically write one entry; an existing entry is replaced.
        Returns the entry path."""
        path = self.path_for(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        table: dict[str, list[int]] = {}
        parts: list[bytes] = []
        off = 0
        for name in sorted(blobs):
            data = blobs[name]
            table[name] = [off, len(data)]
            parts.append(data)
            off += len(data)
        payload = b"".join(parts)
        header = json.dumps({
            "meta": meta,
            "blobs": table,
            "payload_sha256": hashlib.sha256(payload).hexdigest(),
            "created": time.time(),
        }, sort_keys=True, default=str).encode()
        fd, tmp = tempfile.mkstemp(prefix=".put-", suffix=_SUFFIX,
                                   dir=os.path.dirname(path))
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(_MAGIC)
                f.write(_LEN.pack(len(header)))
                f.write(header)
                f.write(payload)
            os.replace(tmp, path)       # atomic publish
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._event("put", key, bytes=len(payload) + len(header))
        return path

    # -- read path -----------------------------------------------------------

    def _read_header(self, path: str) -> tuple[dict, int]:
        """(header dict, blob-section offset); raises StoreCorruption."""
        try:
            with open(path, "rb") as f:
                magic = f.read(len(_MAGIC))
                if magic != _MAGIC:
                    raise StoreCorruption("bad magic")
                raw = f.read(_LEN.size)
                if len(raw) != _LEN.size:
                    raise StoreCorruption("truncated length")
                (hlen,) = _LEN.unpack(raw)
                header = f.read(hlen)
                if len(header) != hlen:
                    raise StoreCorruption("truncated header")
                return (json.loads(header.decode()),
                        len(_MAGIC) + _LEN.size + hlen)
        except StoreCorruption:
            raise
        except (OSError, ValueError, UnicodeDecodeError) as e:
            raise StoreCorruption(str(e))

    def get(self, key: str,
            ) -> Optional[tuple[dict, dict[str, bytes]]]:
        """Load one entry as ``(meta, blobs)``; ``None`` on a missing *or
        unreadable* entry — corruption can only cost a replay."""
        path = self.path_for(key)
        if not os.path.exists(path):
            self._event("get", key, hit=False)
            return None
        try:
            header, base = self._read_header(path)
            with open(path, "rb") as f:
                f.seek(base)
                payload = f.read()
            if (hashlib.sha256(payload).hexdigest()
                    != header.get("payload_sha256")):
                raise StoreCorruption("payload hash mismatch")
            blobs: dict[str, bytes] = {}
            for name, (off, length) in header.get("blobs", {}).items():
                if off < 0 or off + length > len(payload):
                    raise StoreCorruption(f"blob {name!r} out of bounds")
                blobs[name] = payload[off:off + length]
            self._event("get", key, hit=True)
            return header.get("meta", {}), blobs
        except StoreCorruption as e:
            self._event("get", key, hit=False, corrupt=str(e))
            return None

    def entry_info(self, key: str) -> Optional[dict]:
        """Header meta + file size/mtime without loading blobs; ``None``
        when missing or unreadable."""
        path = self.path_for(key)
        try:
            st = os.stat(path)
            header, _ = self._read_header(path)
        except (OSError, StoreCorruption):
            return None
        return {"key": key, "meta": header.get("meta", {}),
                "created": header.get("created"),
                "size": st.st_size, "mtime": st.st_mtime}

    def verify(self, key: str) -> bool:
        """Full payload-hash verification of one entry."""
        return self.get(key) is not None

    # -- enumeration / maintenance -------------------------------------------

    def keys(self) -> Iterator[str]:
        for sub in sorted(os.listdir(self.root)):
            d = os.path.join(self.root, sub)
            if len(sub) != 2 or not os.path.isdir(d):
                continue
            for name in sorted(os.listdir(d)):
                if name.endswith(_SUFFIX):
                    yield name[:-len(_SUFFIX)]

    def delete(self, key: str) -> bool:
        try:
            os.unlink(self.path_for(key))
            return True
        except OSError:
            return False

    def total_bytes(self) -> int:
        return sum((i or {}).get("size", 0)
                   for i in (self.entry_info(k) for k in self.keys()) if i)

    def evict_to(self, max_bytes: int) -> list[str]:
        """Delete oldest-mtime entries until the store fits ``max_bytes``;
        returns the evicted keys."""
        infos = [i for i in (self.entry_info(k) for k in self.keys()) if i]
        infos.sort(key=lambda i: i["mtime"])
        total = sum(i["size"] for i in infos)
        evicted: list[str] = []
        for info in infos:
            if total <= max_bytes:
                break
            if self.delete(info["key"]):
                total -= info["size"]
                evicted.append(info["key"])
                self._event("evict", info["key"], bytes=info["size"])
        return evicted

    def events(self) -> list[dict]:
        """Parsed event log, oldest first — the rotated generation (if
        any) followed by the current file; malformed lines skipped."""
        path = os.path.join(self.root, _EVENTS)
        out: list[dict] = []
        for p in (path + ".1", path):
            try:
                with open(p) as f:
                    for line in f:
                        line = line.strip()
                        if not line:
                            continue
                        try:
                            out.append(json.loads(line))
                        except json.JSONDecodeError:
                            continue
            except OSError:
                continue
        return out
