"""Stock batched perception step: wire/bag payloads -> decode -> model
forward under ONE jit, with donated batch buffers.

The paper's "User Logic" for playback simulation is a perception model
consuming decoded sensor records.  Before this module the platform ran
that as two worlds glued by Python: the Pallas decode produced features,
control returned to the host, and any model forward was a separate
dispatch with fresh buffers.  :class:`PerceptionStep` fuses the whole
consumer into one compiled program:

    payload (R, Nb) u8 --sensor_decode[_metrics]--> features (R, Nb) f32
        --reshape--> embeds (R, Nb/d_model, d_model)
        --model forward (transformer.py / ssm.py archs)--> logits
        --last position, first ``out_features`` lanes--> (R, out_features)

``jax.jit(..., donate_argnums=...)`` donates the batch buffers (payload /
scale / zero_point / lengths [/ ts_low]), so the steady-state replay loop
re-uses the previous batch's device allocations instead of growing the
arena each step — together with the zero-copy ``frame_to_batch`` feed
(:func:`repro.net.wire.frame_to_batch`) the path from a received DATA
frame to model logits performs no per-message work at all.

Scenario integration: ``user_logic="perception://<model>"`` resolves (via
``resolve_logic_ref``) to a cached :class:`PerceptionStep` and runs it as
a first-class *batched* logic — no custom callables.  ``<model>`` is any
registered arch name (``qwen3-4b``, ``falcon-mamba-7b``, ...), reduced to
its tiny same-structure config so CPU suites stay cheap; params are
deterministic in ``seed``, so two steps built from the same ref are
bit-identical — golden verdicts are stable across runs and processes.

Thread backends only: the step owns jitted state, and process-backend
workers fork from a jax-loaded driver (initialising jax there can
deadlock) — ``ScenarioSuite`` rejects the combination up front.
"""

from __future__ import annotations

import warnings
from typing import Optional, Sequence

import numpy as np

from repro.core.bag import Message
from repro.kernels.compat import resolve_interpret
from repro.obs import trace as otrace

#: default topic perception outputs publish on
OUT_TOPIC = "/perception"


def _ts_low(timestamps: np.ndarray) -> np.ndarray:
    return (np.asarray(timestamps).astype(np.uint64)
            & np.uint64(0xFFFFFFFF)).astype(np.uint32)


class PerceptionStep:
    """Jitted decode→forward consumer with a donated steady-state loop.

    ``model`` — registered arch name; the tiny same-structure config is
    used (attention archs exercise ``models/transformer.py``, SSM archs
    ``models/ssm.py`` through the same forward).  ``metrics=True`` swaps
    the decode for the fused ``sensor_decode_metrics`` sweep, so the step
    also returns per-record input digests (the aggregation checksums) for
    free.  ``interpret`` resolves once at construction via
    :func:`repro.kernels.compat.resolve_interpret` — env
    ``REPRO_PALLAS_INTERPRET``, else compiled on TPU.  ``donate=False``
    opts out of buffer donation (keeps inputs readable after the call —
    for tests and debugging).

    Callable as the batched user-logic contract
    (``list[Message] -> [(topic, ts, bytes)]``); :meth:`run_batch` is the
    zero-copy face (columnar batch dict in, columnar batch dict out).
    """

    def __init__(self, model: str = "qwen3-4b", seed: int = 0,
                 out_topic: str = OUT_TOPIC, out_features: int = 16,
                 metrics: bool = False, donate: bool = True,
                 interpret: Optional[bool] = None):
        import jax
        from repro.configs.tiny import tiny_config
        from repro.models import get_model

        cfg = tiny_config(model)
        if out_features < 1 or out_features > cfg.vocab_size:
            raise ValueError(f"out_features must be in [1, {cfg.vocab_size}]")
        self.model = model
        self.seed = seed
        self.out_topic = out_topic
        self.out_features = out_features
        self.metrics = metrics
        self.donate = donate
        self.interpret = resolve_interpret(interpret)
        self.cfg = cfg
        api = get_model(cfg)
        self.params = api.init_params(jax.random.PRNGKey(seed))
        self._step = self._build(api.forward)

    def _build(self, forward):
        import jax
        import jax.numpy as jnp
        from repro.kernels.sensor_decode import (sensor_decode,
                                                sensor_decode_metrics)
        d_model = self.cfg.d_model
        out_k = self.out_features
        interpret = self.interpret

        def head(params, feats):
            R, Nb = feats.shape
            S = Nb // d_model
            if S == 0:
                raise ValueError(
                    f"payload rows of {Nb} bytes are narrower than "
                    f"d_model={d_model}; pad records to at least one token")
            embeds = feats[:, :S * d_model].reshape(R, S, d_model)
            logits = forward(params, {"embeds": embeds})
            return logits[:, -1, :out_k].astype(jnp.float32)

        if self.metrics:
            def step(params, payload, scale, zero_point, lengths, ts_low):
                out = sensor_decode_metrics(payload, scale, zero_point,
                                            lengths, ts_low,
                                            interpret=interpret)
                return head(params, out["features"]), out["record_digests"]
            donate = (1, 2, 3, 4, 5)
        else:
            def step(params, payload, scale, zero_point, lengths):
                feats = sensor_decode(payload, scale, zero_point, lengths,
                                      interpret=interpret)
                return head(params, feats), None
            donate = (1, 2, 3, 4)
        # params (arg 0) are NOT donated — they persist across steps; the
        # batch buffers are consumed exactly once, which is what makes
        # them donatable
        return jax.jit(step, donate_argnums=donate if self.donate else ())

    # -- array faces --------------------------------------------------------

    def step_arrays(self, batch: dict):
        """Run the fused step over one columnar batch.

        Returns ``(logits, record_digests)``: (R, out_features) f32 device
        array, plus (R,) uint32 input digests when ``metrics=True`` (else
        ``None``).  The batch buffers are copied to fresh device arrays
        and those — not the caller's numpy memory — are donated, so a
        zero-copy frame view stays valid after the call.
        """
        import jax.numpy as jnp
        tr = otrace.TRACER
        slot = (tr.begin("perception.step", "logic",
                         attrs={"rows": len(batch["lengths"])})
                if tr is not None else None)
        args = [jnp.array(batch["payload"]), jnp.array(batch["scale"]),
                jnp.array(batch["zero_point"]),
                jnp.array(np.asarray(batch["lengths"], dtype=np.int32))]
        if self.metrics:
            args.append(jnp.array(_ts_low(batch["timestamps"])))
        with warnings.catch_warnings():
            # the logits output is smaller than the donated payload buffer,
            # so backends that only alias shape-matched pairs report the
            # donation as "not usable" — the early-free half of donation
            # still applies, and the warning would fire once per trace
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            out = self._step(self.params, *args)
        if slot is not None:
            otrace.Tracer.end(slot)
        return out

    def run_batch(self, batch: dict) -> dict:
        """Zero-copy face: columnar batch in, columnar output batch out.

        The output dict has the same shape contract the input had —
        ``payload`` is the (R, 4*out_features) uint8 view of the f32
        logits rows, ``timestamps`` pass through, and the routing columns
        name ``out_topic`` — so it feeds :func:`batch_to_frame` for
        republish, or :func:`accumulate_topic_state_arrays` for metrics,
        without ever materialising ``Message`` objects.
        """
        logits, digests = self.step_arrays(batch)
        out = np.asarray(logits)
        payload = np.ascontiguousarray(out).view(np.uint8).reshape(
            out.shape[0], out.shape[1] * 4)
        result = {
            "payload": payload,
            "lengths": np.full(out.shape[0], payload.shape[1],
                               dtype=np.int32),
            "timestamps": np.asarray(batch["timestamps"], dtype=np.int64),
            "scale": np.full(out.shape[0], 1.0 / 255.0, dtype=np.float32),
            "zero_point": np.zeros(out.shape[0], dtype=np.float32),
            "topics": (self.out_topic,),
            "topic_idx": np.zeros(out.shape[0], dtype=np.uint32),
        }
        if digests is not None:
            result["input_record_digests"] = np.asarray(digests)
        return result

    # -- batched user-logic contract -----------------------------------------

    def __call__(self, msgs: Sequence[Message]):
        from repro.data.pipeline import assemble_message_batch
        batch = assemble_message_batch(msgs)
        logits, _ = self.step_arrays(batch)
        out = np.asarray(logits)
        return [(self.out_topic, m.timestamp, out[i].tobytes())
                for i, m in enumerate(msgs)]


_STEPS: dict[str, PerceptionStep] = {}

SCHEME = "perception://"


def get_step(ref: str) -> PerceptionStep:
    """Resolve (and cache per process) the step a ``perception://<model>``
    logic ref names.  The cache keeps the jit trace warm across the
    partitions/scenarios of a suite — every partition of every scenario
    naming the same model shares one compiled program and one param set."""
    model = ref[len(SCHEME):] if ref.startswith(SCHEME) else ref
    step = _STEPS.get(model)
    if step is None:
        step = _STEPS[model] = PerceptionStep(model=model)
    return step


__all__ = ["OUT_TOPIC", "PerceptionStep", "SCHEME", "get_step"]
