"""Same-host zero-copy data plane (ARCHITECTURE.md §11).

Two layers share this package:

* :mod:`repro.shm.segments` — the ref-counted shared-memory segment
  pool behind ``ProcessBackend`` arg/result spill (blobs above
  ``spill_bytes`` ride ``/dev/shm`` segments instead of temp files,
  with a temp-file fallback when shm is unavailable).
* :mod:`repro.shm.ring` — the SPSC frame ring the net layer switches
  DATA traffic onto after a successful same-host HELLO negotiation.

Only the segment API is re-exported here: ``repro.core`` imports this
package, and the ring pulls in the wire codec, so it is imported
lazily by ``repro.net.transport`` instead.
"""

from repro.shm.segments import (  # noqa: F401
    SHM_PREFIX_BASE,
    MappedSegment,
    SegmentError,
    SegmentHandle,
    SegmentPool,
    attach_segment,
    leaked_segments,
    map_segment,
    new_prefix,
    read_segment,
    shm_available,
    sweep_segments,
    unlink_segment,
    write_segment,
)

__all__ = [
    "SHM_PREFIX_BASE", "MappedSegment", "SegmentError", "SegmentHandle",
    "SegmentPool", "attach_segment", "leaked_segments", "map_segment",
    "new_prefix", "read_segment", "shm_available", "sweep_segments",
    "unlink_segment", "write_segment",
]
