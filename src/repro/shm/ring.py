"""Single-producer / single-consumer shared-memory frame ring.

The same-host fast path of the net layer: once a ``LaneTransport`` /
``RemoteBus`` pair has proven (boot-id + probe segment, see
``net/transport.py``) that both ends share one shm namespace, every
sender->receiver frame rides this ring instead of the loopback TCP
socket — same frame grammar as :class:`repro.net.wire.FrameSocket`
(``[u32 body_len][u8 type][body][u32 crc]``, CRC trailer over
type + body), so corruption detection, DRAIN barriers and CLOSE
semantics carry over unchanged, minus the syscall + kernel copy per
frame.

Layout (one shm segment)::

    [8s magic "RPRORING"][u32 version][u32 generation][u64 capacity]
    [u64 head][u64 tail][u32 closed][pad -> 64]
    [data region: ``capacity`` bytes]

``head``/``tail`` are *monotonic* byte counters (never wrapped), each
written by exactly one side: the writer owns ``head`` and the
``closed`` flag, the reader owns ``tail``.  8-byte-aligned
``struct.pack_into`` stores on a shared mmap are single stores under
CPython's GIL, which is all the atomicity an SPSC ring needs on one
host.

**Frames never wrap.**  A frame that would cross the wrap boundary is
preceded by a skip: the writer stamps a ``0xFFFFFFFF`` marker (an
impossible ``body_len`` — it exceeds ``MAX_FRAME_BYTES``) at the write
offset and advances to offset 0; when fewer than 4 contiguous bytes
remain, both sides skip them implicitly.  Non-wrapping frames are what
make the zero-copy read possible: ``recv_frame`` returns the body as a
:class:`memoryview` *into the ring* — ``frame_to_batch`` /
``decode_data`` consume it without a copy — valid until the next
``recv_frame`` call, which releases it and only then advances ``tail``
(the writer cannot overwrite a frame the reader still holds).

To guarantee progress, one frame may use at most half the data region
(``max_frame``); the transport layer bounds its flush batches to fit.

The chaos ``wire_corrupt`` seam is honored exactly like the TCP path:
``bitflip`` damages one bit past the length prefix (framing survives,
the CRC trailer catches it at the reader), ``truncate`` publishes a
frame prefix and closes the ring (the reader dies mid-frame with a
:class:`~repro.net.wire.WireError`, never hangs).
"""

from __future__ import annotations

import struct
import time
from multiprocessing import shared_memory
from typing import Callable, Optional, Tuple

from repro import chaos
from repro.net.wire import (CTX_FLAG, CTX_PREFIX, MAX_FRAME_BYTES, WireError,
                            frame_crc)
from repro.obs import trace as otrace
from repro.shm.segments import _shm_unlink, _untrack, new_prefix

__all__ = ["ShmRing", "RING_BYTES", "boot_id"]

_MAGIC = b"RPRORING"
_VERSION = 1
_STATIC = struct.Struct("<8sIIQ")       # magic, version, generation, capacity
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")
_FRAME_HDR = struct.Struct("<IB")       # body_len, ftype — the wire grammar
_HEAD_OFF = 24
_TAIL_OFF = 32
_CLOSED_OFF = 40
DATA_OFF = 64
_SKIP = 0xFFFFFFFF                      # impossible body_len: wrap marker

#: default data-region size; creation failure (tiny /dev/shm) simply
#: declines the shm fast path and the stream stays on TCP
RING_BYTES = 32 << 20

_SPIN = 200                             # cooperative yields before sleeping
_IDLE_SLEEP = 0.0002
_EOF_CHECK_PERIOD = 0.005


def boot_id() -> str:
    """Kernel boot id: equal on both ends only if they share a host
    (first gate of the same-host negotiation)."""
    try:
        with open("/proc/sys/kernel/random/boot_id") as fh:
            return fh.read().strip()
    except OSError:
        return ""


class ShmRing:
    """One direction of a negotiated same-host stream.

    Exactly one process calls ``send_frame`` and one calls
    ``recv_frame``; the creator (the receiving ``RemoteBus`` handler)
    owns the segment and unlinks it.
    """

    def __init__(self, seg: shared_memory.SharedMemory, capacity: int,
                 owner: bool, chaos_key: str = ""):
        self._seg = seg
        self._buf = seg.buf                     # skip the property per access
        self.capacity = capacity
        self.owner = owner
        self.chaos_key = chaos_key
        self.max_frame = capacity // 2 - 16
        self._head = self._load(_HEAD_OFF)      # writer-local cache
        self._tail = self._load(_TAIL_OFF)      # reader-local cache
        self._pending = 0                       # bytes held by the last view
        self._pending_view: Optional[memoryview] = None
        self._local_closed = False
        self.frames_sent = 0
        self.bytes_sent = 0
        self.frames_received = 0
        self.bytes_received = 0
        #: trace context stripped from the last annotated frame received
        self.last_trace_ctx: Optional[int] = None

    # -- construction -------------------------------------------------------

    @classmethod
    def create(cls, prefix: Optional[str] = None,
               capacity: int = RING_BYTES, generation: int = 0,
               chaos_key: str = "") -> "ShmRing":
        name = (prefix or new_prefix("r")) + "ring"
        seg = shared_memory.SharedMemory(name=name, create=True,
                                         size=DATA_OFF + capacity)
        _untrack(seg)
        _STATIC.pack_into(seg.buf, 0, _MAGIC, _VERSION,
                          generation & 0xFFFFFFFF, capacity)
        _U64.pack_into(seg.buf, _HEAD_OFF, 0)
        _U64.pack_into(seg.buf, _TAIL_OFF, 0)
        _U32.pack_into(seg.buf, _CLOSED_OFF, 0)
        return cls(seg, capacity, owner=True, chaos_key=chaos_key)

    @classmethod
    def attach(cls, name: str, chaos_key: str = "") -> "ShmRing":
        seg = shared_memory.SharedMemory(name=name)
        _untrack(seg)
        magic, version, _gen, capacity = _STATIC.unpack_from(seg.buf, 0)
        if magic != _MAGIC or version != _VERSION:
            seg.close()
            raise WireError(f"shm segment {name!r} is not a v{_VERSION} "
                            f"ring (magic={magic!r})")
        return cls(seg, capacity, owner=False, chaos_key=chaos_key)

    @property
    def name(self) -> str:
        return self._seg.name

    # -- shared-counter access ----------------------------------------------

    def _load(self, off: int) -> int:
        return _U64.unpack_from(self._seg.buf, off)[0]

    def _publish_head(self) -> None:
        _U64.pack_into(self._seg.buf, _HEAD_OFF, self._head)

    def _publish_tail(self) -> None:
        _U64.pack_into(self._seg.buf, _TAIL_OFF, self._tail)

    def _closed(self) -> bool:
        return _U32.unpack_from(self._seg.buf, _CLOSED_OFF)[0] != 0

    # -- writer side --------------------------------------------------------

    def send_frame(self, ftype: int, body=b"",
                   timeout: Optional[float] = 30.0,
                   trace_ctx: Optional[int] = None) -> None:
        """Publish one frame; blocks while the ring is full.  Raises
        ``OSError`` if the ring is closed or the reader stops draining
        (the transport's reconnect path treats it like a dead socket).

        Unlike the socket path there is no joined frame allocation:
        header, body and CRC trailer are placed straight into the ring
        region (the chaos seam still materialises full frame bytes — it
        has to damage them).  ``trace_ctx`` applies the same
        frame-header annotation as :meth:`FrameSocket.send_frame`."""
        if not isinstance(body, (bytes, bytearray, memoryview)):
            body = bytes(body)
        tr = otrace.TRACER
        if tr is not None:
            if trace_ctx is None:
                trace_ctx = tr.ctx()
            _t0 = otrace.perf_counter_ns()
        if trace_ctx is not None:
            ftype |= CTX_FLAG
            body = b"".join((CTX_PREFIX.pack(trace_ctx), bytes(body)))
        plan = chaos.active_plan()
        if plan is not None:
            fault = plan.probe("wire_corrupt", self.chaos_key)
            if fault is not None:
                body = bytes(body)
                frame = b"".join((_FRAME_HDR.pack(len(body), ftype), body,
                                  _U32.pack(frame_crc(ftype, body))))
                self._send_tampered(frame, fault, plan, timeout)
                return
        body_len = len(body)
        need = _FRAME_HDR.size + body_len + _U32.size
        w = self._reserve(need, timeout)
        buf = self._buf
        base = DATA_OFF + w
        _FRAME_HDR.pack_into(buf, base, body_len, ftype)
        payload_off = base + _FRAME_HDR.size
        if body_len:
            buf[payload_off:payload_off + body_len] = body
        _U32.pack_into(buf, payload_off + body_len, frame_crc(ftype, body))
        self._head += need
        _U64.pack_into(buf, _HEAD_OFF, self._head)
        self.frames_sent += 1
        self.bytes_sent += need
        if tr is not None:
            tr.emit("shm.send", "shm", _t0, otrace.perf_counter_ns(),
                    attrs={"bytes": need})

    def _send_tampered(self, frame: bytes, fault, plan,
                       timeout: Optional[float]) -> None:
        """Mirror of ``FrameSocket._send_tampered`` on the ring:
        ``truncate`` publishes a prefix then closes the ring (the peer
        errors mid-frame), default ``bitflip`` flips one bit past the
        length prefix so the CRC trailer catches it."""
        rng = plan.rng("wire_corrupt", self.chaos_key)
        if getattr(fault, "mode", None) == "truncate":
            keep = rng.randrange(1, len(frame))
            try:
                self._write(frame[:keep], timeout, allow_partial=True)
            except OSError:
                pass
            self.close_write()
        else:
            dmg = bytearray(frame)
            pos = rng.randrange(_U32.size, len(dmg))
            dmg[pos] ^= 1 << rng.randrange(8)
            self._write(bytes(dmg), timeout)

    def _reserve(self, need: int, timeout: Optional[float],
                 allow_partial: bool = False) -> int:
        """Wait for ``need`` contiguous bytes (inserting a wrap skip when
        required) and return the write offset; the caller places the
        frame and publishes ``head``."""
        if need > self.max_frame and not allow_partial:
            raise WireError(
                f"frame of {need} bytes exceeds the shm ring's max_frame "
                f"({self.max_frame}); bound flush batches below it")
        buf = self._buf
        if self._local_closed or buf[_CLOSED_OFF]:
            raise OSError("shm ring is closed")
        cap = self.capacity
        w = self._head % cap
        cont = cap - w
        pad = cont if need > cont else 0
        deadline = None if timeout is None else time.monotonic() + timeout
        spins = 0
        unpack_u64 = _U64.unpack_from
        while cap - (self._head - unpack_u64(buf, _TAIL_OFF)[0]) < pad + need:
            if self._local_closed:
                raise OSError("shm ring is closed")
            if deadline is not None and time.monotonic() > deadline:
                raise OSError(
                    f"shm ring send timed out after {timeout}s: reader "
                    f"is not draining")
            spins += 1
            time.sleep(0 if spins < _SPIN else _IDLE_SLEEP)
        if pad:
            if cont >= _U32.size:
                _U32.pack_into(buf, DATA_OFF + w, _SKIP)
            # fewer than 4 contiguous bytes: both sides skip implicitly
            self._head += pad
            w = 0
        return w

    def _write(self, frame: bytes, timeout: Optional[float],
               allow_partial: bool = False) -> None:
        """Place pre-built frame bytes (the chaos tamper path)."""
        need = len(frame)
        w = self._reserve(need, timeout, allow_partial)
        buf = self._buf
        buf[DATA_OFF + w:DATA_OFF + w + need] = frame
        self._head += need
        _U64.pack_into(buf, _HEAD_OFF, self._head)

    def close_write(self) -> None:
        """Orderly writer shutdown: the reader drains what was published,
        then sees clean EOF (``(None, b'')``)."""
        self._local_closed = True
        if self._seg is None:
            return
        try:
            _U32.pack_into(self._seg.buf, _CLOSED_OFF, 1)
        except (ValueError, OSError):
            pass                        # already unmapped by the owner

    # -- reader side --------------------------------------------------------

    def recv_frame(self, eof_check: Optional[Callable[[], bool]] = None,
                   timeout: Optional[float] = None
                   ) -> Tuple[Optional[int], memoryview]:
        """Next frame as ``(ftype, body-view)``; the view aliases the
        ring and is valid until the next ``recv_frame``/``close`` call.
        Clean writer close between frames returns ``(None, b"")``; a
        writer gone mid-frame raises :class:`WireError`.  ``eof_check``
        is polled while idle so a dead TCP control channel unblocks the
        reader even if the writer never set the closed flag."""
        buf = self._buf
        view = self._pending_view
        if view is not None:            # retire the previous frame's view
            try:
                view.release()
            except BufferError:
                pass                    # caller still exports it; its bytes
            self._pending_view = None   # are stale after this point anyway
        if self._pending:
            self._tail += self._pending
            self._pending = 0
            _U64.pack_into(buf, _TAIL_OFF, self._tail)
        cap = self.capacity
        unpack_u64 = _U64.unpack_from
        unpack_u32 = _U32.unpack_from
        hdr_size = _FRAME_HDR.size
        deadline = None if timeout is None else time.monotonic() + timeout
        spins = 0
        last_eof_check = 0.0
        while True:
            (head,) = unpack_u64(buf, _HEAD_OFF)
            avail = head - self._tail
            if avail:
                r = self._tail % cap
                cont = cap - r
                if cont < 4:
                    self._tail += cont          # implicit skip
                    _U64.pack_into(buf, _TAIL_OFF, self._tail)
                    continue
                (first,) = unpack_u32(buf, DATA_OFF + r)
                if first == _SKIP:
                    if avail >= cont:           # marker published with frame
                        self._tail += cont
                        _U64.pack_into(buf, _TAIL_OFF, self._tail)
                        continue
                elif first > MAX_FRAME_BYTES:
                    raise WireError(f"shm ring advertises a {first}-byte "
                                    f"frame beyond MAX_FRAME_BYTES "
                                    f"({MAX_FRAME_BYTES})")
                elif avail >= hdr_size:
                    body_len = first
                    need = hdr_size + body_len + 4
                    if avail >= need:
                        t_parse = time.perf_counter_ns()
                        ftype = buf[DATA_OFF + r + 4]
                        start = DATA_OFF + r + hdr_size
                        body = buf[start:start + body_len]
                        (crc,) = unpack_u32(buf, start + body_len)
                        if crc != frame_crc(ftype, body):
                            body.release()
                            raise WireError(
                                f"CRC mismatch on a type-{ftype} frame of "
                                f"{body_len} bytes: corrupt on the ring")
                        self._pending = need
                        self._pending_view = body
                        self.frames_received += 1
                        self.bytes_received += need
                        if ftype & CTX_FLAG:
                            if body_len < CTX_PREFIX.size:
                                raise WireError(
                                    "annotated frame too short for a trace "
                                    "context prefix")
                            (self.last_trace_ctx,) = CTX_PREFIX.unpack_from(
                                body, 0)
                            ftype &= ~CTX_FLAG
                            body = body[CTX_PREFIX.size:]
                        else:
                            self.last_trace_ctx = None
                        tr = otrace.TRACER
                        if tr is not None:
                            tr.emit("shm.recv", "shm", t_parse,
                                    time.perf_counter_ns(),
                                    parent=self.last_trace_ctx,
                                    attrs={"bytes": need})
                        return ftype, body
            # no complete frame yet: closed flag, dead peer, then wait
            if buf[_CLOSED_OFF]:
                if self._load(_HEAD_OFF) == self._tail:
                    return None, b""
                if self._load(_HEAD_OFF) == head:
                    raise WireError("shm ring writer closed mid-frame")
                continue                        # more arrived; reparse
            now = time.monotonic()
            if (eof_check is not None
                    and now - last_eof_check >= _EOF_CHECK_PERIOD):
                last_eof_check = now
                if eof_check():
                    if self._load(_HEAD_OFF) == self._tail:
                        return None, b""
                    if self._load(_HEAD_OFF) == head:
                        raise WireError(
                            "shm ring writer died mid-frame (control "
                            "channel EOF)")
                    continue
            if deadline is not None and now > deadline:
                raise WireError(f"shm ring recv timed out after {timeout}s")
            spins += 1
            time.sleep(0 if spins < _SPIN else _IDLE_SLEEP)

    # -- lifecycle ----------------------------------------------------------

    def close(self, unlink: Optional[bool] = None) -> None:
        """Detach (and unlink when owner).  Idempotent."""
        if self._seg is None:
            return
        if self._pending_view is not None:
            try:
                self._pending_view.release()
            except BufferError:
                pass
            self._pending_view = None
        seg, self._seg = self._seg, None
        try:
            seg.close()
        except BufferError:             # a caller still exports ring memory;
            pass                        # leak the mapping, not the segment
        if unlink if unlink is not None else self.owner:
            _shm_unlink(seg.name)

    def __del__(self):  # pragma: no cover - backstop only
        try:
            self.close(unlink=False)
        except Exception:
            pass
