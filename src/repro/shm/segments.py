"""Shared-memory segment pool: the same-host zero-copy spill carrier.

``ProcessBackend`` arg/result spill historically rode temp files — two
full kernel copies (write-out, read-back) plus filesystem metadata per
blob.  This module replaces that with POSIX shared memory
(``multiprocessing.shared_memory``): a blob is one memcpy into a
``/dev/shm`` segment on the producing side and a zero-syscall view on
the consuming side.

Layout of every segment::

    [8s magic "RPROSEG\\0"][u32 version][u32 generation][u64 payload_len]
    [payload ...]

The 24-byte header makes a segment self-describing: an attacher
validates magic + generation + length before trusting a byte, so a
stale :class:`SegmentHandle` (a name reused after release by an
unrelated writer) or a half-written segment fails loudly instead of
feeding garbage downstream — the same fail-at-the-boundary contract as
the wire CRC trailer.

Ownership model:

* The **driver** owns a :class:`SegmentPool`: it creates arg-spill
  segments (``put``), adopts worker-created result segments into its
  registry, ref-counts multi-consumer handles, and unlinks at zero.
* **Workers** use the stateless helpers (:func:`write_segment` /
  :func:`read_segment`): a worker never unlinks what the driver may
  still need.
* Every name this process family creates starts with a per-pool prefix
  under :data:`SHM_PREFIX_BASE`, so crash-safe reaping is a prefix
  sweep of ``/dev/shm`` — a worker that died mid-transfer (the chaos
  ``worker_crash`` seam) cannot leak segments past
  ``SegmentPool.shutdown()``, and test sessions can assert
  :func:`leaked_segments` is empty.

Python 3.10 pitfall handled here once: ``SharedMemory`` registers every
segment — attach *and* create — with ``multiprocessing.resource_tracker``,
which both spams "leaked shared_memory" warnings at exit and may unlink
segments the driver still owns when a worker exits.  ``_untrack``
deregisters after every open; lifecycle is managed explicitly by this
module instead.
"""

from __future__ import annotations

import errno
import os
import secrets
import struct
import threading
from dataclasses import dataclass
from multiprocessing import shared_memory
from typing import Dict, List, Optional, Union

from repro.obs import metrics as obs_metrics

__all__ = [
    "SHM_PREFIX_BASE", "SegmentError", "SegmentHandle", "MappedSegment",
    "SegmentPool", "shm_available", "new_prefix", "write_segment",
    "read_segment", "map_segment", "attach_segment", "unlink_segment",
    "leaked_segments", "sweep_segments",
]

#: every segment name this codebase creates starts with this, so a
#: directory sweep can tell ours from the rest of the machine's
SHM_PREFIX_BASE = "reproshm-"

_MAGIC = b"RPROSEG\x00"
_VERSION = 1
_HEADER = struct.Struct("<8sIIQ")          # magic, version, generation, len
HEADER_BYTES = _HEADER.size

_SHM_DIR = "/dev/shm"                      # POSIX tmpfs backing (Linux)


class SegmentError(OSError):
    """A segment that is missing, stale, or fails header validation."""


@dataclass(frozen=True)
class SegmentHandle:
    """A picklable, hashable reference to one shared-memory segment.

    ``generation`` must match the segment header on attach: it stamps
    *which* write this handle refers to, so a name recycled by a later
    writer is rejected instead of silently read.
    """

    name: str
    generation: int
    size: int


def _untrack(shm: shared_memory.SharedMemory) -> None:
    """Undo resource_tracker registration (see module docstring)."""
    try:
        from multiprocessing import resource_tracker
        resource_tracker.unregister(shm._name, "shared_memory")
    except Exception:
        pass


try:
    import _posixshmem  # the module shared_memory itself uses on POSIX
except ImportError:                     # pragma: no cover - non-POSIX
    _posixshmem = None


def _shm_unlink(name: str) -> None:
    """Unlink by name without touching the resource tracker —
    ``SharedMemory.unlink()`` would unregister a name we already
    untracked, which the tracker process logs as a KeyError."""
    if _posixshmem is not None:
        try:
            _posixshmem.shm_unlink(name if name.startswith("/")
                                   else "/" + name)
        except OSError:
            pass
        return
    try:                                # pragma: no cover - non-POSIX
        seg = shared_memory.SharedMemory(name=name)
    except Exception:
        return
    seg.close()
    try:
        seg.unlink()
    except OSError:
        pass


def new_prefix(kind: str = "p") -> str:
    """A fresh per-owner segment-name prefix (pool ``p``, ring ``r``,
    probe ``q``), unique per process + random token."""
    return f"{SHM_PREFIX_BASE}{kind}{os.getpid():x}-{secrets.token_hex(4)}-"


_AVAILABLE: Optional[bool] = None
_AVAILABLE_LOCK = threading.Lock()


def shm_available() -> bool:
    """Probe (once) whether POSIX shared memory actually works here —
    some sandboxes mount no ``/dev/shm`` or forbid ``shm_open``."""
    global _AVAILABLE
    if _AVAILABLE is None:
        with _AVAILABLE_LOCK:
            if _AVAILABLE is None:
                name = new_prefix("t") + "probe"
                try:
                    seg = shared_memory.SharedMemory(
                        name=name, create=True, size=HEADER_BYTES)
                    _untrack(seg)
                    seg.close()
                    _shm_unlink(name)
                    _AVAILABLE = True
                except Exception:
                    _AVAILABLE = False
    return _AVAILABLE


def write_segment(prefix: str, data, generation: int = 0,
                  name: Optional[str] = None) -> SegmentHandle:
    """Create a segment under ``prefix`` holding ``data``; returns its
    handle.  Raises ``OSError`` when shm is unavailable or full — the
    caller falls back to the temp-file spill path."""
    data = memoryview(data)
    size = len(data)
    if name is None:
        name = f"{prefix}{secrets.token_hex(6)}"
    seg = shared_memory.SharedMemory(name=name, create=True,
                                     size=HEADER_BYTES + size)
    _untrack(seg)
    try:
        _HEADER.pack_into(seg.buf, 0, _MAGIC, _VERSION,
                          generation & 0xFFFFFFFF, size)
        if size:
            seg.buf[HEADER_BYTES:HEADER_BYTES + size] = data
    except BaseException:
        seg.close()
        _shm_unlink(name)
        raise
    seg.close()
    return SegmentHandle(name=name, generation=generation & 0xFFFFFFFF,
                         size=size)


def attach_segment(handle: SegmentHandle) -> shared_memory.SharedMemory:
    """Attach and validate; caller must ``close()`` (and maybe
    ``unlink()``) the returned mapping."""
    try:
        seg = shared_memory.SharedMemory(name=handle.name)
    except FileNotFoundError:
        raise SegmentError(errno.ENOENT,
                           f"shm segment {handle.name!r} is gone")
    _untrack(seg)
    try:
        magic, version, gen, size = _HEADER.unpack_from(seg.buf, 0)
        if magic != _MAGIC or version != _VERSION:
            raise SegmentError(
                errno.EINVAL, f"shm segment {handle.name!r} has a foreign "
                f"header (magic={magic!r} version={version})")
        if gen != handle.generation or size != handle.size:
            raise SegmentError(
                errno.ESTALE, f"stale shm handle for {handle.name!r}: "
                f"header gen={gen}/len={size}, handle "
                f"gen={handle.generation}/len={handle.size}")
    except SegmentError:
        seg.close()
        raise
    except Exception as exc:
        seg.close()
        raise SegmentError(errno.EINVAL,
                           f"unreadable shm header on {handle.name!r}: "
                           f"{exc!r}")
    return seg


def read_segment(handle: SegmentHandle, unlink: bool = False) -> bytes:
    """Copy a segment's payload out; with ``unlink`` the segment is
    reclaimed in the same call (single-consumer hand-off)."""
    seg = attach_segment(handle)
    try:
        return bytes(seg.buf[HEADER_BYTES:HEADER_BYTES + handle.size])
    finally:
        seg.close()
        if unlink:
            _shm_unlink(handle.name)


class MappedSegment:
    """A zero-copy window onto a segment's payload.

    ``view`` is a memoryview straight into the shared mapping — no bytes
    are copied out of ``/dev/shm``; :meth:`close` releases the view and
    the mapping (without unlinking).  Usable as a context manager.  The
    consumer-side half of the zero-copy story: a spilled bag image can
    be checksummed/parsed in place instead of being re-materialised.
    """

    __slots__ = ("_seg", "view")

    def __init__(self, seg: shared_memory.SharedMemory, size: int):
        self._seg = seg
        self.view = seg.buf[HEADER_BYTES:HEADER_BYTES + size]

    def close(self) -> None:
        if self._seg is None:
            return
        try:
            self.view.release()
        except BufferError:
            pass
        seg, self._seg = self._seg, None
        try:
            seg.close()
        except BufferError:     # an export escaped: leak the mapping,
            pass                # never block the caller

    def __enter__(self) -> "MappedSegment":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):          # backstop; explicit close is the contract
        self.close()


def map_segment(handle: SegmentHandle) -> MappedSegment:
    """Attach a segment for zero-copy payload access; the caller closes
    the returned :class:`MappedSegment` when done with the view."""
    return MappedSegment(attach_segment(handle), handle.size)


def unlink_segment(ref: Union[str, SegmentHandle]) -> None:
    """Best-effort unlink by handle or raw name (idempotent)."""
    _shm_unlink(ref.name if isinstance(ref, SegmentHandle) else ref)


def leaked_segments(prefix: str = SHM_PREFIX_BASE) -> List[str]:
    """Names still present under ``/dev/shm`` with our prefix — the
    leak-check assertion hook tests run after every suite/session."""
    try:
        names = os.listdir(_SHM_DIR)
    except OSError:
        return []
    return sorted(n for n in names if n.startswith(prefix))


def sweep_segments(prefix: str) -> int:
    """Unlink every segment under ``prefix``; returns how many were
    reaped.  The crash-safety backstop: a worker killed mid-transfer
    left its segment on disk with our prefix, nothing else."""
    if not prefix or not prefix.startswith(SHM_PREFIX_BASE):
        raise ValueError(f"refusing to sweep non-repro prefix {prefix!r}")
    reaped = 0
    for name in leaked_segments(prefix):
        unlink_segment(name)
        reaped += 1
    return reaped


#: recycling caps: a released put-segment keeps its mapping (pages
#: already faulted) on a small free-list so the next ``put`` is a pure
#: memcpy instead of a zero-page fault storm — faulting fresh tmpfs
#: pages costs more than the copy itself for multi-MB blobs
_RECYCLE_MAX_SEGS = 4
_RECYCLE_MAX_BYTES = 64 << 20
#: reuse a parked segment only when the payload fits without hoarding:
#: capacity must be <= max(this multiple of the payload, 1 MiB)
_RECYCLE_SLACK = 4
_RECYCLE_MIN_CAP = 1 << 20


class SegmentPool:
    """Driver-owned registry of live segments with ref-counts.

    ``put`` creates (refs default 1), ``adopt`` registers a
    worker-created segment under driver ownership, ``release``
    decrements and unlinks at zero, ``read`` copies a payload out
    (optionally releasing in the same call).  ``shutdown`` unlinks
    everything still registered *and* prefix-sweeps ``/dev/shm`` for
    orphans from crashed workers; it is idempotent.

    Segments created by ``put`` are **recycled**: the pool keeps their
    mappings open, and ``release`` at refcount zero parks the segment on
    a bounded free-list instead of unlinking, so a subsequent ``put``
    of a similar-sized blob reuses the already-faulted pages (memcpy
    speed, no page faults).  Every reuse stamps a fresh generation into
    the header, so a stale handle attaching a recycled name fails with
    ``ESTALE`` instead of reading the new occupant.  The generation is
    written *before* the payload: an attacher racing the overwrite
    either sees the new generation (rejected) or attached before the
    bump — a window that only exists after the driver dropped the last
    ref, i.e. after the scheduler stopped caring about that consumer's
    result.  Adopted (worker-created) segments are never recycled; the
    driver holds no mapping for them.
    """

    def __init__(self, prefix: Optional[str] = None):
        self.prefix = prefix or new_prefix("p")
        self._lock = threading.Lock()
        self._refs: Dict[SegmentHandle, int] = {}
        #: open mappings for put-created segments, keyed by name
        self._open: Dict[str, shared_memory.SharedMemory] = {}
        self._free: List[shared_memory.SharedMemory] = []
        self._free_bytes = 0
        self._gen = 0
        self._closed = False
        # counters live in the repro.obs.metrics registry; the old
        # attribute names remain as read-only property shims
        self._metrics = obs_metrics.scope("shm_pool")
        self._m_puts = self._metrics.counter("puts")
        self._m_bytes_in = self._metrics.counter("bytes_in")
        self._m_recycled = self._metrics.counter("recycled")

    @property
    def puts(self) -> int:
        return self._m_puts.value

    @property
    def bytes_in(self) -> int:
        return self._m_bytes_in.value

    @property
    def recycled(self) -> int:
        return self._m_recycled.value

    def _pop_free(self, size: int) -> Optional[shared_memory.SharedMemory]:
        """Smallest parked segment that fits ``size`` without hoarding
        (caller holds the lock)."""
        limit = max(size * _RECYCLE_SLACK, _RECYCLE_MIN_CAP)
        best = None
        for i, seg in enumerate(self._free):
            cap = seg.size - HEADER_BYTES
            if size <= cap <= limit and (
                    best is None
                    or cap < self._free[best].size - HEADER_BYTES):
                best = i
        if best is None:
            return None
        seg = self._free.pop(best)
        self._free_bytes -= seg.size
        self._m_recycled.inc()
        return seg

    def put(self, data, refs: int = 1) -> SegmentHandle:
        data = memoryview(data)
        size = len(data)
        with self._lock:
            if self._closed:
                raise SegmentError(errno.ESHUTDOWN, "segment pool is closed")
            self._gen += 1
            gen = self._gen & 0xFFFFFFFF
            seg = self._pop_free(size)
        if seg is None:
            seg = shared_memory.SharedMemory(
                name=f"{self.prefix}{secrets.token_hex(6)}",
                create=True, size=HEADER_BYTES + size)
            _untrack(seg)
        try:
            # generation lands before the payload (see class docstring)
            _HEADER.pack_into(seg.buf, 0, _MAGIC, _VERSION, gen, size)
            if size:
                seg.buf[HEADER_BYTES:HEADER_BYTES + size] = data
        except BaseException:
            seg.close()
            _shm_unlink(seg.name)
            raise
        handle = SegmentHandle(name=seg.name, generation=gen, size=size)
        with self._lock:
            if self._closed:            # racing a shutdown: don't leak
                closing = True
            else:
                closing = False
                self._refs[handle] = max(1, refs)
                self._open[handle.name] = seg
                self._m_puts.inc()
                self._m_bytes_in.inc(size)
        if closing:
            seg.close()
            unlink_segment(handle)
            raise SegmentError(errno.ESHUTDOWN, "segment pool is closed")
        return handle

    def adopt(self, handle: SegmentHandle, refs: int = 1) -> SegmentHandle:
        with self._lock:
            if self._closed:
                unlink_segment(handle)
                raise SegmentError(errno.ESHUTDOWN, "segment pool is closed")
            self._refs[handle] = self._refs.get(handle, 0) + refs
        return handle

    def read(self, handle: SegmentHandle, release: bool = False) -> bytes:
        data = read_segment(handle)
        if release:
            self.release(handle)
        return data

    def release(self, handle: SegmentHandle) -> None:
        """Tolerant like ``reclaim_spill``: releasing an unknown or
        already-released handle is a no-op, not an error — and never
        unlinks a name that was recycled and is live under a newer
        generation."""
        seg = None
        with self._lock:
            n = self._refs.get(handle)
            if n is not None and n > 1:
                self._refs[handle] = n - 1
                return
            known = handle in self._refs
            self._refs.pop(handle, None)
            if not known:
                # a stale/double release must not touch the name if the
                # pool still tracks it (recycled under a new generation)
                if (handle.name in self._open
                        or any(s.name == handle.name for s in self._free)):
                    return
            else:
                seg = self._open.pop(handle.name, None)
                if (seg is not None and not self._closed
                        and len(self._free) < _RECYCLE_MAX_SEGS
                        and self._free_bytes + seg.size
                        <= _RECYCLE_MAX_BYTES):
                    self._free.append(seg)
                    self._free_bytes += seg.size
                    return
        if seg is not None:
            seg.close()
        unlink_segment(handle)

    def live(self) -> List[SegmentHandle]:
        with self._lock:
            return list(self._refs)

    def parked(self) -> List[str]:
        """Names held on the recycling free-list: pool-owned capacity
        awaiting reuse, not leaks (``shutdown`` reaps them)."""
        with self._lock:
            return [seg.name for seg in self._free]

    def shutdown(self) -> int:
        with self._lock:
            self._closed = True
            handles = list(self._refs)
            self._refs.clear()
            mappings = list(self._open.values()) + self._free
            self._open.clear()
            self._free = []
            self._free_bytes = 0
        for seg in mappings:
            try:
                seg.close()
            except BufferError:         # pragma: no cover - escaped view
                pass
            _shm_unlink(seg.name)
        for h in handles:
            unlink_segment(h)
        return len(handles) + sweep_segments(self.prefix)
