"""Batched serving driver: prefill + decode with a request queue
(continuous-batching-lite: fixed decode batch, slots refilled between
decode bursts).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --tiny \
        --requests 32 --batch 8 --prompt-len 16 --gen 24
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    generated: list = field(default_factory=list)
    done: bool = False


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args()

    from repro.configs import tiny_config
    from repro.models import get_config, get_model

    cfg = tiny_config(args.arch) if args.tiny else get_config(args.arch)
    cfg = cfg.replace(remat="none")
    if cfg.is_encoder_decoder or cfg.frontend == "vision":
        raise SystemExit("serve driver targets text-token archs; "
                         "see examples/distributed_playback.py for the "
                         "multimodal playback path")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    s_max = args.prompt_len + args.gen

    prefill = jax.jit(lambda p, b: model.prefill(p, b, s_max))
    decode = jax.jit(model.decode_step)

    rng = np.random.RandomState(0)
    pending = [Request(i, rng.randint(0, cfg.vocab_size,
                                      size=(args.prompt_len,)))
               for i in range(args.requests)]
    finished: list[Request] = []
    t0 = time.time()
    tokens_out = 0

    while pending:
        batch_reqs = pending[:args.batch]
        pending = pending[args.batch:]
        # pad the batch to full width with repeats (masked out at collect)
        rows = [r.prompt for r in batch_reqs]
        while len(rows) < args.batch:
            rows.append(rows[-1])
        prompts = jnp.asarray(np.stack(rows), jnp.int32)
        state = prefill(params, {"tokens": prompts})
        tok = state.last_logits[:, -1:, :cfg.vocab_size].argmax(-1)
        tok = tok.astype(jnp.int32)
        for step in range(args.gen):
            for i, r in enumerate(batch_reqs):
                r.generated.append(int(tok[i, 0]))
            state = decode(params, state, tok)
            tok = state.last_logits[:, -1:, :cfg.vocab_size].argmax(-1)
            tok = tok.astype(jnp.int32)
            tokens_out += len(batch_reqs)
        finished.extend(batch_reqs)

    dt = time.time() - t0
    print(f"served {len(finished)} requests, {tokens_out} tokens "
          f"in {dt:.2f}s ({tokens_out/dt:,.0f} tok/s)")
    r = finished[0]
    print(f"request 0: prompt {r.prompt[:8].tolist()}... -> "
          f"generated {r.generated[:12]}...")


if __name__ == "__main__":
    main()
