"""Input ShapeDtypeStruct stand-ins for every (architecture x shape) cell —
weak-type-correct, shardable, no device allocation.

Shape cells (LM-family, seq_len x global_batch):
    train_4k     4,096 x 256   -> train_step
    prefill_32k  32,768 x 32   -> serve_prefill
    decode_32k   32,768 x 128  -> serve_decode (1 new token, cache=seq_len)
    long_500k    524,288 x 1   -> serve_decode; ONLY sub-quadratic archs

[vlm]/[audio] cells feed precomputed patch/frame embeddings (frontend STUB).
For the enc-dec arch, seq_len is split S/2 encoder frames + S/2 decoder
positions so total positions per cell match the LM cells (DESIGN.md §4).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import get_config, get_model
from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    cell = SHAPES[shape]
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 500k dense-KV decode is "
                       "quadratic-history; skipped per assignment "
                       "(DESIGN.md §4)")
    return True, ""


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _emb(cfg, *shape):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(cfg.dtype))


def train_batch_struct(cfg: ModelConfig, B: int, S: int) -> dict:
    if cfg.is_encoder_decoder:
        h = S // 2
        return {"frames": _emb(cfg, B, h, cfg.d_model),
                "tokens": _i32(B, h), "labels": _i32(B, h)}
    if cfg.frontend == "vision":
        return {"embeds": _emb(cfg, B, S, cfg.d_model),
                "positions": _i32(B, S, 3), "labels": _i32(B, S)}
    return {"tokens": _i32(B, S), "labels": _i32(B, S)}


def prefill_batch_struct(cfg: ModelConfig, B: int, S: int) -> dict:
    if cfg.is_encoder_decoder:
        return {"frames": _emb(cfg, B, S // 2, cfg.d_model)}
    if cfg.frontend == "vision":
        return {"embeds": _emb(cfg, B, S, cfg.d_model),
                "positions": _i32(B, S, 3)}
    return {"tokens": _i32(B, S)}


def decode_state_struct(cfg: ModelConfig, B: int, S: int):
    model = get_model(cfg)
    if cfg.is_encoder_decoder:
        return jax.eval_shape(
            functools.partial(model.init_decode_state,
                              B, S // 2, S // 2, index=S // 2 - 1))
    return jax.eval_shape(
        functools.partial(model.init_decode_state, B, S, index=S - 1))


def input_specs(arch: str, shape: str) -> dict:
    """Returns {"kind", "args": tuple-of-structs (excluding params/opt)}."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{arch} x {shape} skipped: {why}")
    B, S = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        return {"kind": "train",
                "batch": train_batch_struct(cfg, B, S)}
    if cell.kind == "prefill":
        return {"kind": "prefill",
                "batch": prefill_batch_struct(cfg, B, S),
                "s_max": S}
    state = decode_state_struct(cfg, B, S)
    return {"kind": "decode", "state": state, "tokens": _i32(B, 1)}


def concrete_train_batch(cfg: ModelConfig, B: int, S: int,
                         key: jax.Array) -> dict:
    """Small concrete batches for CPU smoke runs (not the dry-run)."""
    kt, kl, ke = jax.random.split(key, 3)
    struct = train_batch_struct(cfg, B, S)
    out = {}
    for name, sd in struct.items():
        if sd.dtype == jnp.int32:
            hi = cfg.vocab_size if name in ("tokens", "labels") else S
            out[name] = jax.random.randint(kl, sd.shape, 0, hi)
        else:
            out[name] = jax.random.normal(ke, sd.shape, jnp.float32
                                          ).astype(sd.dtype)
    return out
