"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --tiny \
        --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/run1

Features exercised: bag-backed data pipeline (paper substrate), prefetch,
jitted train step with sharding (single device or host mesh), async
checkpointing with restart (``--resume``), gradient compression
(``--compress``), loss logging.  ``--tiny`` shrinks the arch to its smoke
config so the driver runs on CPU; on a real TPU slice drop the flag.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--data-bag", default="")
    ap.add_argument("--num-seqs", type=int, default=2048)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override tiny d_model (e.g. ~100M model: 512)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.checkpoint import CheckpointManager
    from repro.configs import tiny_config
    from repro.data import (BagTokenDataset, PrefetchIterator,
                            synthetic_corpus_bag)
    from repro.distributed import training as T
    from repro.distributed.compression import CompressionConfig
    from repro.models import get_config, get_model
    from repro.optim import AdamWConfig, linear_warmup_cosine

    cfg = tiny_config(args.arch) if args.tiny else get_config(args.arch)
    if args.d_model:
        cfg = cfg.replace(d_model=args.d_model,
                          head_dim=args.d_model // max(cfg.num_heads, 1))
    if args.layers:
        cfg = cfg.replace(num_layers=args.layers)
    cfg = cfg.replace(remat="none" if args.tiny else cfg.remat)
    model = get_model(cfg)
    total, active = cfg.param_count()
    print(f"arch={cfg.name} params={total/1e6:.1f}M "
          f"(active {active/1e6:.1f}M) devices={jax.device_count()}")

    bag = args.data_bag
    if not bag:
        bag = os.path.join(args.ckpt_dir or "/tmp", "corpus.bag")
        if not os.path.exists(bag):
            os.makedirs(os.path.dirname(bag) or ".", exist_ok=True)
            synthetic_corpus_bag(bag, args.num_seqs, args.seq,
                                 cfg.vocab_size)
    ds = BagTokenDataset(bag, args.batch)

    opt_cfg = AdamWConfig(
        lr=linear_warmup_cosine(args.lr, 20, args.steps), clip_norm=1.0)
    comp_cfg = CompressionConfig(enabled=args.compress)

    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = T.init_opt_state(cfg, opt_cfg, params, comp_cfg)
    step0 = 0

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and mgr is not None:
        (params, opt_state), step0, extra = mgr.restore_latest(
            (params, opt_state))
        print(f"resumed from step {step0}")

    train_step = jax.jit(T.make_train_step(cfg, opt_cfg, comp_cfg),
                         donate_argnums=(0, 1))

    it = PrefetchIterator(ds.batches())
    t0 = time.time()
    losses = []
    for step in range(step0 + 1, args.steps + 1):
        batch = next(it)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps:
            dt = time.time() - t0
            tok_s = args.log_every * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {step:5d} loss {np.mean(losses[-args.log_every:]):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} tok/s {tok_s:,.0f}")
            t0 = time.time()
        if mgr is not None and step % args.ckpt_every == 0:
            mgr.save(step, (params, opt_state), extra={"loss": losses[-1]})
    if mgr is not None:
        mgr.save(args.steps, (params, opt_state), blocking=True)
        print(f"final checkpoint at step {args.steps} in {args.ckpt_dir}")
    first = np.mean(losses[:10]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-10:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
