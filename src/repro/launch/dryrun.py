import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware, and extract the roofline terms from the compiled artifact.

Per (architecture x input shape x mesh) cell this does THREE compiles:

1. the FULL model, layers scanned — proves lower+compile succeeds at 256 /
   512 devices and yields ``memory_analysis()`` (real per-chip HBM demand);
2. two CALIBRATION probes (1-layer and 2-layer, layers + inner loops
   unrolled) — XLA's ``cost_analysis()`` counts while-loop bodies ONCE
   regardless of trip count (verified empirically), so per-layer flops /
   bytes / collective-bytes are recovered from the probe difference and
   extrapolated:  total = outside + L x per_layer.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b \
        --shape train_4k --mesh single --out results/yi.json
    PYTHONPATH=src python -m repro.launch.dryrun --all --out-dir results/
(--all spawns one subprocess per cell for isolation.)
"""

import argparse
import json
import subprocess
import sys
import time


def _probe_cfg(cfg, L: int):
    # attn_chunk/ssm_block = 0: single full tile per layer — no inner scan
    # loops left to undercount, and far cheaper to compile than unrolled
    # chunk loops (flop totals are identical).
    kw = dict(num_layers=L, scan_layers=False, unroll_inner=True,
              attn_chunk=0, ssm_block=0)
    if cfg.is_encoder_decoder:
        kw["num_encoder_layers"] = L
    return cfg.replace(**kw)


def _build_lowered(cfg, mesh, shape: str, use_fsdp: bool, opt_cfg):
    """Lower the right step for this cell under the mesh context."""
    import jax

    from repro.distributed import training as T
    from repro.distributed.context import use_mesh_ctx
    from repro.launch import specs as S

    cell = S.SHAPES[shape]
    B, SL = cell.global_batch, cell.seq_len
    with mesh, use_mesh_ctx(mesh):
        if cell.kind == "train":
            batch = S.train_batch_struct(cfg, B, SL)
            step = T.jit_train_step(cfg, opt_cfg, mesh, batch, fsdp=use_fsdp)
            p_struct = T.param_struct(cfg)
            o_struct = jax.eval_shape(
                lambda p: T.init_opt_state(cfg, opt_cfg, p), p_struct)
            return step.lower(p_struct, o_struct, batch)
        if cell.kind == "prefill":
            batch = S.prefill_batch_struct(cfg, B, SL)
            state_struct = jax.eval_shape(
                lambda p, b: T.make_serve_prefill(cfg, SL)(p, b),
                T.param_struct(cfg), batch)
            fn = T.jit_serve_prefill(cfg, mesh, SL, batch, state_struct,
                                     fsdp=use_fsdp)
            return fn.lower(T.param_struct(cfg), batch)
        state = S.decode_state_struct(cfg, B, SL)
        tokens = S._i32(B, 1)
        fn = T.jit_serve_decode(cfg, mesh, state, fsdp=use_fsdp)
        return fn.lower(T.param_struct(cfg), state, tokens)


def run_cell(arch: str, shape: str, mesh_kind: str, fsdp: str = "auto",
             opt_flags: dict | None = None) -> dict:
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import SHAPES
    from repro.models import get_config
    from repro.optim import AdamWConfig

    t0 = time.time()
    cfg = get_config(arch)
    opt_flags = opt_flags or {}
    if opt_flags.get("remat"):
        cfg = cfg.replace(remat=opt_flags["remat"])
    if opt_flags.get("expert_sharding"):
        cfg = cfg.replace(expert_sharding=opt_flags["expert_sharding"])
    if opt_flags.get("attn_chunk"):
        cfg = cfg.replace(attn_chunk=int(opt_flags["attn_chunk"]))
    if opt_flags.get("ssm_block"):
        cfg = cfg.replace(ssm_block=int(opt_flags["ssm_block"]))
    if opt_flags.get("seq_residual"):
        cfg = cfg.replace(seq_sharded_residual=True)
    if opt_flags.get("seq_attn"):
        cfg = cfg.replace(seq_sharded_attention=True)
    if opt_flags.get("ssm_bf16"):
        cfg = cfg.replace(ssm_bf16=True)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    cell = SHAPES[shape]

    total_params, _ = cfg.param_count()
    if fsdp == "auto":
        if cell.kind == "train":
            use_fsdp = True
        else:   # serve: FSDP the weights only when TP alone can't fit HBM
            use_fsdp = total_params * 2 / mesh.shape["model"] > 8e9
    else:
        use_fsdp = fsdp == "on"

    opt_cfg = AdamWConfig(moment_dtype=opt_flags.get("moment_dtype",
                                                     "float32"))

    # --- 1. full compile (the dry-run deliverable) -------------------------
    lowered = _build_lowered(cfg, mesh, shape, use_fsdp, opt_cfg)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    full_compile_s = round(time.time() - t0, 1)

    # --- 2. calibration probes --------------------------------------------
    def probe(L: int):
        low = _build_lowered(_probe_cfg(cfg, L), mesh, shape, use_fsdp,
                             opt_cfg)
        comp = low.compile()
        cost = comp.cost_analysis()
        if isinstance(cost, (list, tuple)):     # older jax: list per program
            cost = cost[0] if cost else {}
        coll = rl.collective_bytes(comp.as_text())
        return (float(cost.get("flops", 0.0)),
                float(cost.get("bytes accessed", 0.0)),
                coll)

    f1, b1, c1 = probe(1)
    f2, b2, c2 = probe(2)
    L = cfg.num_layers
    flops_layer = max(f2 - f1, 0.0)
    bytes_layer = max(b2 - b1, 0.0)
    flops_total = max(f1 - flops_layer, 0.0) + L * flops_layer
    bytes_total = max(b1 - bytes_layer, 0.0) + L * bytes_layer
    coll_layer = max(c2["total_bytes"] - c1["total_bytes"], 0)
    coll_total = max(c1["total_bytes"] - coll_layer, 0) + L * coll_layer
    coll_by_op = {}
    for op in set(c1["bytes_by_op"]) | set(c2["bytes_by_op"]):
        per = max(c2["bytes_by_op"].get(op, 0) - c1["bytes_by_op"].get(op, 0),
                  0)
        out = max(c1["bytes_by_op"].get(op, 0) - per, 0)
        tot = out + L * per
        if tot:
            coll_by_op[op] = tot
    counts = {op: c1["counts"][op] + (c2["counts"][op] - c1["counts"][op])
              * (L - 1) for op in c1["counts"]
              if c1["counts"][op] or c2["counts"][op]}

    mf = rl.model_flops(cfg, cell.kind, cell.seq_len, cell.global_batch)

    r = rl.Roofline(
        arch=arch, shape=shape, mesh=mesh_kind, chips=chips,
        hlo_flops=flops_total,
        hlo_bytes=bytes_total,
        collective_bytes_per_chip=float(coll_total),
        collective_counts=counts,
        model_flops=mf,
        bytes_per_device=float(mem.argument_size_in_bytes
                               + mem.temp_size_in_bytes
                               + mem.output_size_in_bytes
                               - mem.alias_size_in_bytes),
    ).finalize()
    d = r.to_dict()
    d.update(
        kind=cell.kind, fsdp=use_fsdp,
        argument_bytes=int(mem.argument_size_in_bytes),
        temp_bytes=int(mem.temp_size_in_bytes),
        output_bytes=int(mem.output_size_in_bytes),
        alias_bytes=int(mem.alias_size_in_bytes),
        bytes_by_op=coll_by_op,
        full_compile_s=full_compile_s,
        compile_s=round(time.time() - t0, 1),
        opt_flags=opt_flags,
    )
    return d


def _summary(d: dict) -> str:
    gb = d["bytes_per_device"] / 2**30
    return (f"{d['arch']:24s} {d['shape']:12s} {d['mesh']:6s} "
            f"chips={d['chips']:4d} mem/chip={gb:7.2f}GiB "
            f"compute={d['compute_s']*1e3:9.3f}ms "
            f"memory={d['memory_s']*1e3:9.3f}ms "
            f"coll={d['collective_s']*1e3:9.3f}ms "
            f"bottleneck={d['bottleneck']:10s} "
            f"useful={d['useful_ratio']:6.2%} "
            f"compile={d['compile_s']:6.1f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--remat", default="")
    ap.add_argument("--expert-sharding", default="")
    ap.add_argument("--moment-dtype", default="")
    ap.add_argument("--attn-chunk", default="")
    ap.add_argument("--ssm-block", default="")
    ap.add_argument("--seq-residual", action="store_true")
    ap.add_argument("--seq-attn", action="store_true")
    ap.add_argument("--ssm-bf16", action="store_true")
    ap.add_argument("--out", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--out-dir", default="results")
    args = ap.parse_args()

    if args.all:
        _run_all(args)
        return

    opt_flags = {}
    if args.remat:
        opt_flags["remat"] = args.remat
    if args.expert_sharding:
        opt_flags["expert_sharding"] = args.expert_sharding
    if args.moment_dtype:
        opt_flags["moment_dtype"] = args.moment_dtype
    if args.attn_chunk:
        opt_flags["attn_chunk"] = args.attn_chunk
    if args.ssm_block:
        opt_flags["ssm_block"] = args.ssm_block
    if args.seq_residual:
        opt_flags["seq_residual"] = True
    if args.seq_attn:
        opt_flags["seq_attn"] = True
    if args.ssm_bf16:
        opt_flags["ssm_bf16"] = True
    d = run_cell(args.arch, args.shape, args.mesh, args.fsdp, opt_flags)
    print(_summary(d))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(d, f, indent=1)


def _run_all(args) -> None:
    from repro.configs import ALL_ARCHS
    from repro.launch.specs import SHAPES, cell_applicable
    from repro.models import get_config

    os.makedirs(args.out_dir, exist_ok=True)
    cells = []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = cell_applicable(cfg, shape)
            if not ok:
                print(f"SKIP {arch:24s} {shape:12s} -- {why}", flush=True)
                continue
            for mesh in args.meshes.split(","):
                cells.append((arch, shape, mesh))
    print(f"{len(cells)} cells to run", flush=True)
    failures = []
    for arch, shape, mesh in cells:
        out = os.path.join(args.out_dir,
                           f"{arch}__{shape}__{mesh}.json".replace("/", "_"))
        if os.path.exists(out):
            with open(out) as f:
                print("CACHED " + _summary(json.load(f)), flush=True)
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh,
               "--out", out]
        r = subprocess.run(cmd, capture_output=True, text=True)
        if r.returncode != 0:
            failures.append((arch, shape, mesh))
            print(f"FAIL {arch} {shape} {mesh}\n{r.stderr[-2500:]}",
                  flush=True)
        else:
            print(r.stdout.strip(), flush=True)
    print(f"done; {len(failures)} failures: {failures}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
