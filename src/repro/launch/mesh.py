"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, smoke tests stay on 1 device.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod's worth).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis is
    the cross-DCN/ICI axis (outer data-parallel by default, or the GPipe
    axis — see distributed/pipeline.py)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2, pod: int = 0) -> Mesh:
    """Small mesh over however many (forced) host devices exist — used by
    multi-device CPU tests."""
    devs = jax.devices()
    n = (pod or 1) * data * model
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices, have {len(devs)}")
    if pod:
        arr = np.array(devs[:n]).reshape(pod, data, model)
        return Mesh(arr, ("pod", "data", "model"))
    arr = np.array(devs[:n]).reshape(data, model)
    return Mesh(arr, ("data", "model"))
