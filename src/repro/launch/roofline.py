"""Roofline-term extraction from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips x 197e12 FLOP/s bf16)
    memory term     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective term = collective_bytes / (chips x 50e9 B/s ICI link)

``cost_analysis()`` supplies flops/bytes; collective bytes are parsed out
of the post-SPMD HLO text (operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute), per instructions.
Post-SPMD shapes are per-device, so the parsed sum is already per-chip wire
bytes; we also report a ring-model estimate for reference.
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field

# TPU v5e-class constants (per instructions)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

_DT_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+\d+|pred)\[([\d,]*)\]")
_OPLINE_RE = re.compile(
    r"=\s*(?P<out>\([^)]*\)|[a-z]+\d*\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>[a-z\-]+)(?:\()")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LEGACY_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DT_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))            # [num_groups, group_size]
    m = _GROUPS_LEGACY_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Sum *operand* bytes per collective opcode from post-SPMD HLO text.

    Operand shapes are not inline in optimized HLO, so we parse each
    collective's output shape and convert:  all-gather operand = out/g,
    reduce-scatter operand = out*g (g = replica group size), the rest
    operand = out.  Post-SPMD shapes are per-device."""
    out = {op: 0 for op in _COLLECTIVES}
    counts = {op: 0 for op in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _OPLINE_RE.search(stripped)
        if not m:
            continue
        op = m.group("op")
        if op.endswith("-start"):
            op = op[:-6]
        if op.endswith("-done") or op.endswith("-update"):
            continue                      # counted at -start
        if op not in _COLLECTIVES:
            continue
        total = sum(_shape_bytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(m.group("out")))
        g = _group_size(stripped)
        if op == "all-gather":
            total = total // max(g, 1)
        elif op == "reduce-scatter":
            total = total * g
        out[op] += total
        counts[op] += 1
    return {"bytes_by_op": out, "counts": counts,
            "total_bytes": sum(out.values())}


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float              # whole-program (all chips)
    hlo_bytes: float
    collective_bytes_per_chip: float
    collective_counts: dict
    model_flops: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0
    bytes_per_device: float = 0.0

    def finalize(self) -> "Roofline":
        # cost_analysis flops on the SPMD-partitioned module are per-chip
        # program flops; treat them as per-chip and normalise model flops.
        self.compute_s = self.hlo_flops / PEAK_FLOPS
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.collective_bytes_per_chip / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        per_chip_model = self.model_flops / max(self.chips, 1)
        self.useful_ratio = (per_chip_model / self.hlo_flops
                             if self.hlo_flops else 0.0)
        return self

    def to_dict(self) -> dict:
        return asdict(self)


def model_flops(cfg, kind: str, seq_len: int, global_batch: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params.

    D = total tokens processed by the step being lowered: B*S for train and
    prefill, B for a single decode step.  For the enc-dec arch the prefill
    cell runs the ENCODER only over S/2 frames, so N is scaled to the
    encoder's parameter share and D to the frame count (DESIGN.md §4)."""
    _, active = cfg.param_count()
    if kind == "train":
        tokens = global_batch * seq_len
        return 6.0 * active * tokens
    if kind == "prefill":
        tokens = global_batch * seq_len
        if cfg.is_encoder_decoder:
            # encoder ~ self-attn + mlp per enc layer; exclude embeddings
            D = cfg.d_model
            attn = 4 * D * cfg.num_heads * cfg.head_dim
            mlp = 3 * D * cfg.d_ff
            n_enc = cfg.num_encoder_layers * (attn + mlp)
            return 2.0 * n_enc * global_batch * (seq_len // 2)
        return 2.0 * active * tokens
    return 2.0 * active * global_batch     # decode: one token per sequence
