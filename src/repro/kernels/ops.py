"""Jit'd public wrappers for the Pallas kernels.

``interpret=None`` resolves through
:func:`repro.kernels.compat.resolve_interpret`: the ``REPRO_PALLAS_INTERPRET``
env var wins, otherwise compiled Mosaic on a real TPU backend and Python
interpret mode everywhere else (this container is CPU-only; the kernels are
*targeted* at TPU and validated in interpret mode).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .compat import resolve_interpret
from .flash_attention import flash_attention
from .selective_scan import selective_scan
from .sensor_decode import sensor_decode


def _interpret_default() -> bool:
    # kept for callers that need the resolved mode itself (benchmarks)
    return resolve_interpret(None)


def attention(q, k, v, *, causal=True, window=0, blk_q=128, blk_k=128,
              interpret=None):
    """Flash attention; layout (B, H, S, hd) / (B, KV, S, hd)."""
    return flash_attention(q, k, v, causal=causal, window=window,
                           blk_q=blk_q, blk_k=blk_k, interpret=interpret)


def mamba_scan(x, dt, B, C, A, *, blk_d=128, blk_s=128, interpret=None):
    """Selective scan; x/dt (b,S,di), B/C (b,S,N), A (di,N) negative."""
    return selective_scan(x, dt, B, C, A, blk_d=blk_d, blk_s=blk_s,
                          interpret=interpret)


def decode_records(payload, scale, zero_point, lengths, *, blk_r=8,
                   blk_n=512, interpret=None):
    """On-device BinPipedRDD decode stage (paper Fig 4)."""
    return sensor_decode(payload, scale, zero_point, lengths,
                         blk_r=blk_r, blk_n=blk_n, interpret=interpret)


def decode_partition(partition, feature_bytes: int, *, interpret=None):
    """Convenience: core.binpipe.BinaryPartition -> (R, feature_bytes) f32
    feature matrix on device (frame + pad/clip + dequantize)."""
    payload, offsets, lengths = partition.to_arrays(align=128)
    R = len(lengths)
    rows = np.zeros((R, feature_bytes), np.uint8)
    for i, (o, l) in enumerate(zip(offsets.tolist(), lengths.tolist())):
        n = min(l, feature_bytes)
        rows[i, :n] = payload[o:o + n]
    lengths = np.minimum(lengths, feature_bytes).astype(np.int32)
    scale = np.full((R,), 1.0 / 255.0, np.float32)
    zp = np.zeros((R,), np.float32)
    return decode_records(jnp.asarray(rows), jnp.asarray(scale),
                          jnp.asarray(zp), jnp.asarray(lengths),
                          interpret=interpret)
