"""Flash attention as a Pallas TPU kernel.

Canonical TPU tiling: grid = (batch, q_heads, num_q_blocks, num_kv_blocks)
with the kv dimension innermost and sequential; the online-softmax running
max / sum / accumulator live in VMEM scratch that persists across the kv
sweep.  Causal masking skips fully-masked kv blocks (compute saved; the
BlockSpec prefetch still streams them).  GQA is handled in the k/v
index_map: q head h reads kv head ``h // (H // KV)``.

Block shapes are MXU-aligned (multiples of 128 on the lane dim).  Validated
in interpret mode against ``ref.attention_reference`` over shape/dtype
sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams, resolve_interpret

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int,
                 blk_q: int, blk_k: int, seq_k: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * blk_q + q_offset      # absolute position of first query
    k_start = ik * blk_k

    # block-level skip: whole kv block masked => no compute (flops saved)
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + blk_q - 1
    if window:
        run &= k_start + blk_k - 1 > q_start - window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (blk_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (blk_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (blk_k, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (blk_q, blk_k)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (blk_q, blk_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (blk_q, blk_k), 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                            # (blk_q, 128)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)     # (blk_q, 1)
        m_new = jnp.maximum(m_prev, m_cur)             # lanes replicated
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, :1])                  # (blk_q, blk_k)
        l_new = l_prev * alpha + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha[:, :1] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _emit():
        l = l_scr[...][:, :1]
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: "bool | None" = None) -> jax.Array:
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd); H % KV == 0.

    Returns (B, H, Sq, hd) in q.dtype.  ``window`` > 0 adds sliding-window
    masking on top of causal.  ``interpret=None`` resolves via
    :func:`repro.kernels.compat.resolve_interpret`.
    """
    return _flash_attention(q, k, v, causal=causal, window=window,
                            blk_q=blk_q, blk_k=blk_k,
                            interpret=resolve_interpret(interpret))


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "blk_q", "blk_k",
                              "interpret"))
def _flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool, window: int, blk_q: int, blk_k: int,
                     interpret: bool) -> jax.Array:
    B, H, Sq, hd = q.shape
    _, KV, Sk, _ = k.shape
    G = H // KV
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    nq = -(-Sq // blk_q)
    nk = -(-Sk // blk_k)
    pad_q = nq * blk_q - Sq
    pad_k = nk * blk_k - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=hd ** -0.5, causal=causal,
                          window=window, blk_q=blk_q, blk_k=blk_k,
                          seq_k=Sk, q_offset=Sk - Sq),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, blk_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, blk_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * blk_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 128), jnp.float32),   # running max
            pltpu.VMEM((blk_q, 128), jnp.float32),   # running sum
            pltpu.VMEM((blk_q, hd), jnp.float32),    # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :, :Sq]
    return out
