"""Mamba-1 selective scan as a Pallas TPU kernel.

Grid = (batch, d_inner blocks, seq blocks) with the seq dimension innermost
and sequential; the SSM hidden state (blk_d, N) lives in VMEM scratch and is
carried across seq blocks — the TPU-native replacement for the CUDA
kernel's register-resident state.  Within a block the recurrence runs as a
``fori_loop`` over time steps; channels are vectorised across lanes (blk_d
is lane-aligned at 128) so each step is a (blk_d, N) VPU op, not a scalar
loop.

Computes:  h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) * B_t
           y_t = (h_t * C_t).sum(-1)
(the D skip-connection and silu(z) gating stay outside — see ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams, resolve_interpret


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, h_scr, *,
                 blk_s: int):
    isq = pl.program_id(2)

    @pl.when(isq == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a_neg = a_ref[...].astype(jnp.float32)             # (blk_d, N)

    def step(t, h):
        dt = dt_ref[0, t, :].astype(jnp.float32)       # (blk_d,)
        xt = x_ref[0, t, :].astype(jnp.float32)        # (blk_d,)
        bt = b_ref[0, t, :].astype(jnp.float32)        # (N,)
        ct = c_ref[0, t, :].astype(jnp.float32)        # (N,)
        decay = jnp.exp(dt[:, None] * a_neg)           # (blk_d, N)
        h = decay * h + (dt * xt)[:, None] * bt[None, :]
        y_ref[0, t, :] = (h * ct[None, :]).sum(-1).astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, blk_s, step, h_scr[...])


def selective_scan(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
                   A: jax.Array, *, blk_d: int = 128, blk_s: int = 128,
                   interpret: "bool | None" = None) -> jax.Array:
    """x, dt: (batch, S, d_inner); B, C: (batch, S, N); A: (d_inner, N)
    (A already negative, i.e. ``A = -exp(A_log)``).  Returns y (batch, S,
    d_inner) f32.  ``interpret=None`` resolves via
    :func:`repro.kernels.compat.resolve_interpret`."""
    return _selective_scan(x, dt, B, C, A, blk_d=blk_d, blk_s=blk_s,
                           interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("blk_d", "blk_s", "interpret"))
def _selective_scan(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
                    A: jax.Array, *, blk_d: int, blk_s: int,
                    interpret: bool) -> jax.Array:
    bsz, S, di = x.shape
    N = A.shape[1]
    blk_d = min(blk_d, di)
    blk_s = min(blk_s, S)
    nd = -(-di // blk_d)
    ns = -(-S // blk_s)
    pad_d = nd * blk_d - di
    pad_s = ns * blk_s - S
    if pad_d:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad_d)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad_d)))
        A = jnp.pad(A, ((0, pad_d), (0, 0)))
    if pad_s:
        x = jnp.pad(x, ((0, 0), (0, pad_s), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_s), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad_s), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad_s), (0, 0)))

    y = pl.pallas_call(
        functools.partial(_scan_kernel, blk_s=blk_s),
        grid=(bsz, nd, ns),
        in_specs=[
            pl.BlockSpec((1, blk_s, blk_d), lambda b, d, s: (b, s, d)),  # x
            pl.BlockSpec((1, blk_s, blk_d), lambda b, d, s: (b, s, d)),  # dt
            pl.BlockSpec((1, blk_s, N), lambda b, d, s: (b, s, 0)),      # B
            pl.BlockSpec((1, blk_s, N), lambda b, d, s: (b, s, 0)),      # C
            pl.BlockSpec((blk_d, N), lambda b, d, s: (d, 0)),            # A
        ],
        out_specs=pl.BlockSpec((1, blk_s, blk_d), lambda b, d, s: (b, s, d)),
        out_shape=jax.ShapeDtypeStruct((bsz, ns * blk_s, nd * blk_d),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((blk_d, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, B, C, A)
    return y[:, :S, :di]
