"""Pallas API compatibility shims + backend-mode resolution.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` across
jax releases; the kernels import the resolved name from here so they run on
either side of the rename.

:func:`resolve_interpret` is the single policy point for Pallas interpret
mode.  Every kernel entry point (``sensor_decode*``, ``flash_attention``,
``selective_scan`` and their :mod:`repro.kernels.ops` wrappers) defaults to
``interpret=None`` and resolves it here, so one environment variable flips
the whole platform between interpreted CPU emulation and compiled Mosaic:

    REPRO_PALLAS_INTERPRET=1   force interpret mode (debugging on TPU)
    REPRO_PALLAS_INTERPRET=0   force compiled kernels (fail loudly off-TPU)
    unset                      interpret everywhere except a real TPU

This replaces the per-call ``interpret=True`` defaults that used to be
scattered through the kernels and their core/benchmark callers — those
defaults silently ran Python emulation even on real hardware, which is why
every kernel number before this change was a CPU interpret-mode number.
"""

from __future__ import annotations

import os
from typing import Optional

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

#: environment toggle honored by every kernel entry point
INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"

_FALSY = ("0", "false", "no", "off")


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve a kernel's ``interpret`` argument to a concrete bool.

    Precedence: an explicit ``True``/``False`` wins; otherwise the
    ``REPRO_PALLAS_INTERPRET`` env var (``0/false/no/off`` -> compiled,
    anything else -> interpret); otherwise platform-aware — compiled on a
    real TPU backend, interpret mode everywhere else.  Resolution happens
    *outside* the jitted kernels (their ``interpret`` is a static
    argument), so flipping the env var mid-process takes effect on the
    next call rather than being frozen into a trace cache keyed on None.
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get(INTERPRET_ENV)
    if env is not None and env.strip():
        return env.strip().lower() not in _FALSY
    import jax
    return jax.default_backend() != "tpu"


__all__ = ["CompilerParams", "INTERPRET_ENV", "resolve_interpret"]
