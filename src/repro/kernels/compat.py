"""Pallas API compatibility shims.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` across
jax releases; the kernels import the resolved name from here so they run on
either side of the rename.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
