"""Pure-jnp oracles for every Pallas kernel (the ground truth the
shape/dtype sweeps in tests/test_kernels.py assert against)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    _, KV, Sk, _ = k.shape
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, hd).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg,
                   k.astype(jnp.float32)) * hd ** -0.5
    qpos = jnp.arange(Sq) + (Sk - Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None] <= qpos[:, None]
    if window:
        mask &= kpos[None] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", w, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, hd).astype(q.dtype)


def selective_scan_reference(x: jax.Array, dt: jax.Array, B: jax.Array,
                             C: jax.Array, A: jax.Array) -> jax.Array:
    """Sequential scan oracle.  x, dt: (b, S, di); B, C: (b, S, N);
    A: (di, N) negative.  Returns (b, S, di) f32."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # (b,di) (b,di) (b,N) (b,N)
        decay = jnp.exp(dtt[..., None] * Af)        # (b, di, N)
        h = decay * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = (h * ct[:, None, :]).sum(-1)            # (b, di)
        return h, y

    h0 = jnp.zeros((x.shape[0], x.shape[2], A.shape[1]), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)


def sensor_decode_reference(payload: jax.Array, scale: jax.Array,
                            zero_point: jax.Array,
                            lengths: jax.Array) -> jax.Array:
    """(R, Nb) uint8 -> (R, Nb) f32 dequantized, padding zeroed."""
    u = payload.astype(jnp.float32)
    val = (u - zero_point[:, None]) * scale[:, None]
    col = jnp.arange(payload.shape[1])[None, :]
    return jnp.where(col < lengths[:, None], val, 0.0)
