"""BinPipedRDD decode stage (paper §3.1, Fig 4) as a Pallas TPU kernel.

The paper pipes serialized binary sensor records from Spark into a ROS node
over a Linux pipe and decodes them on the CPU.  On TPU the decode stage runs
*on device*, next to the consumer model: framed uint8 record payloads
(produced by ``repro.core.binpipe.frame`` — 128-aligned records) are
dequantized to normalized f32 features in VMEM tiles.

    out[r, n] = (payload[r, n] - zero_point[r]) * scale[r]    (n < length[r],
                                                               else 0)

Grid = (record blocks, byte blocks); per-record scale / zero-point / length
ride along as (blk_r, 1) tiles.  This is the "User Logic" pre-stage every
playback simulation runs, fused with whatever model consumes the features.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _decode_kernel(payload_ref, scale_ref, zp_ref, len_ref, out_ref, *,
                   blk_n: int):
    j = pl.program_id(1)
    u = payload_ref[...].astype(jnp.float32)            # (blk_r, blk_n)
    scale = scale_ref[...].astype(jnp.float32)          # (blk_r, 1)
    zp = zp_ref[...].astype(jnp.float32)                # (blk_r, 1)
    ln = len_ref[...]                                   # (blk_r, 1) int32
    col = j * blk_n + jax.lax.broadcasted_iota(
        jnp.int32, u.shape, 1)                          # absolute byte index
    val = (u - zp) * scale
    out_ref[...] = jnp.where(col < ln, val, 0.0)


@functools.partial(jax.jit, static_argnames=("blk_r", "blk_n", "interpret"))
def sensor_decode(payload: jax.Array, scale: jax.Array, zero_point: jax.Array,
                  lengths: jax.Array, *, blk_r: int = 8, blk_n: int = 512,
                  interpret: bool = True) -> jax.Array:
    """payload: (R, Nb) uint8 — one framed record per row (128-aligned);
    scale, zero_point: (R,) f32; lengths: (R,) int32 valid-byte counts.
    Returns (R, Nb) f32 with padding bytes zeroed."""
    R, Nb = payload.shape
    blk_r = min(blk_r, R)
    blk_n = min(blk_n, Nb)
    nr = -(-R // blk_r)
    nn = -(-Nb // blk_n)
    pad_r = nr * blk_r - R
    pad_n = nn * blk_n - Nb
    if pad_r or pad_n:
        payload = jnp.pad(payload, ((0, pad_r), (0, pad_n)))
        scale = jnp.pad(scale, (0, pad_r))
        zero_point = jnp.pad(zero_point, (0, pad_r))
        lengths = jnp.pad(lengths, (0, pad_r))

    out = pl.pallas_call(
        functools.partial(_decode_kernel, blk_n=blk_n),
        grid=(nr, nn),
        in_specs=[
            pl.BlockSpec((blk_r, blk_n), lambda i, j: (i, j)),
            pl.BlockSpec((blk_r, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((blk_r, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((blk_r, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk_r, blk_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nr * blk_r, nn * blk_n), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(payload, scale[:, None], zero_point[:, None],
      lengths.astype(jnp.int32)[:, None])
    return out[:R, :Nb]


def decode_message_batch(batch: dict, *, interpret: bool = True) -> jax.Array:
    """Run the decode stage on one assembled replay micro-batch.

    ``batch`` is the dict produced by
    :func:`repro.data.pipeline.assemble_message_batch` — the glue that puts
    this kernel in the batched-replay hot loop (``RosPlay.run_batched`` ->
    batch user logic -> assemble -> decode on device).  Returns (R, Nb) f32
    normalized features with padding bytes zeroed.
    """
    return sensor_decode(jnp.asarray(batch["payload"]),
                         jnp.asarray(batch["scale"]),
                         jnp.asarray(batch["zero_point"]),
                         jnp.asarray(batch["lengths"]),
                         interpret=interpret)
