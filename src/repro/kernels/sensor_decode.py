"""BinPipedRDD decode stage (paper §3.1, Fig 4) as a Pallas TPU kernel.

The paper pipes serialized binary sensor records from Spark into a ROS node
over a Linux pipe and decodes them on the CPU.  On TPU the decode stage runs
*on device*, next to the consumer model: framed uint8 record payloads
(produced by ``repro.core.binpipe.frame`` — 128-aligned records) are
dequantized to normalized f32 features in VMEM tiles.

    out[r, n] = (payload[r, n] - zero_point[r]) * scale[r]    (n < length[r],
                                                               else 0)

Grid = (record blocks, byte blocks); per-record scale / zero-point / length
ride along as (blk_r, 1) tiles.  This is the "User Logic" pre-stage every
playback simulation runs, fused with whatever model consumes the features.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams, resolve_interpret


def _decode_kernel(payload_ref, scale_ref, zp_ref, len_ref, out_ref, *,
                   blk_n: int):
    j = pl.program_id(1)
    u = payload_ref[...].astype(jnp.float32)            # (blk_r, blk_n)
    scale = scale_ref[...].astype(jnp.float32)          # (blk_r, 1)
    zp = zp_ref[...].astype(jnp.float32)                # (blk_r, 1)
    ln = len_ref[...]                                   # (blk_r, 1) int32
    col = j * blk_n + jax.lax.broadcasted_iota(
        jnp.int32, u.shape, 1)                          # absolute byte index
    val = (u - zp) * scale
    out_ref[...] = jnp.where(col < ln, val, 0.0)


def sensor_decode(payload: jax.Array, scale: jax.Array, zero_point: jax.Array,
                  lengths: jax.Array, *, blk_r: int = 8, blk_n: int = 512,
                  interpret: "bool | None" = None) -> jax.Array:
    """payload: (R, Nb) uint8 — one framed record per row (128-aligned);
    scale, zero_point: (R,) f32; lengths: (R,) int32 valid-byte counts.
    Returns (R, Nb) f32 with padding bytes zeroed.

    ``interpret=None`` resolves via :func:`repro.kernels.compat
    .resolve_interpret` (env ``REPRO_PALLAS_INTERPRET``, else compiled on
    TPU / interpreted elsewhere); resolution happens here, outside the jit,
    so the trace cache keys on the concrete mode.
    """
    return _sensor_decode(payload, scale, zero_point, lengths, blk_r=blk_r,
                          blk_n=blk_n, interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("blk_r", "blk_n", "interpret"))
def _sensor_decode(payload: jax.Array, scale: jax.Array,
                   zero_point: jax.Array, lengths: jax.Array, *, blk_r: int,
                   blk_n: int, interpret: bool) -> jax.Array:
    R, Nb = payload.shape
    blk_r = min(blk_r, R)
    blk_n = min(blk_n, Nb)
    nr = -(-R // blk_r)
    nn = -(-Nb // blk_n)
    pad_r = nr * blk_r - R
    pad_n = nn * blk_n - Nb
    if pad_r or pad_n:
        payload = jnp.pad(payload, ((0, pad_r), (0, pad_n)))
        scale = jnp.pad(scale, (0, pad_r))
        zero_point = jnp.pad(zero_point, (0, pad_r))
        lengths = jnp.pad(lengths, (0, pad_r))

    out = pl.pallas_call(
        functools.partial(_decode_kernel, blk_n=blk_n),
        grid=(nr, nn),
        in_specs=[
            pl.BlockSpec((blk_r, blk_n), lambda i, j: (i, j)),
            pl.BlockSpec((blk_r, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((blk_r, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((blk_r, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk_r, blk_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nr * blk_r, nn * blk_n), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(payload, scale[:, None], zero_point[:, None],
      lengths.astype(jnp.int32)[:, None])
    return out[:R, :Nb]


def _decode_metrics_kernel(payload_ref, scale_ref, zp_ref, len_ref, ts_ref,
                           out_ref, dig_ref, cnt_ref, min_ref, max_ref, *,
                           blk_n: int):
    """Fused decode + per-record reductions (one VMEM sweep).

    The byte-block grid dimension is sequential ("arbitrary"): the
    reduction outputs live in (blk_r, 1) accumulator tiles revisited across
    byte blocks — initialised at the first block, accumulated after, and
    finalised (timestamp/length mixing of the digest) at the last block.
    Digest arithmetic is wrapping uint32, identical op-for-op to the jitted
    ``record_digest`` reduction in :mod:`repro.core.aggregation`, so the
    fused checksums are bit-identical to the two-pass ones and golden
    verdicts are stable across the upgrade.
    """
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    u8 = payload_ref[...]                               # (blk_r, blk_n)
    u = u8.astype(jnp.float32)
    scale = scale_ref[...].astype(jnp.float32)          # (blk_r, 1)
    zp = zp_ref[...].astype(jnp.float32)                # (blk_r, 1)
    ln = len_ref[...]                                   # (blk_r, 1) int32
    col = j * blk_n + jax.lax.broadcasted_iota(
        jnp.int32, u.shape, 1)                          # absolute byte index
    mask = col < ln
    out_ref[...] = jnp.where(mask, (u - zp) * scale, 0.0)

    # per-record reduction partials over this byte block
    w = (col.astype(jnp.uint32) * jnp.uint32(2246822519)
         + jnp.uint32(0x9E3779B9))
    part = jnp.sum(jnp.where(mask, u8.astype(jnp.uint32) * w, 0),
                   axis=1, keepdims=True, dtype=jnp.uint32)
    cnt = jnp.sum(mask, axis=1, keepdims=True, dtype=jnp.int32)
    b32 = u8.astype(jnp.int32)
    mn = jnp.min(jnp.where(mask, b32, 256), axis=1, keepdims=True)
    mx = jnp.max(jnp.where(mask, b32, -1), axis=1, keepdims=True)

    @pl.when(j == 0)
    def _init():
        dig_ref[...] = part
        cnt_ref[...] = cnt
        min_ref[...] = mn
        max_ref[...] = mx

    @pl.when(j > 0)
    def _accumulate():
        dig_ref[...] = dig_ref[...] + part
        cnt_ref[...] = cnt_ref[...] + cnt
        min_ref[...] = jnp.minimum(min_ref[...], mn)
        max_ref[...] = jnp.maximum(max_ref[...], mx)

    @pl.when(j == nj - 1)
    def _finalize():
        ts = ts_ref[...]                                # (blk_r, 1) uint32
        d = (dig_ref[...] ^ ts) * jnp.uint32(2654435761)
        dig_ref[...] = d + ln.astype(jnp.uint32) * jnp.uint32(40503)
        # empty records keep the documented (255, 0) sentinel, not the
        # out-of-range block sentinels
        min_ref[...] = jnp.minimum(min_ref[...], 255)
        max_ref[...] = jnp.maximum(max_ref[...], 0)


def sensor_decode_metrics(payload: jax.Array, scale: jax.Array,
                          zero_point: jax.Array, lengths: jax.Array,
                          ts_low: jax.Array, *, blk_r: int = 128,
                          blk_n: int = 512,
                          interpret: "bool | None" = None
                          ) -> dict[str, jax.Array]:
    """Single-pass decode **and** metric extraction (ISSUE 3 tentpole).

    Same contract as :func:`sensor_decode` plus ``ts_low``: (R,) uint32
    timestamps mod 2**32.  One grid sweep emits the decoded features and
    the per-record reductions the aggregation layer consumes, so metrics
    ride the replay decode pass instead of re-sweeping the payload matrix:

    ``features``        (R, Nb) f32 — identical to :func:`sensor_decode`,
    ``record_digests``  (R,) uint32 — wrapping checksum over valid bytes,
                        mixed with timestamp and length; bit-identical to
                        the aggregation layer's jitted ``record_digest``,
    ``counts``          (R,) int32 valid-byte counts (== ``lengths``),
    ``min_byte`` / ``max_byte``  (R,) int32 over valid bytes (255 / 0 for
                        empty records).

    The default record block is larger than :func:`sensor_decode`'s: the
    (blk_r, 1) accumulator tiles amortize the sequential byte-block sweep
    best over wide record blocks (measured optimum ~128 rows).

    ``interpret=None`` resolves via :func:`repro.kernels.compat
    .resolve_interpret` (env ``REPRO_PALLAS_INTERPRET``, else platform-
    aware), outside the jit cache.
    """
    return _sensor_decode_metrics(payload, scale, zero_point, lengths,
                                  ts_low, blk_r=blk_r, blk_n=blk_n,
                                  interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("blk_r", "blk_n", "interpret"))
def _sensor_decode_metrics(payload: jax.Array, scale: jax.Array,
                           zero_point: jax.Array, lengths: jax.Array,
                           ts_low: jax.Array, *, blk_r: int, blk_n: int,
                           interpret: bool) -> dict[str, jax.Array]:
    R, Nb = payload.shape
    blk_r = min(blk_r, R)
    blk_n = min(blk_n, Nb)
    nr = -(-R // blk_r)
    nn = -(-Nb // blk_n)
    pad_r = nr * blk_r - R
    pad_n = nn * blk_n - Nb
    if pad_r or pad_n:
        payload = jnp.pad(payload, ((0, pad_r), (0, pad_n)))
        scale = jnp.pad(scale, (0, pad_r))
        zero_point = jnp.pad(zero_point, (0, pad_r))
        lengths = jnp.pad(lengths, (0, pad_r))
        ts_low = jnp.pad(ts_low, (0, pad_r))

    col_spec = pl.BlockSpec((blk_r, 1), lambda i, j: (i, 0))
    feats, dig, cnt, mn, mx = pl.pallas_call(
        functools.partial(_decode_metrics_kernel, blk_n=blk_n),
        grid=(nr, nn),
        in_specs=[
            pl.BlockSpec((blk_r, blk_n), lambda i, j: (i, j)),
            col_spec, col_spec, col_spec, col_spec,
        ],
        out_specs=[
            pl.BlockSpec((blk_r, blk_n), lambda i, j: (i, j)),
            col_spec, col_spec, col_spec, col_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nr * blk_r, nn * blk_n), jnp.float32),
            jax.ShapeDtypeStruct((nr * blk_r, 1), jnp.uint32),
            jax.ShapeDtypeStruct((nr * blk_r, 1), jnp.int32),
            jax.ShapeDtypeStruct((nr * blk_r, 1), jnp.int32),
            jax.ShapeDtypeStruct((nr * blk_r, 1), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(payload, scale[:, None], zero_point[:, None],
      lengths.astype(jnp.int32)[:, None],
      ts_low.astype(jnp.uint32)[:, None])
    return {
        "features": feats[:R, :Nb],
        "record_digests": dig[:R, 0],
        "counts": cnt[:R, 0],
        "min_byte": mn[:R, 0],
        "max_byte": mx[:R, 0],
    }


def decode_message_batch(batch: dict, *,
                         interpret: "bool | None" = None) -> jax.Array:
    """Run the decode stage on one assembled replay micro-batch.

    ``batch`` is the dict produced by
    :func:`repro.data.pipeline.assemble_message_batch` — the glue that puts
    this kernel in the batched-replay hot loop (``RosPlay.run_batched`` ->
    batch user logic -> assemble -> decode on device).  Returns (R, Nb) f32
    normalized features with padding bytes zeroed.
    """
    return sensor_decode(jnp.asarray(batch["payload"]),
                         jnp.asarray(batch["scale"]),
                         jnp.asarray(batch["zero_point"]),
                         jnp.asarray(batch["lengths"]),
                         interpret=interpret)


def batch_record_digests(batch: dict,
                         interpret: "bool | None" = None) -> np.ndarray:
    """Per-record digests of one assembled micro-batch via the fused
    consume step — the digest face of :func:`decode_message_batch_metrics`.

    This is what makes the fused kernel the stock batched consume path of
    the staged replay pipeline: the sink stage runs one fused sweep per
    output micro-batch and keeps the ``record_digests`` plane as its
    metric partial, so every batched scenario ships its per-topic
    checksums without any end-of-task re-sweep of the output image.  The
    decoded feature plane is currently discarded by the tap — it becomes
    free the moment a downstream consumer of the output stream is
    attached to the same sweep (the device-context plan).  Bit-identical
    to :func:`repro.core.aggregation.record_digests_np` and the jitted
    ``record_digest`` reduction, so engine choice never moves a verdict.

    ``interpret=None`` resolves via :func:`repro.kernels.compat
    .resolve_interpret` (env toggle, else compiled on TPU / interpret mode
    elsewhere) — the stock sink-stage path must never run the Pallas kernel
    in Python emulation on real hardware.
    """
    return np.asarray(
        decode_message_batch_metrics(batch, interpret=interpret)
        ["record_digests"])


def decode_message_batch_metrics(batch: dict, *,
                                 interpret: "bool | None" = None) -> dict:
    """Fused decode + metrics over one assembled replay micro-batch: the
    features ``decode_message_batch`` returns plus the per-record digest /
    count / min / max reductions, from one payload sweep (see
    :func:`sensor_decode_metrics`)."""
    ts_low = (np.asarray(batch["timestamps"]).astype(np.uint64)
              & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return sensor_decode_metrics(jnp.asarray(batch["payload"]),
                                 jnp.asarray(batch["scale"]),
                                 jnp.asarray(batch["zero_point"]),
                                 jnp.asarray(batch["lengths"]),
                                 jnp.asarray(ts_low),
                                 interpret=interpret)
