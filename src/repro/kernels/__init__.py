"""Pallas TPU kernels for the compute hot spots: flash attention,
Mamba selective scan, and the BinPipedRDD sensor-decode stage.
Each has a jit wrapper in ops.py and a pure-jnp oracle in ref.py."""

from . import ops, ref

__all__ = ["ops", "ref"]
