"""minicpm3-4b [dense] — MLA (multi-head latent attention)
[hf:openbmb/MiniCPM3-4B].  62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA latents: q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v=64 —
the KV cache holds (kv_lora + qk_rope) = 288 floats/token instead of
40 heads x 128 = 5120 (17.8x compression)."""

from repro.models.config import ModelConfig, register

register(ModelConfig(
    name="minicpm3-4b",
    family="dense",
    attention="mla",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_head_dim=64,
    qk_rope_head_dim=32,
    v_head_dim=64,
    rope_theta=10_000.0,
))
