"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1; unverified].
64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072.  Huge experts =>
TP inside experts (d_ff over `model`) + FSDP over `data` to fit HBM."""

from repro.models.config import ModelConfig, register

register(ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    num_experts_per_tok=2,
    expert_sharding="ffn",
    rope_theta=10_000.0,
))
