"""seamless-m4t-large-v2 [audio] — encoder-decoder [arXiv:2308.11596; hf].
24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
The speech frontend is a STUB: input_specs() feeds precomputed frame
embeddings; 24 encoder + 24 decoder layers with cross-attention."""

from repro.models.config import ModelConfig, register

register(ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    is_encoder_decoder=True,
    num_layers=24,
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    rope_theta=10_000.0,
    frontend="audio",
))
