"""Assigned architecture configs (``--arch <id>``). Importing this package
registers all of them; each module holds exactly one architecture with the
exact published shape, plus ``tiny()`` reductions for smoke tests."""

from . import (falcon_mamba_7b, granite_moe_1b_a400m, grok_1_314b,
               hymba_1_5b, minicpm3_4b, qwen2_5_32b, qwen2_vl_7b, qwen3_4b,
               seamless_m4t_large_v2, yi_34b)
from .tiny import tiny_config

ALL_ARCHS = [
    "hymba-1.5b", "granite-moe-1b-a400m", "grok-1-314b", "yi-34b",
    "minicpm3-4b", "qwen3-4b", "qwen2.5-32b", "qwen2-vl-7b",
    "seamless-m4t-large-v2", "falcon-mamba-7b",
]

__all__ = ["ALL_ARCHS", "tiny_config"]
