"""hymba-1.5b [hybrid] — parallel attention + Mamba heads per block
[arXiv:2411.13676; hf].  32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16.  Sliding-window attention (the full-attention
layers of the released model are approximated as SWA; the mamba path carries
global context) => sub-quadratic, runs long_500k."""

from repro.models.config import ModelConfig, register

register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_d_inner=3200,
    sliding_window=1024,
    rope_theta=10_000.0,
))
