"""Reduced same-family configs for CPU smoke tests: small widths, few
layers/experts, tiny vocab — the structure (attention flavour, MoE, SSM,
enc-dec, M-RoPE) is preserved exactly."""

from repro.models.config import ModelConfig, get_config


def tiny_config(name: str) -> ModelConfig:
    cfg = get_config(name)
    kw = dict(
        name=cfg.name + "-tiny",
        num_layers=2,
        d_model=64,
        vocab_size=256,
        dtype="float32",
        remat="none",
    )
    if cfg.has_attention:
        if cfg.attention == "mla":
            kw.update(num_heads=4, num_kv_heads=4, head_dim=16,
                      q_lora_rank=24, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
        else:
            ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
            kv = max(1, 4 // min(ratio, 4))
            kw.update(num_heads=4, num_kv_heads=kv, head_dim=16)
    if cfg.d_ff > 0:
        kw.update(d_ff=96)
    if cfg.is_moe:
        kw.update(num_experts=4,
                  num_experts_per_tok=min(cfg.num_experts_per_tok, 2))
    if cfg.has_ssm:
        kw.update(ssm_d_inner=128, ssm_state=8, ssm_dt_rank=8)
    if cfg.sliding_window:
        kw.update(sliding_window=16)
    if cfg.is_encoder_decoder:
        kw.update(num_encoder_layers=2)
    if cfg.rope_type == "mrope":
        kw.update(mrope_sections=(2, 3, 3))
    return cfg.replace(**kw)
