"""falcon-mamba-7b [ssm] — attention-free Mamba-1 [arXiv:2410.05355;
unverified].  64L d_model=4096 d_inner=8192 ssm_state=16 vocab=65024.
O(1)/token state => runs the long_500k cell."""

from repro.models.config import ModelConfig, register

register(ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    attention="none",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_d_inner=8192,
    ssm_conv=4,
))
