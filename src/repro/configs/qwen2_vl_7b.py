"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
The vision tower is a STUB: input_specs() feeds precomputed patch
embeddings (B, S, d_model) + 3D (t,h,w) position ids for M-RoPE."""

from repro.models.config import ModelConfig, register

register(ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    rope_type="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    frontend="vision",
))
