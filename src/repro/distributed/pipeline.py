"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

The multi-pod mesh's default plan treats ``pod`` as an outer data-parallel
axis; this module is the alternative: layers are split into S = pod stages,
microbatches stream through the stages via ``ppermute`` (cross-pod DCI
traffic is exactly one activation tensor per tick per boundary — the
communication pattern that makes pipeline parallelism attractive between
pods, where links are scarcer than ICI).

Implementation: ``shard_map`` over ``pod``; a ``lax.scan`` over
``n_micro + S - 1`` ticks carries the inter-stage activation; stage s
processes microbatch m = t - s at tick t (bubble ticks compute on dummy
data and are masked).  Differentiable end-to-end (scan + ppermute have
transposes), so ``jax.grad`` through :func:`gpipe_apply` yields pipelined
backward with the same schedule reversed.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import context as ctx


def gpipe_apply(mesh: Mesh, stage_fn: Callable, stage_params,
                x: jax.Array, n_micro: int, axis: str = "pod") -> jax.Array:
    """Run ``x: (B, ...)`` through ``S`` pipeline stages.

    stage_params: pytree with leading dim S on every leaf (sharded over
    ``axis``); ``stage_fn(params_slice, x_mb) -> y_mb`` must preserve the
    microbatch shape.  Returns (B, ...) outputs (valid on every device).
    """
    S = mesh.shape[axis]
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro
    micro = x.reshape((n_micro, mb) + x.shape[1:])

    def body(params_local, micro_local):
        # params_local: (1, ...) slice for this stage; micro_local: full
        # microbatch stack (replicated over the pipeline axis)
        stage = jax.lax.axis_index(axis)
        p_here = jax.tree.map(lambda a: a[0], params_local)
        ticks = n_micro + S - 1
        perm = [(i, i + 1) for i in range(S - 1)]

        def tick(carry, t):
            act_in = carry                          # from stage-1, (mb, ...)
            m = t - stage                           # microbatch index here
            feed = micro_local[jnp.clip(m, 0, n_micro - 1)]
            x_in = jnp.where(stage == 0, feed, act_in)
            y = stage_fn(p_here, x_in)
            sent = jax.lax.ppermute(y, axis, perm)
            # the last stage emits y for microbatch m when valid
            valid = jnp.logical_and(m >= 0, m < n_micro)
            out = jnp.where(valid, y, jnp.zeros_like(y))
            return sent, (out, m)

        z0 = jnp.zeros_like(micro_local[0])
        _, (outs, ms) = jax.lax.scan(tick, z0, jnp.arange(ticks))
        # keep only the last stage's valid outputs, reassembled in order
        is_last = stage == S - 1
        result = jnp.zeros_like(micro_local)
        def place(res, om):
            out, m = om
            upd = jnp.where(is_last, out, jnp.zeros_like(out))
            safe = jnp.clip(m, 0, n_micro - 1)
            cur = jax.lax.dynamic_index_in_dim(res, safe, 0, keepdims=False)
            keep = jnp.logical_and(m >= 0, m < n_micro)
            new = jnp.where(keep, cur + upd, cur)
            return jax.lax.dynamic_update_index_in_dim(res, new, safe, 0), None
        result, _ = jax.lax.scan(place, result, (outs, ms))
        # broadcast final outputs from the last stage to every pod member
        return jax.lax.psum(
            jnp.where(is_last, result, jnp.zeros_like(result)), axis)

    fn = ctx.shard_map(body, mesh=mesh,
                       in_specs=(P(axis), P()), out_specs=P())
    out = fn(stage_params, micro)
    return out.reshape((B,) + x.shape[1:])


def split_layers_into_stages(stacked_params, n_stages: int):
    """(L, ...) stacked layer params -> (S, L//S, ...) stage-major layout."""
    def resh(a):
        L = a.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return a.reshape((n_stages, L // n_stages) + a.shape[1:])
    return jax.tree.map(resh, stacked_params)


def make_stage_fn(block_fn: Callable) -> Callable:
    """Wrap a per-layer ``block_fn(layer_params, x) -> x`` into a stage
    that scans its (L//S, ...) slice."""
    def stage_fn(stage_params, x):
        def body(h, lp):
            return block_fn(lp, h), None
        y, _ = jax.lax.scan(body, x, stage_params)
        return y
    return stage_fn
