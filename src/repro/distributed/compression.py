"""Gradient compression with error feedback (distributed-optimization trick).

Two pieces:

1. ``compress_decompress_ef`` — int8 symmetric per-tensor quantization with
   an error-feedback accumulator, applied to gradients inside the train
   step.  Under GSPMD the gradient reduction happens on the *quantize->
   dequantize* residual-corrected gradients; numerically this is the
   EF-SGD/EF21 scheme (convergence-preserving), and tests verify training
   still reaches the uncompressed loss.

2. ``ring_reduce_scatter_int8`` — an explicit shard_map ring implementation
   showing the wire format: chunks move between neighbours as int8 (4x less
   ICI traffic than f32 all-reduce), accumulation in f32, requantized per
   hop.  Used by the perf study; validated against ``psum`` on a host-device
   mesh in tests/test_distributed.py.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import context as ctx


@dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    bits: int = 8
    ef_dtype: str = "float32"       # error-feedback accumulator dtype


def _quantize(x: jax.Array, bits: int) -> tuple[jax.Array, jax.Array]:
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(x)) / qmax
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def compress_decompress_ef(cfg: CompressionConfig, grads: Any,
                           ef: Any) -> tuple[Any, Any]:
    """Returns (decompressed grads, new error-feedback state)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, scale = _quantize(g32, cfg.bits)
        ghat = q.astype(jnp.float32) * scale
        new_e = (g32 - ghat).astype(e.dtype)
        return ghat.astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), \
        tdef.unflatten([o[1] for o in out])


def ring_reduce_scatter_int8(x: jax.Array, mesh: Mesh, axis: str,
                             ) -> jax.Array:
    """All-reduce-mean of ``x`` (replicated per device) over mesh axis
    ``axis`` with int8 wire traffic: ring reduce-scatter (N-1 int8 hops,
    f32 accumulation, per-hop requantization) followed by an int8
    all-gather.  x: (N*chunk,) with N = mesh.shape[axis]."""
    N = mesh.shape[axis]

    def body(xs):
        idx = jax.lax.axis_index(axis)
        chunk = xs.shape[0] // N
        xc = xs.reshape(N, chunk)
        perm = [(i, (i + 1) % N) for i in range(N)]

        def hop(t, carry):
            acc, send_q, send_s = carry
            recv_q = jax.lax.ppermute(send_q, axis, perm)
            recv_s = jax.lax.ppermute(send_s, axis, perm)
            # which chunk this hop accumulates: c = idx - t - 1 (mod N)
            c = jnp.mod(idx - t - 1, N)
            local = jax.lax.dynamic_index_in_dim(xc, c, 0, keepdims=False)
            acc_new = recv_q.astype(jnp.float32) * recv_s + local
            q, s = _quantize(acc_new, 8)
            return acc_new, q, s

        # step 0: send own chunk idx
        first = jax.lax.dynamic_index_in_dim(xc, idx, 0, keepdims=False)
        q0, s0 = _quantize(first, 8)
        acc, q, s = (first, q0, s0)
        def loop(t, carry):
            return hop(t, carry)
        acc, q, s = jax.lax.fori_loop(0, N - 1, loop, (acc, q, s))
        # after N-1 hops this device owns the full sum of chunk
        # c_own = idx - (N-1) - 1 ... == idx (mod N)?  -> idx + 1 mod N
        own = jnp.mod(idx + 1, N)
        # all-gather the owned chunks (int8 on the wire)
        qg = jax.lax.all_gather(q, axis)                  # (N, chunk) int8
        sg = jax.lax.all_gather(s, axis)                  # (N,)
        owners = jnp.mod(jnp.arange(N) + 1, N)            # device i owns chunk
        # reorder: chunk j was produced by device (j - 1) mod N
        producer = jnp.mod(jnp.arange(N) - 1, N)
        chunks = qg[producer].astype(jnp.float32) * sg[producer][:, None]
        return (chunks.reshape(-1) / N).astype(x.dtype)

    return ctx.shard_map(body, mesh=mesh, in_specs=P(),
                         out_specs=P())(x)
