"""Trace-time mesh context: lets model-internal code (e.g. MoE dispatch)
apply ``with_sharding_constraint`` without threading the mesh through every
signature.  Set by the launch/dry-run layer around ``.lower()`` / execution;
a no-op when unset (single-device tests)."""

from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def mesh_ctx() -> Optional[Mesh]:
    return _MESH


@contextlib.contextmanager
def use_mesh_ctx(mesh: Optional[Mesh]):
    global _MESH
    old = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = old


def constrain(x: jax.Array, *axes) -> jax.Array:
    """Best-effort sharding constraint: ``axes`` are mesh-axis names (or
    tuples of names, or None) per dimension.  Dims that don't divide are
    left unconstrained; no-op without a mesh context."""
    mesh = _MESH
    if mesh is None:
        return x
    parts = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            parts.append(None)
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        names = tuple(a for a in names if a in mesh.axis_names)
        size = 1
        for a in names:
            size *= mesh.shape[a]
        if not names or size <= 0 or dim % size != 0:
            parts.append(None)
        else:
            parts.append(names if len(names) > 1 else names[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


def dp() -> tuple:
    """The data-parallel axes present in the current mesh context."""
    mesh = _MESH
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-portable ``shard_map``: newer jax exposes it at the top
    level (with replication checking behind ``check_vma``), older ships it
    in ``jax.experimental`` (as ``check_rep``).  Checking is disabled on
    both paths — callers here do manual collectives the checker can't
    type."""
    top = getattr(jax, "shard_map", None)
    if top is not None:
        try:
            return top(f, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
        except TypeError:       # top-level API without check_vma
            return top(f, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
