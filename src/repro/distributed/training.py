"""Distributed train / serve step builders: pjit-compiled, sharded via the
logical-axis rules, donation-correct (params/opt-state buffers reused).

These are the functions the launcher runs and the multi-pod dry-run lowers:

    train_step(params, opt_state, batch)        -> (params, opt_state, metrics)
    serve_prefill(params, batch)                -> DecodeState
    serve_decode(params, state, tokens)         -> DecodeState
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import ModelApi, get_model
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update

from . import sharding as shd
from .compression import CompressionConfig, compress_decompress_ef


# --------------------------------------------------------------------------
# step functions (pure)
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    comp_cfg: Optional[CompressionConfig] = None):
    model = get_model(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        if comp_cfg is not None and comp_cfg.enabled:
            grads, ef = compress_decompress_ef(
                comp_cfg, grads, opt_state["error_feedback"])
            new_p, new_s, metrics = adamw_update(
                opt_cfg, grads, opt_state["adamw"], params)
            new_state = {"adamw": new_s, "error_feedback": ef}
        else:
            new_p, new_s, metrics = adamw_update(
                opt_cfg, grads, opt_state["adamw"], params)
            new_state = {"adamw": new_s}
        metrics["loss"] = loss
        return new_p, new_state, metrics

    return train_step


def init_opt_state(cfg: ModelConfig, opt_cfg: AdamWConfig, params,
                   comp_cfg: Optional[CompressionConfig] = None):
    state = {"adamw": adamw_init(opt_cfg, params)}
    if comp_cfg is not None and comp_cfg.enabled:
        state["error_feedback"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.dtype(comp_cfg.ef_dtype)),
            params)
    return state


def make_serve_prefill(cfg: ModelConfig, s_max: int):
    model = get_model(cfg)

    def serve_prefill(params, batch):
        return model.prefill(params, batch, s_max)

    return serve_prefill


def make_serve_decode(cfg: ModelConfig):
    model = get_model(cfg)

    def serve_decode(params, state, tokens):
        return model.decode_step(params, state, tokens)

    return serve_decode


# --------------------------------------------------------------------------
# sharding builders
# --------------------------------------------------------------------------

def param_struct(cfg: ModelConfig):
    model = get_model(cfg)
    return jax.eval_shape(
        functools.partial(model.init_params, jax.random.PRNGKey(0)))


def make_param_shardings(cfg: ModelConfig, mesh: Mesh, fsdp: bool = True):
    model = get_model(cfg)
    return shd.param_shardings(cfg, mesh, model.param_specs(),
                               param_struct(cfg), fsdp=fsdp)


def make_opt_shardings(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh: Mesh,
                       fsdp: bool = True,
                       comp_cfg: Optional[CompressionConfig] = None):
    p_sh = make_param_shardings(cfg, mesh, fsdp)
    from repro.optim.adamw import AdamWState
    state = {"adamw": AdamWState(NamedSharding(mesh, P()), p_sh, p_sh)}
    if comp_cfg is not None and comp_cfg.enabled:
        state["error_feedback"] = p_sh
    return state


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def jit_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh: Mesh,
                   batch_struct: Any, fsdp: bool = True,
                   comp_cfg: Optional[CompressionConfig] = None):
    """Returns the jitted train step with explicit in/out shardings + donation."""
    step = make_train_step(cfg, opt_cfg, comp_cfg)
    p_sh = make_param_shardings(cfg, mesh, fsdp)
    o_sh = make_opt_shardings(cfg, opt_cfg, mesh, fsdp, comp_cfg)
    b_sh = shd.batch_shardings(mesh, batch_struct)
    m_sh = {"loss": replicated(mesh), "grad_norm": replicated(mesh),
            "lr": replicated(mesh)}
    return jax.jit(step,
                   in_shardings=(p_sh, o_sh, b_sh),
                   out_shardings=(p_sh, o_sh, m_sh),
                   donate_argnums=(0, 1))


def jit_serve_prefill(cfg: ModelConfig, mesh: Mesh, s_max: int,
                      batch_struct: Any, state_struct: Any,
                      fsdp: bool = False):
    fn = make_serve_prefill(cfg, s_max)
    p_sh = make_param_shardings(cfg, mesh, fsdp)
    b_sh = shd.batch_shardings(mesh, batch_struct)
    out_sh = _decode_state_shardings(cfg, mesh, state_struct)
    return jax.jit(fn, in_shardings=(p_sh, b_sh), out_shardings=out_sh)


def jit_serve_decode(cfg: ModelConfig, mesh: Mesh, state_struct: Any,
                     fsdp: bool = False):
    fn = make_serve_decode(cfg)
    p_sh = make_param_shardings(cfg, mesh, fsdp)
    st_sh = _decode_state_shardings(cfg, mesh, state_struct)
    tok_sh = NamedSharding(
        mesh, shd.batch_spec(mesh, state_struct.last_logits.shape[0], 2))
    return jax.jit(fn, in_shardings=(p_sh, st_sh, tok_sh),
                   out_shardings=st_sh, donate_argnums=(1,))


def _decode_state_shardings(cfg: ModelConfig, mesh: Mesh, state_struct):
    """Cache leaves sharded (B over dp, head-ish over model); index and
    logits handled explicitly."""
    cache_sh = shd.cache_shardings(cfg, mesh, _cache_of(state_struct))
    B = state_struct.last_logits.shape[0]
    logits_sh = NamedSharding(mesh, shd.batch_spec(mesh, B, 3))
    return _rebuild_state(state_struct, cache_sh,
                          NamedSharding(mesh, P()), logits_sh)


def _cache_of(state):
    from repro.models.encdec import EncDecState
    from repro.models.transformer import DecodeState
    if isinstance(state, DecodeState):
        return state.cache
    return (state.self_kv, state.cross_k, state.cross_v)


def _rebuild_state(state, cache_sh, idx_sh, logits_sh):
    from repro.models.encdec import EncDecState
    from repro.models.transformer import DecodeState
    if isinstance(state, DecodeState):
        return DecodeState(cache_sh, idx_sh, logits_sh)
    kv_sh, ck_sh, cv_sh = cache_sh
    return EncDecState(kv_sh, ck_sh, cv_sh, idx_sh, logits_sh)
