"""Distributed runtime: sharding rules, mesh context, step builders,
gradient compression, pipeline parallelism.

NOTE: submodules are imported lazily (``from repro.distributed import
training``) — this package __init__ stays import-light because model code
imports ``repro.distributed.context`` at module load.
"""

from . import context
from .context import constrain, mesh_ctx, use_mesh_ctx

__all__ = ["context", "constrain", "mesh_ctx", "use_mesh_ctx"]
