"""Logical-axis sharding rules: DP / FSDP / TP / EP / SP on a
(pod, data, model) or (data, model) mesh.

Model code declares *logical* axes per parameter (see models.layers.ParamDef)
and this module maps them to mesh axes.  The default plan:

  vocab / heads / kv_heads / mlp / ssm_inner / latent-up -> "model"   (TP)
  embed (d_model dim of weights)                        -> "data"    (FSDP)
  expert:  "model" when cfg.expert_sharding == "expert" (EP), else None
           (experts replicated, TP inside each expert's d_ff)
  layers (scan dim), norms                              -> replicated

Activations: batch over ("pod","data") [DP], attention heads over "model".
The "pod" axis is an outer data-parallel axis by default (hierarchical
gradient reduction ICI-then-DCI); distributed/pipeline.py can instead run
GPipe over it.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def logical_rules(cfg: ModelConfig, mesh: Mesh, fsdp: bool = True,
                  ) -> dict[Optional[str], Optional[str]]:
    ep = cfg.expert_sharding == "expert"
    return {
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": None if ep else "model",
        "expert": "model" if ep else None,
        "ssm_inner": "model",
        "latent": None,
        "embed": "data" if (fsdp and "data" in mesh.axis_names) else None,
        "layers": None,
        None: None,
    }


def _spec_for(axes: tuple, rules: dict, shape: tuple, mesh: Mesh) -> P:
    parts = []
    for ax, dim in zip(axes, shape):
        mesh_ax = rules.get(ax)
        if mesh_ax is not None and dim % mesh.shape[mesh_ax] != 0:
            mesh_ax = None          # don't shard non-divisible small dims
        parts.append(mesh_ax)
    return P(*parts)


def param_shardings(cfg: ModelConfig, mesh: Mesh, specs: Any,
                    shapes: Any, fsdp: bool = True) -> Any:
    """specs: pytree of logical-axis tuples (models.param_specs);
    shapes: matching pytree of jax.ShapeDtypeStruct (or arrays)."""
    rules = logical_rules(cfg, mesh, fsdp)

    def one(axes, leaf):
        return NamedSharding(mesh, _spec_for(tuple(axes), rules,
                                             leaf.shape, mesh))

    return jax.tree.map(one, specs, shapes,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_spec(mesh: Mesh, global_batch: int, ndim: int) -> P:
    """Shard the leading batch dim over ("pod","data") when divisible."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if dp and global_batch % dp_size == 0:
        return P(dp, *([None] * (ndim - 1)))
    return P(*([None] * ndim))


def batch_shardings(mesh: Mesh, batch: Any) -> Any:
    return jax.tree.map(
        lambda leaf: NamedSharding(
            mesh, batch_spec(mesh, leaf.shape[0], leaf.ndim)), batch)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, state: Any) -> Any:
    """Decode-state sharding: KV/latent caches are (L, B, S, heads-ish, ...)
    — shard B over dp axes when divisible and the head-ish dims over model
    where divisible."""
    rules = logical_rules(cfg, mesh)
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    model_size = mesh.shape.get("model", 1)

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        parts: list = [None] * leaf.ndim
        # find batch dim: axis 1 for (L, B, ...) stacked caches, else 0
        bdim = 1 if leaf.ndim >= 2 else 0
        if leaf.shape[bdim] % dp_size == 0 and dp:
            parts[bdim] = dp
        # shard KV-head / channel dim over model when divisible:
        # (L,B,S,KV,hd) -> KV at -2 ; ssm (L,B,di,N) -> di at -2.
        # GQA archs usually have KV < model-axis size, so fall back to
        # sharding the SEQUENCE dim (axis bdim+1) — sequence-parallel KV,
        # the layout that actually fits 32k x 128-seq caches in HBM.
        if leaf.ndim >= 4:
            placed = False
            cand = leaf.ndim - 2
            if cand != bdim and leaf.shape[cand] % model_size == 0 \
                    and leaf.shape[cand] >= model_size:
                parts[cand] = "model"
                placed = True
            seq = bdim + 1
            if not placed and seq != cand and leaf.ndim >= 5 \
                    and leaf.shape[seq] % model_size == 0 \
                    and leaf.shape[seq] >= model_size:
                parts[seq] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, state)


def constrain_activations(x: jax.Array, mesh: Mesh) -> jax.Array:
    """(B, S, D) activations: batch over dp axes."""
    dp = dp_axes(mesh)
    if not dp or x.shape[0] % int(
            np.prod([mesh.shape[a] for a in dp])) != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, *([None] * (x.ndim - 1)))))
