"""End-to-end training driver: a ~100M-parameter qwen3-family model
trained for a few hundred steps on a bag-backed synthetic corpus, with
async checkpointing and a kill-and-resume demonstration.

Full run (~100M params, 300 steps — minutes on a TPU host, ~1h on this
1-core CPU container):
    PYTHONPATH=src python examples/train_lm.py

CI-scale run (same code path, reduced width/steps):
    PYTHONPATH=src python examples/train_lm.py --ci
"""

import subprocess
import sys
import tempfile

ci = "--ci" in sys.argv
ckpt = tempfile.mkdtemp(prefix="train_lm")

# ~100M params: qwen3 family, 12 layers x d_model 640, vocab from tiny cfg
common = ["--arch", "qwen3-4b", "--tiny", "--ckpt-dir", ckpt]
if ci:
    size = ["--layers", "2", "--d-model", "128", "--steps", "60",
            "--batch", "4", "--seq", "48", "--ckpt-every", "25"]
    resume_steps = "80"
else:
    size = ["--layers", "12", "--d-model", "640", "--steps", "300",
            "--batch", "8", "--seq", "128", "--ckpt-every", "100"]
    resume_steps = "340"

run = [sys.executable, "-m", "repro.launch.train"] + common + size
print(">>", " ".join(run))
subprocess.run(run, check=True)

# simulate a preemption: restart from the latest checkpoint and continue
resume = [sys.executable, "-m", "repro.launch.train"] + common + size
resume[resume.index("--steps") + 1] = resume_steps
resume.append("--resume")
print(">> (restart after simulated preemption)")
print(">>", " ".join(resume))
subprocess.run(resume, check=True)
print("train_lm: OK (trained, checkpointed, resumed)")
