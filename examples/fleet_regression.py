"""Fleet-scale regression harness: sharded scenarios + golden verdicts.

The aggregation layer turns the platform from "replay bags fast" into a
regression suite.  This example:

1. records a 4-shard drive fleet (one bag per vehicle, interleaved
   timestamps),
2. runs a sharded perception scenario and *records its merged output as
   the golden bag* — counts, timestamps and payload checksums included,
3. reruns the identical scenario against the golden: **PASS**,
4. reruns with a subtly perturbed perception model (one bit flipped in a
   handful of detections — the classic silent regression): **FAIL**, with
   per-topic checksum diffs naming exactly what moved.

    PYTHONPATH=src python examples/fleet_regression.py
"""

import os
import tempfile

import numpy as np

from repro.core import Bag, Scenario, ScenarioSuite

SHARDS = 4
FRAMES_PER_SHARD = 300
WORKERS = 4

tmp = tempfile.mkdtemp(prefix="fleet")
shard_paths = []
rng = np.random.RandomState(42)
for s in range(SHARDS):
    path = os.path.join(tmp, f"vehicle{s}.bag")
    with Bag.open_write(path, chunk_bytes=16 * 1024) as bag:
        for i in range(FRAMES_PER_SHARD):
            topic = "/camera" if i % 2 == 0 else "/lidar"
            # shards interleave in time: vehicle s is offset s ms
            bag.write(topic, i * 33_000_000 + s * 1_000_000, rng.bytes(256))
    shard_paths.append(path)
print(f"fleet: {SHARDS} shards x {FRAMES_PER_SHARD} frames")


def detect(msg):
    """Healthy perception: threshold the mean intensity."""
    level = int(np.frombuffer(msg.data, np.uint8).mean())
    return ("/det" + msg.topic, bytes([level]))


def detect_regressed(msg):
    """The regression under test: identical except a rounding change that
    nudges a few detections by one level."""
    level = int(round(float(np.frombuffer(msg.data, np.uint8).mean())))
    return ("/det" + msg.topic, bytes([level]))


def run_fleet(logic, golden=None):
    sc = Scenario("fleet-perception", bag_paths=shard_paths,
                  user_logic=logic, num_partitions=2,
                  golden_bag_path=golden)
    return ScenarioSuite([sc], num_workers=WORKERS).run()["fleet-perception"]


# --- 1. baseline run: merge the fleet, record the golden --------------------
baseline = run_fleet(detect)
rep = baseline.report
stamps = [m.timestamp for m in rep.open_output_bag().read_messages()]
assert stamps == sorted(stamps) and len(stamps) == SHARDS * FRAMES_PER_SHARD
print(f"baseline: {baseline.status} — {rep.shards} shards -> "
      f"{rep.partitions} partitions -> one merged bag "
      f"({len(stamps)} msgs, globally time-ordered)")
for topic, m in rep.metrics.items():
    print(f"  {topic}: n={m.count} bytes={m.bytes_total} "
          f"gap_p99={m.gap_p99_ns/1e6:.1f}ms checksum={m.checksum:#010x}")

golden_path = os.path.join(tmp, "golden.bag")
with open(golden_path, "wb") as f:
    f.write(rep.output_image)

# --- 2. identical rerun vs golden: PASS -------------------------------------
rerun = run_fleet(detect, golden=golden_path)
print(f"rerun vs golden: {rerun.status}")
assert rerun.passed

# --- 3. regressed model vs golden: FAIL with pinpointed diffs ---------------
regressed = run_fleet(detect_regressed, golden=golden_path)
print(regressed.summary())
assert not regressed.passed, "regression went undetected!"
assert all(d.field == "checksum" for d in regressed.diffs)
print("OK: the verdict layer flipped PASS -> FAIL on a one-level "
      "perception nudge")
