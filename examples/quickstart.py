"""Quickstart: the paper's platform end to end in ~60 lines.

1. record a synthetic sensor drive into a Bag (rosbag-style),
2. replay it through the distributed scheduler with a perception
   "User Logic" (here: the on-device BinPipedRDD decode + a tiny jitted
   classifier) across 4 workers with the ROSBag memory cache,
3. inspect the output bag.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Bag, DistributedSimulation
from repro.kernels import ops

# --- 1. record a drive ------------------------------------------------------
tmp = tempfile.mkdtemp(prefix="quickstart")
bag_path = os.path.join(tmp, "drive.bag")
rng = np.random.RandomState(0)
with Bag.open_write(bag_path, chunk_bytes=64 * 1024) as bag:
    for i in range(200):
        frame = rng.randint(0, 256, size=2048, dtype=np.uint8).tobytes()
        bag.write("/camera/front", i * 33_000_000, frame)       # ~30 fps
        if i % 3 == 0:
            scan = rng.randint(0, 256, size=4096, dtype=np.uint8).tobytes()
            bag.write("/lidar/points", i * 33_000_000 + 1, scan)

src = Bag.open_read(bag_path)
print(f"recorded {src.num_messages} messages on {src.topics} "
      f"({src.chunked_file.size()/1024:.0f} KiB, {src.num_chunks} chunks)")

# --- 2. a tiny perception model as User Logic -------------------------------
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (2048, 8), jnp.float32) * 0.02

@jax.jit
def classify(feats):                      # feats: (1, 2048) f32
    return jnp.argmax(feats @ w, axis=-1)

def user_logic(msg):
    if msg.topic != "/camera/front":
        return None
    payload = np.frombuffer(msg.data, np.uint8)[None, :]
    feats = ops.decode_records(
        jnp.asarray(payload), jnp.full((1,), 1 / 255.0, jnp.float32),
        jnp.zeros((1,), jnp.float32),
        jnp.full((1,), payload.shape[1], jnp.int32))
    label = int(classify(feats)[0])
    return ("/detections", bytes([label]))

# --- 3. distributed replay ---------------------------------------------------
report = DistributedSimulation(bag_path, user_logic, num_workers=4,
                               use_memory_cache=True).run()
print(f"replayed {report.messages_in} msgs -> {report.messages_out} "
      f"detections on {report.partitions} partitions in "
      f"{report.wall_time_s:.2f}s ({report.throughput_msgs_s:,.0f} msg/s)")
print(f"scheduler stats: {report.scheduler_stats}")

out = report.open_output_bag()            # merged, timestamp-ordered
dets = [m.data[0] for m in out.read_messages()][:10]
print(f"first detections: {dets}")
print("per-topic metrics:", {t: (m.count, hex(m.checksum))
                             for t, m in report.metrics.items()})
