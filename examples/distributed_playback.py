"""The paper's headline scenario at benchmark scale: a heterogeneous
scenario suite with fault injection and straggler mitigation.

A recorded multi-topic drive feeds a ScenarioSuite of three tests — a
camera-only functional check, a time-windowed replay, and a batched
perception scenario whose user logic assembles replay micro-batches into
fixed-layout arrays and runs the Pallas sensor-decode stage — all fanned
through ONE scheduler.  Mid-suite we kill a worker and add two elastic
replacements; lineage-based retry + speculative execution must deliver
every message exactly once to the output bags.

    PYTHONPATH=src python examples/distributed_playback.py
"""

import os
import tempfile
import threading
import time

import numpy as np

from repro.core import Bag, Scenario, ScenarioSuite

FRAMES = 1200
WORKERS = 4
PARTITIONS = 12

tmp = tempfile.mkdtemp(prefix="playback")
bag_path = os.path.join(tmp, "drive.bag")
rng = np.random.RandomState(7)
with Bag.open_write(bag_path, chunk_bytes=32 * 1024) as bag:
    for i in range(FRAMES):
        topic = "/camera" if i % 2 == 0 else "/lidar"
        bag.write(topic, i * 33_000_000, rng.bytes(1024))


def detect(msg):
    """Per-message user logic (seed contract: -> (topic, payload))."""
    return ("/det" + msg.topic, msg.data[:8])


def decode_batch(msgs):
    """Batched user logic: assemble the micro-batch into fixed-layout
    arrays and decode on device (interpret-mode Pallas), one feature
    message out per input frame."""
    from repro.data.pipeline import assemble_message_batch
    from repro.kernels.sensor_decode import decode_message_batch
    batch = assemble_message_batch(msgs)
    feats = np.asarray(decode_message_batch(batch))        # (R, Nb) f32
    means = feats.mean(axis=1).astype(np.float32)
    return [("/feat" + m.topic, int(ts), means[i:i + 1].tobytes())
            for i, (m, ts) in enumerate(zip(msgs, batch["timestamps"]))]


scenarios = [
    Scenario("camera-functional", bag_path, detect, topics=("/camera",),
             num_partitions=PARTITIONS // 2),
    Scenario("first-10s-window", bag_path, detect,
             start=0, end=10_000_000_000, num_partitions=PARTITIONS // 2),
    Scenario("batched-perception", bag_path, decode_batch, batch_size=64,
             latency_model_s=0.002, num_partitions=PARTITIONS),
]


def chaos(sched):
    sched.add_worker("flaky", fail_after=2)          # dies on its 2nd task

    def later():
        time.sleep(0.15)
        sched.kill_worker("w0")                      # node loss mid-suite
        sched.add_worker("elastic1")                 # elastic scale-up
        sched.add_worker("elastic2")

    threading.Thread(target=later, daemon=True).start()


t0 = time.monotonic()
suite = ScenarioSuite(scenarios, num_workers=WORKERS,
                      scheduler_kwargs={"heartbeat_timeout": 0.5,
                                        "speculation": True},
                      on_scheduler=chaos)
verdicts = suite.run(timeout=240)
wall = time.monotonic() - t0

stats = next(iter(verdicts.values())).report.scheduler_stats
for name, v in verdicts.items():
    rep = v.report
    print(f"{name}: {v.status} partitions={rep.partitions} "
          f"in={rep.messages_in} out={rep.messages_out} "
          f"wall={rep.wall_time_s:.2f}s "
          f"({rep.throughput_msgs_s:.0f} msg/s)")
print(f"suite wall={wall:.2f}s scheduler: {stats}")

assert all(v.passed for v in verdicts.values())
assert verdicts["camera-functional"].report.messages_in == FRAMES // 2
assert verdicts["camera-functional"].report.messages_out == FRAMES // 2
assert verdicts["batched-perception"].report.messages_in == FRAMES
assert verdicts["batched-perception"].report.messages_out == FRAMES
# the merged output bag is globally time-ordered despite 12-way partitioning
stamps = [m.timestamp for m in
          verdicts["batched-perception"].report.open_output_bag()
          .read_messages()]
assert stamps == sorted(stamps)
print("OK: every frame survived a worker crash + node loss "
      f"(retries={stats['retries']}, "
      f"speculative={stats['speculative_launches']}, "
      f"deaths={stats['worker_deaths']})")
