"""The paper's headline scenario at benchmark scale: distributed playback
simulation with fault injection and straggler mitigation.

A recorded multi-topic drive is partitioned across a worker pool; each
worker replays its partition through the ROSBag memory cache into a
perception-latency user logic.  Mid-job we kill a worker and add two
elastic replacements; the scheduler's lineage-based retry + speculative
execution must deliver every message exactly once to the output bags.

    PYTHONPATH=src python examples/distributed_playback.py
"""

import os
import tempfile
import threading
import time

import numpy as np

from repro.core import Bag, Scheduler
from repro.core.bag import partition_bag
from repro.core.simulation import _run_partition

FRAMES = 1200
WORKERS = 4
PARTITIONS = 12

tmp = tempfile.mkdtemp(prefix="playback")
bag_path = os.path.join(tmp, "drive.bag")
rng = np.random.RandomState(7)
with Bag.open_write(bag_path, chunk_bytes=32 * 1024) as bag:
    for i in range(FRAMES):
        bag.write("/camera", i * 33_000_000, rng.bytes(1024))

def user_logic(msg):
    return ("/det", msg.data[:8])

src = Bag.open_read(bag_path)
parts = partition_bag(src, PARTITIONS)
src.close()

t0 = time.monotonic()
with Scheduler(num_workers=WORKERS, heartbeat_timeout=0.5,
               speculation=True) as sched:
    sched.add_worker("flaky", fail_after=2)          # dies on its 2nd task
    for lo, hi in parts:
        sched.submit(_run_partition, bag_path, (lo, hi), user_logic, True,
                     0.002, lineage=("bag", bag_path, lo, hi))

    def chaos():
        time.sleep(0.15)
        sched.kill_worker("w0")                      # node loss mid-job
        sched.add_worker("elastic1")                 # elastic scale-up
        sched.add_worker("elastic2")

    threading.Thread(target=chaos, daemon=True).start()
    results = sched.run(timeout=120)
    stats = dict(sched.stats)

wall = time.monotonic() - t0
total_in = sum(r[0] for r in results.values())
total_out = sum(r[1] for r in results.values())
print(f"partitions={len(parts)} replayed={total_in} detections={total_out} "
      f"wall={wall:.2f}s")
print(f"scheduler: {stats}")
assert total_in == FRAMES, "lost messages!"
assert total_out == FRAMES
print("OK: every frame survived a worker crash + node loss "
      f"(retries={stats['retries']}, "
      f"speculative={stats['speculative_launches']}, "
      f"deaths={stats['worker_deaths']})")
