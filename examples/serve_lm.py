"""Batched serving example: prefill + decode with a request queue on a
small LM (see repro/launch/serve.py for the driver; this example runs it
at a demo scale and prints throughput).

    PYTHONPATH=src python examples/serve_lm.py
"""

import subprocess
import sys

cmd = [sys.executable, "-m", "repro.launch.serve",
       "--arch", "qwen3-4b", "--tiny",
       "--requests", "16", "--batch", "8",
       "--prompt-len", "16", "--gen", "16"]
print(">>", " ".join(cmd))
subprocess.run(cmd, check=True)
