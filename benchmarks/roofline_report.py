"""Roofline table from the dry-run JSONs (results/*.json) — EXPERIMENTS.md
§Roofline reads this output.  One row per (arch x shape x mesh) cell with
the three terms, bottleneck, and MODEL_FLOPS/HLO_FLOPs ratio."""

from __future__ import annotations

import glob
import json
import os

RESULTS_DIR = os.environ.get("REPRO_RESULTS_DIR", "results")


def load_cells(results_dir: str = RESULTS_DIR) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def table(cells: list[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':6s} {'kind':7s} "
           f"{'compute_ms':>10s} {'memory_ms':>10s} {'coll_ms':>9s} "
           f"{'bottleneck':>10s} {'useful':>7s} {'mem/chip':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for d in cells:
        lines.append(
            f"{d['arch']:24s} {d['shape']:12s} {d['mesh']:6s} "
            f"{d.get('kind','?'):7s} "
            f"{d['compute_s']*1e3:10.3f} {d['memory_s']*1e3:10.3f} "
            f"{d['collective_s']*1e3:9.3f} {d['bottleneck']:>10s} "
            f"{d['useful_ratio']:7.2%} "
            f"{d['bytes_per_device']/2**30:8.2f}G")
    return "\n".join(lines)


def main(csv: bool = True) -> list[tuple]:
    cells = load_cells()
    rows = []
    for d in cells:
        dominant_ms = max(d["compute_s"], d["memory_s"],
                          d["collective_s"]) * 1e3
        rows.append((
            f"roofline_{d['arch']}_{d['shape']}_{d['mesh']}",
            dominant_ms * 1e3,
            f"bottleneck={d['bottleneck']} compute={d['compute_s']*1e3:.2f}ms "
            f"memory={d['memory_s']*1e3:.2f}ms "
            f"coll={d['collective_s']*1e3:.2f}ms useful={d['useful_ratio']:.2%}"))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}")
        if not rows:
            print("roofline_no_results,0.0,run repro.launch.dryrun --all first")
    return rows


if __name__ == "__main__":
    print(table(load_cells()))
