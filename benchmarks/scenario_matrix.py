"""Scenario-matrix throughput: batched vs per-message replay × executor
backend.

The scenario engine's two hot-path levers, measured on one synthetic
multi-topic drive:

  * **replay granularity** — per-message Python callbacks vs
    timestamp-ordered micro-batches (``RosPlay.run_batched`` ->
    ``MessageBus.publish_batch`` -> one vectorized user-logic step per
    batch, over arrays from ``assemble_message_batch``),
  * **executor backend** — thread pool vs one-OS-process-per-worker.

The user logic is the BinPipedRDD dequantize stage: per-message it runs
numpy ops per 2 KB frame; batched it runs one vectorized op over the
(R, Nb) assembled payload matrix.  Emits CSV rows plus a machine-readable
``BENCH_scenario_matrix.json`` (msgs/s per backend × batch size) so the
perf trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.scenario_matrix
"""

from __future__ import annotations

import json
import os
import tempfile

import numpy as np

from repro.core.bag import Bag
from repro.core.simulation import Scenario, ScenarioSuite
from repro.data.pipeline import assemble_message_batch

N_FRAMES = 3600
FRAME_BYTES = 2048
TOPICS = ("/camera", "/lidar", "/radar")
BATCH_SIZES = (0, 32, 128)          # 0 = per-message replay
BACKENDS = ("thread", "process")
WORKERS = 2
PARTITIONS = 4

_SCALE = np.float32(1.0 / 255.0)
_ZP = np.float32(0.0)

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         os.pardir, "BENCH_scenario_matrix.json")


def _make_bag(path: str) -> str:
    rng = np.random.RandomState(3)
    bag = Bag.open_write(path, chunk_bytes=32 * 1024)
    for i in range(N_FRAMES):
        bag.write(TOPICS[i % len(TOPICS)], i * 33_000_000,
                  rng.bytes(FRAME_BYTES))
    bag.close()
    return path


def decode_per_message(msg):
    """Per-message user logic: dequantize one frame, emit its feature."""
    arr = np.frombuffer(msg.data, dtype=np.uint8).astype(np.float32)
    feat = ((arr - _ZP) * _SCALE).mean(dtype=np.float32)
    return ("/feat" + msg.topic, np.float32(feat).tobytes())


def decode_batched(msgs):
    """Batched user logic: one vectorized dequantize over the assembled
    (R, Nb) payload matrix — the jitted-array-step stand-in."""
    batch = assemble_message_batch(msgs, scale=float(_SCALE),
                                   zero_point=float(_ZP))
    payload = batch["payload"].astype(np.float32)
    feats = (payload - _ZP) * _SCALE
    # padding bytes decode to 0, so a plain row-sum / valid-length is the
    # masked mean
    means = (feats.sum(axis=1)
             / np.maximum(batch["lengths"], 1)).astype(np.float32)
    return [("/feat" + m.topic, int(ts), means[i].tobytes())
            for i, (m, ts) in enumerate(zip(msgs, batch["timestamps"]))]


def run_matrix(bag_path: str) -> list[dict]:
    results = []
    for backend in BACKENDS:
        for batch in BATCH_SIZES:
            name = f"{backend}-b{batch}"
            logic = ("benchmarks.scenario_matrix:decode_per_message"
                     if batch == 0 else
                     "benchmarks.scenario_matrix:decode_batched")
            scenario = Scenario(
                name=name, bag_path=bag_path, user_logic=logic,
                batch_size=batch or None, num_partitions=PARTITIONS)
            # best-of-3: the first run pays worker startup (process fork,
            # lazy imports); keep the fastest repetition
            rep = None
            for _ in range(3):
                r = ScenarioSuite([scenario], num_workers=WORKERS,
                                  backend=backend).run(
                                      timeout=300)[name].report
                assert r.messages_in == N_FRAMES == r.messages_out, \
                    (r.messages_in, r.messages_out)
                if rep is None or r.wall_time_s < rep.wall_time_s:
                    rep = r
            results.append({
                "backend": backend, "batch_size": batch,
                "wall_s": rep.wall_time_s, "messages": rep.messages_in,
                "msgs_per_s": rep.throughput_msgs_s,
            })
    return results


def main(csv: bool = True, json_path: str = JSON_PATH) -> list[tuple]:
    d = tempfile.mkdtemp(prefix="scenmat")
    bag_path = _make_bag(os.path.join(d, "drive.bag"))
    results = run_matrix(bag_path)

    base = {r["backend"]: r["msgs_per_s"] for r in results
            if r["batch_size"] == 0}
    rows = []
    for r in results:
        speedup = r["msgs_per_s"] / base[r["backend"]]
        r["speedup_vs_per_message"] = speedup
        mode = ("per-message" if r["batch_size"] == 0
                else f"batched(b={r['batch_size']})")
        rows.append((f"scenario_matrix_{r['backend']}_b{r['batch_size']}",
                     r["wall_s"] * 1e6 / r["messages"],
                     f"{mode} {r['msgs_per_s']:.0f} msg/s "
                     f"speedup {speedup:.2f}x vs per-message"))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}")
    if json_path:
        payload = {
            "bench": "scenario_matrix",
            "frames": N_FRAMES, "frame_bytes": FRAME_BYTES,
            "topics": list(TOPICS), "workers": WORKERS,
            "partitions": PARTITIONS,
            "results": results,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows


if __name__ == "__main__":
    main()
