"""Chaos race: a clean suite vs the same suite under injected faults.

The paper's platform claims fault tolerance; this benchmark makes the
claim falsifiable.  It runs one scenario matrix twice on the same thread
pool:

  * **clean**    — no chaos plan installed,
  * **injected** — a seeded :class:`repro.chaos.ChaosPlan` active for the
    whole run: one worker crash (tolerated — the scheduler reschedules
    and the run stays green), one slow-lane stall (tolerated — queued
    backpressure absorbs it), and ``k`` perma-failing user-logic faults
    (NOT tolerated — each burns ``max_attempts`` and must degrade).

The degradation contract is exact, and ``--check`` gates it in CI:

  * the injected suite **completes** (``on_error="degrade"``),
  * exactly ``k`` directly-poisoned scenarios come back ERROR, plus
    every scenario downstream of a poisoned *exporter* in the routing
    DAG (with the upstream lineage in its cause string),
  * every surviving scenario's verdict, per-topic checksums **and
    merged output image** are bit-identical to the clean run — chaos
    may slow the suite down, it may never move a surviving byte.

Emits CSV rows plus machine-readable ``BENCH_chaos.json``.

    PYTHONPATH=src python -m benchmarks.chaos [--check]
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

from repro import chaos
from repro.core import Bag, Scenario, ScenarioSuite

N_MSGS = 1500
TOPICS = ("/camera", "/lidar", "/imu")
NUM_WORKERS = 4
MAX_ATTEMPTS = 2

#: directly-poisoned scenarios (ERROR by injection)
POISONED = ("victim", "provider")
#: scenarios errored transitively through the routing DAG
DOWNSTREAM = ("consumer",)

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         os.pardir, "BENCH_chaos.json")


def _make_bag(path: str, seed: int) -> str:
    rng = np.random.RandomState(seed)
    bag = Bag.open_write(path, chunk_bytes=16 * 1024)
    for i in range(N_MSGS):
        bag.write(TOPICS[i % len(TOPICS)], i * 1000 + int(rng.randint(500)),
                  rng.bytes(96))
    bag.close()
    return path


def _det_logic(msg):
    return ("/det" + msg.topic, msg.data[:16])


def _det_batch_logic(msgs):
    return [("/det" + m.topic, m.timestamp, m.data[:16]) for m in msgs]


def _prov_logic(msg):
    return ("/fused", msg.data[:8])


def _cons_logic(msg):
    return ("/score", bytes(reversed(msg.data)))


def _scenarios(bag: str) -> list[Scenario]:
    return [
        Scenario("clean-a", bag, _det_logic),
        Scenario("victim", bag, _det_logic),
        Scenario("clean-b", bag, _det_logic, drop_rate=0.2, seed=7),
        Scenario("provider", bag, _prov_logic, exports=("/fused",)),
        Scenario("consumer", bag, _cons_logic, imports=("/fused",)),
        Scenario("clean-c", bag, _det_batch_logic, batch_size=64),
    ]


def _plan() -> chaos.ChaosPlan:
    return chaos.ChaosPlan([
        # tolerated: one thread worker dies mid-run; the scheduler reaps
        # it and reruns the lost task elsewhere
        chaos.Fault("worker_crash", target="w1", at=1, count=1),
        # tolerated: one replay lane stalls per delivery for a while;
        # backpressure absorbs it without reordering anything
        chaos.Fault("lane_stall", target="*logic*", at=0, count=20,
                    param=0.002),
        # NOT tolerated: these two scenarios' user logic raises on every
        # attempt — each must degrade to an ERROR verdict, and
        # "provider"'s failure must cascade to "consumer" downstream
        chaos.Fault("logic_raise", target="victim", count=None),
        chaos.Fault("logic_raise", target="provider", count=None),
    ], seed=20260807)


def _suite(bag: str) -> ScenarioSuite:
    return ScenarioSuite(_scenarios(bag), num_workers=NUM_WORKERS,
                         backend="thread", on_error="degrade",
                         scheduler_kwargs={"max_attempts": MAX_ATTEMPTS})


def _snapshot(verdicts) -> dict:
    return {n: {"status": v.status,
                "error": v.error,
                "image": v.report.output_image,
                "checksums": {t: m.checksum for t, m in v.metrics.items()}}
            for n, v in verdicts.items()}


def run_race() -> dict:
    with tempfile.TemporaryDirectory(prefix="repro-chaos-") as d:
        bag = _make_bag(os.path.join(d, "drive.bag"), 3)

        _suite(bag).run(timeout=300)        # warmup: imports, lazy inits

        t0 = time.perf_counter()
        clean = _snapshot(_suite(bag).run(timeout=300))
        clean_s = time.perf_counter() - t0
        assert all(v["status"].startswith("PASS") for v in clean.values()), \
            {n: v["status"] for n, v in clean.items()}

        plan = _plan()
        chaos.install(plan)
        try:
            t0 = time.perf_counter()
            hurt = _snapshot(_suite(bag).run(timeout=300))
            hurt_s = time.perf_counter() - t0
        finally:
            chaos.uninstall()

    expect_error = set(POISONED) | set(DOWNSTREAM)
    errored = {n for n, v in hurt.items() if v["status"] == "ERROR"}
    lineage_ok = all(
        hurt[n]["error"] is not None
        and f"upstream scenario {POISONED[1]!r} errored" in hurt[n]["error"]
        for n in DOWNSTREAM)
    survivors = sorted(set(clean) - expect_error)
    survivors_identical = all(hurt[n] == clean[n] for n in survivors)

    return {
        "bench": "chaos",
        "scenarios": len(clean),
        "messages": N_MSGS,
        "seed": plan.seed,
        "faults_planned": len(plan.faults),
        "faults_fired": plan.fired_count(),
        "fired_by_seam": {
            seam: plan.fired_count(seam)
            for seam in ("worker_crash", "lane_stall", "logic_raise")},
        "poisoned": sorted(POISONED),
        "downstream": sorted(DOWNSTREAM),
        "errors_expected": sorted(expect_error),
        "errors_observed": sorted(errored),
        "errors_exact": errored == expect_error,
        "downstream_lineage_ok": lineage_ok,
        "survivors": survivors,
        "survivors_bit_identical": survivors_identical,
        "clean_wall_s": clean_s,
        "injected_wall_s": hurt_s,
        "injected_vs_clean_ratio": hurt_s / clean_s if clean_s else 0.0,
    }


def main(csv: bool = True, json_path: str = JSON_PATH) -> list[tuple]:
    payload = run_race()
    rows = [
        ("chaos_clean", payload["clean_wall_s"] * 1e6 / N_MSGS,
         f"{payload['scenarios']} scenarios, all PASS"),
        ("chaos_injected", payload["injected_wall_s"] * 1e6 / N_MSGS,
         f"{payload['faults_fired']} faults fired, "
         f"{len(payload['errors_observed'])} ERROR, "
         "survivors bit-identical"),
        ("chaos_injected_vs_clean_ratio",
         payload["injected_vs_clean_ratio"],
         f"errors exact={payload['errors_exact']} "
         f"lineage={payload['downstream_lineage_ok']}"),
    ]
    if csv:
        for name, val, derived in rows[:2]:
            print(f"{name},{val:.2f},{derived}")
        print(f"{rows[2][0]},{rows[2][1]:.2f}x,{rows[2][2]}")
    if json_path:
        out = dict(payload)
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
    return rows


def check(json_path: str = JSON_PATH) -> int:
    """CI gate: the injected run must degrade exactly the poisoned set
    (plus DAG downstream, with lineage) and move nothing else."""
    with open(json_path) as f:
        payload = json.load(f)
    print(f"{payload['faults_fired']} faults fired -> "
          f"{len(payload['errors_observed'])} ERROR "
          f"(expected {len(payload['errors_expected'])}), "
          f"{len(payload['survivors'])} survivors")
    ok = True
    if not payload.get("errors_exact"):
        print(f"FAIL: errored set {payload['errors_observed']} != expected "
              f"{payload['errors_expected']}", file=sys.stderr)
        ok = False
    if not payload.get("downstream_lineage_ok"):
        print("FAIL: downstream ERROR verdicts are missing the upstream "
              "cause lineage", file=sys.stderr)
        ok = False
    if not payload.get("survivors_bit_identical"):
        print("FAIL: a surviving scenario's verdict/checksums/output moved "
              "under chaos", file=sys.stderr)
        ok = False
    if payload.get("fired_by_seam", {}).get("logic_raise", 0) <= 0:
        print("FAIL: the logic_raise faults never fired", file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    if "--check" in sys.argv:
        args = [a for a in sys.argv[1:] if a != "--check"]
        sys.exit(check(args[0] if args else JSON_PATH))
    main()
