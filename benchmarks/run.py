"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` CSV rows:
    bag_cache_*        — paper Fig 6 (ROSBag memory cache vs disk) plus
                         the content-addressed result-cache suite race
                         (cold replay vs warm rehydration); writes
                         ``BENCH_bag_cache.json`` at the repo root
                         (warm must be >= 5x cold with bit-identical
                         verdicts — gated by ``--check`` in CI)
    scalability_*      — paper Fig 7 + §4.2 extrapolation
    scenario_matrix_*  — batched vs per-message replay × executor backend;
                         also writes machine-readable
                         ``BENCH_scenario_matrix.json`` at the repo root
                         (msgs/s per backend × batch size) so the perf
                         trajectory is tracked across PRs
    aggregation_*      — result-aggregation stages (k-way shard merge,
                         single-pass metrics/checksums, golden compare,
                         fused vs two-pass metrics race); writes
                         ``BENCH_aggregation.json`` at the repo root
    pipeline_*         — staged (queued-bus) vs synchronous replay with a
                         deliberately slow subscriber; writes
                         ``BENCH_pipeline.json`` (checksums + suite
                         verdicts asserted bit-identical across modes)
    transport_*        — bridged (loopback TCP LaneTransport -> RemoteBus)
                         vs in-process bus throughput with the stock sink
                         set; writes ``BENCH_transport.json`` (checksums
                         + export/import routing verdicts asserted
                         bit-identical across carriers)
    perception_*       — zero-copy device path: message-path vs
                         frame_to_batch vs fused decode→forward jit with
                         donated buffers; writes ``BENCH_perception.json``
                         (input checksums + suite verdicts asserted
                         bit-identical across all three consumers)
    shm_*              — same-host zero-copy data plane: recycled
                         segment-pool spill vs temp-file spill, shm ring
                         vs loopback-TCP framing; writes
                         ``BENCH_shm.json`` (``--check`` gates shm spill
                         >= 1.5x file and ring >= 1.3x loopback, with
                         verdicts bit-identical across carriers and
                         backends and zero leaked segments)
    binpipe_*          — paper Fig 4 (BinPipedRDD stage throughput)
    chaos_*            — clean suite vs the same suite under a seeded
                         fault plan (worker crash, lane stall, poison
                         user logic); writes ``BENCH_chaos.json``
                         (``--check`` gates that exactly the poisoned
                         scenarios + DAG downstream degrade to ERROR and
                         every survivor is bit-identical)
    roofline_*         — dry-run roofline terms per (arch x shape x mesh)
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (aggregation, bag_cache, binpipe, chaos,
                            perception, pipeline, roofline_report,
                            scalability, scenario_matrix, shm, transport)
    failures = 0
    for mod in (bag_cache, scalability, scenario_matrix, aggregation,
                pipeline, transport, shm, perception, binpipe, chaos,
                roofline_report):
        try:
            mod.main(csv=True)
        except Exception:  # noqa: BLE001
            failures += 1
            name = mod.__name__.split(".")[-1]
            print(f"{name}_FAILED,0.0,{traceback.format_exc(limit=1)!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
