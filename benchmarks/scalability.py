"""Paper Fig 7 + §4.2 — system scalability.

"With the increase of computing resources, the calculation time is also
linearly reduced...  it takes 3 hours to process images using stand-alone
processing, and only 25 minutes after using eight Spark workers."

Reproduction: a one-scenario ScenarioSuite replays a recorded bag through a
perception-latency user-logic model at 1..8 workers.  This container has
ONE core, so wall-clock speedup must come from latency-bound concurrency
(the latency model sleeps, like real accelerator-offloaded perception) —
the same regime as the paper's I/O-and-offload-bound workers.  We report:

  * wall-clock time vs workers (the Fig 7 curve),
  * per-worker task counts (load balance),
  * the paper's §4.2 extrapolation arithmetic (600k hours -> 100 hours at
    10k workers) recomputed from our measured single-worker throughput.
"""

from __future__ import annotations

import os
import tempfile

import numpy as np

from repro.core.bag import Bag
from repro.core.simulation import Scenario, ScenarioSuite

N_FRAMES = 240
FRAME_BYTES = 4096
PER_FRAME_LATENCY_S = 0.004       # simulated perception inference time


def _make_bag(path: str) -> str:
    rng = np.random.RandomState(0)
    bag = Bag.open_write(path, chunk_bytes=16 * 1024)
    for i in range(N_FRAMES):
        bag.write("/camera", i * 33_000_000,
                  rng.bytes(FRAME_BYTES))          # ~30 fps timestamps
    bag.close()
    return path


def _detect(msg):
    return ("/det", msg.data[:16])


def run_curve(workers_list=(1, 2, 4, 8)) -> list[dict]:
    d = tempfile.mkdtemp(prefix="scal")
    path = _make_bag(os.path.join(d, "drive.bag"))
    out = []
    for w in workers_list:
        scenario = Scenario(
            name=f"scal-w{w}", bag_path=path, user_logic=_detect,
            latency_model_s=PER_FRAME_LATENCY_S, num_partitions=w)
        rep = ScenarioSuite([scenario],
                            num_workers=w).run()[scenario.name].report
        out.append({"workers": w, "wall_s": rep.wall_time_s,
                    "msgs": rep.messages_in,
                    "throughput": rep.throughput_msgs_s})
    return out


def main(csv: bool = True) -> list[tuple]:
    curve = run_curve()
    base = curve[0]["wall_s"]
    rows = []
    for r in curve:
        speedup = base / r["wall_s"]
        eff = speedup / r["workers"]
        rows.append((f"scalability_w{r['workers']}",
                     r["wall_s"] * 1e6 / r["msgs"],
                     f"wall {r['wall_s']:.2f}s speedup {speedup:.2f}x "
                     f"efficiency {eff:.0%}"))
    # paper §4.2 arithmetic: single-machine 600,000 h -> 10,000 workers
    per_frame_s = curve[0]["wall_s"] / curve[0]["msgs"]
    single_machine_h = 600_000.0
    workers = 10_000
    ideal_h = single_machine_h / workers
    rows.append(("scalability_extrapolation_10k_workers",
                 ideal_h * 3600.0 * 1e6,
                 f"paper: 600k single-machine hours -> {ideal_h:.0f} h on "
                 f"10k workers (linear; paper claims ~100 h); measured "
                 f"per-frame {per_frame_s*1e3:.2f} ms"))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}")
    return rows


if __name__ == "__main__":
    main()
