"""Paper Fig 4 path — BinPipedRDD throughput.

Measures each stage of the binary pipe (encode -> serialize -> frame ->
device decode -> user logic) in MB/s, including the on-device Pallas
``sensor_decode`` stage (interpret mode on CPU; compiled Mosaic on TPU).
The paper's §2.3 quotes 0.3 s/image for the perception stage; the pipe
must sustain well above the consumer's rate so the accelerator never
starves — that ratio is the derived figure.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import binpipe
from repro.kernels import ops

N_RECORDS = 512
RECORD_BYTES = 8192          # ~a compressed camera frame


def main(csv: bool = True) -> list[tuple]:
    rng = np.random.RandomState(0)
    blobs = [rng.bytes(RECORD_BYTES) for _ in range(N_RECORDS)]
    mb = N_RECORDS * RECORD_BYTES / 2**20

    t0 = time.perf_counter()
    encoded = [binpipe.encode(["/camera", i, b])
               for i, b in enumerate(blobs)]
    t_encode = time.perf_counter() - t0

    t0 = time.perf_counter()
    stream = binpipe.serialize(encoded)
    t_serialize = time.perf_counter() - t0

    t0 = time.perf_counter()
    records = binpipe.deserialize(stream)
    decoded = [binpipe.decode(r) for r in records]
    t_decode_host = time.perf_counter() - t0
    assert decoded[0][2] == blobs[0]

    t0 = time.perf_counter()
    payload, offsets, lengths = binpipe.frame(encoded, align=128)
    t_frame = time.perf_counter() - t0

    part = binpipe.BinaryPartition(encoded)
    t0 = time.perf_counter()
    feats = ops.decode_partition(part, feature_bytes=RECORD_BYTES)
    feats.block_until_ready()
    t_device = time.perf_counter() - t0

    rows = []
    for name, t in (("encode", t_encode), ("serialize", t_serialize),
                    ("deserialize_decode", t_decode_host),
                    ("frame", t_frame), ("device_decode", t_device)):
        mbs = mb / max(t, 1e-9)
        # paper consumer: 0.3 s / image => per-record budget comparison
        per_rec_ms = t / N_RECORDS * 1e3
        rows.append((f"binpipe_{name}", t / N_RECORDS * 1e6,
                     f"{mbs:,.0f} MB/s; {per_rec_ms:.3f} ms/record vs "
                     f"300 ms/image consumer"))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}")
    return rows


if __name__ == "__main__":
    main()
