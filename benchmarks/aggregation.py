"""Aggregation-layer throughput: k-way shard merge, metric reductions and
golden comparison — plus the single-pass (fused) vs two-pass metrics race.

The verdict layer bounds how fast a regression suite turns shard outputs
into pass/fail signals.  Five stages measured on a synthetic fleet of
shard output bags:

  * **merge**    — ``merge_bags``: timestamp-ordered k-way merge of all
    shard images into one bag with a rebuilt time/topic index,
  * **metrics**  — ``Aggregator.compute_metrics``: one mixed-topic pass
    (counts, gap percentiles, wrapping-u32 payload checksums from
    per-record digests),
  * **compare_golden** — ``Aggregator.compare`` of the merged bag against
    a golden copy of itself (exact mode — the regression-suite hot case),
  * **metrics_two_pass** — the pre-ISSUE-3 consume shape: one decode pass
    over the payload matrices (replay's jitted user-logic stage) plus a
    *second* full scan for the metric digests,
  * **metrics_fused** — the single-pass shape: one sweep of the fused
    ``sensor_decode_metrics`` Pallas kernel emits the decoded features
    *and* the per-record digests; metrics fall out of a cheap combine.

Both metric shapes produce bit-identical checksums (asserted), so the
speedup is free of semantic drift.  ``--check`` re-reads the emitted
JSON and exits non-zero if the fused stage is slower than the two-pass
baseline — the CI gate that keeps the fusion honest.

Emits CSV rows plus machine-readable ``BENCH_aggregation.json``
(msgs/s and MB/s per stage) so the perf trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.aggregation [--check]
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.core.aggregation import (Aggregator, TopicMetrics, _U32,
                                    accumulate_topic_state,
                                    finalize_topic_state)
from repro.core.bag import Bag, iter_time_ordered, merge_bags

N_SHARDS = 8
MSGS_PER_SHARD = 2000
PAYLOAD_BYTES = 512
TOPICS = ("/det/camera", "/det/lidar")
REPEATS = 3
METRIC_BATCH = 512

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         os.pardir, "BENCH_aggregation.json")


def _make_fleet_images() -> list[bytes]:
    """Shard output images with interleaved timestamps, so the merge has
    real k-way work to do (not a concatenation)."""
    rng = np.random.RandomState(11)
    images = []
    for s in range(N_SHARDS):
        bag = Bag.open_write(backend="memory", chunk_bytes=64 * 1024)
        for i in range(MSGS_PER_SHARD):
            bag.write(TOPICS[i % len(TOPICS)],
                      i * 1000 + s * (1000 // N_SHARDS),
                      rng.bytes(PAYLOAD_BYTES))
        bag.close()
        images.append(bag.chunked_file.image())
    return images


def _best_of(fn, repeats: int = REPEATS) -> tuple[float, object]:
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _best_of_pair(fa, fb, repeats: int = 5):
    """Interleaved best-of for a head-to-head pair: alternating repeats
    see the same clock/cache conditions, so ramp-up or throttling drift
    never lands on only one contestant (a sequential A-then-B measurement
    on this 1-core container skews the ratio either way by ~2x)."""
    best_a = best_b = float("inf")
    out_a = out_b = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out_a = fa()
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        out_b = fb()
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, out_a, best_b, out_b


def _batches(merged: Bag):
    from repro.data.pipeline import (assemble_message_batch,
                                     iter_message_batches)
    for batch in iter_message_batches(iter_time_ordered(merged),
                                      METRIC_BATCH):
        yield batch, assemble_message_batch(batch)


def _consume_two_pass(merged: Bag) -> dict[str, TopicMetrics]:
    """Pre-ISSUE-3 shape: the decode sweep user logic needs, then a whole
    second scan (re-iterate, re-assemble, re-sweep) for metric digests.
    Returns the full TopicMetrics (checksums inside)."""
    from repro.core.aggregation import _jitted
    from repro.kernels.sensor_decode import decode_message_batch

    # pass 1: replay-time decode (features consumed by the jitted logic)
    sink = 0.0
    for _, arrays in _batches(merged):
        feats = decode_message_batch(arrays)
        sink += float(np.asarray(feats[0, 0]))      # force materialisation

    # pass 2: the metrics re-scan
    record_digest = _jitted()["record_digest"]
    state: dict[str, list] = {}
    for batch, arrays in _batches(merged):
        ts_low = (arrays["timestamps"].astype(np.uint64)
                  & _U32).astype(np.uint32)
        digests = np.asarray(record_digest(
            arrays["payload"], arrays["lengths"], ts_low))
        accumulate_topic_state(state, batch, arrays, digests)
    return finalize_topic_state(state)


def _consume_fused(merged: Bag) -> dict[str, TopicMetrics]:
    """Single-pass shape: one sweep of the fused kernel yields the decoded
    features and the per-record digests; full TopicMetrics fall out of the
    shared combine.  Returns the metrics (checksums inside)."""
    from repro.kernels.sensor_decode import decode_message_batch_metrics

    sink = 0.0
    state: dict[str, list] = {}
    for batch, arrays in _batches(merged):
        out = decode_message_batch_metrics(arrays)
        sink += float(np.asarray(out["features"][0, 0]))
        accumulate_topic_state(state, batch, arrays,
                               np.asarray(out["record_digests"]))
    return finalize_topic_state(state)


def run_stages() -> tuple[list[dict], int, float]:
    images = _make_fleet_images()
    total_msgs = N_SHARDS * MSGS_PER_SHARD
    total_mb = total_msgs * PAYLOAD_BYTES / 1e6
    agg = Aggregator(metric_batch=METRIC_BATCH)

    merge_s, merged = _best_of(lambda: merge_bags(images))
    assert merged.num_messages == total_msgs

    metric_s, metrics = _best_of(lambda: agg.compute_metrics(merged))
    assert sum(m.count for m in metrics.values()) == total_msgs

    golden = Bag.open_read(backend="memory",
                           image=merged.chunked_file.image())
    compare_s, diffs = _best_of(
        lambda: agg.compare(merged, golden, actual_metrics=metrics))
    assert diffs == []

    # warm the jit/pallas caches outside the timed region — on the real
    # merged bag, so the ragged tail-batch shape is compiled too
    _consume_two_pass(merged)
    _consume_fused(merged)
    two_pass_s, two_pass_metrics, fused_s, fused_metrics = _best_of_pair(
        lambda: _consume_two_pass(merged), lambda: _consume_fused(merged))

    # acceptance: the fused sweep's checksums are bit-identical to both
    # the two-pass scan's and the aggregation layer's
    assert {t: m.checksum for t, m in fused_metrics.items()} \
        == {t: m.checksum for t, m in two_pass_metrics.items()} \
        == {t: m.checksum for t, m in metrics.items()}

    return [
        {"stage": "merge", "wall_s": merge_s, "shards": N_SHARDS},
        {"stage": "metrics", "wall_s": metric_s,
         "metric_batch": METRIC_BATCH},
        {"stage": "compare_golden", "wall_s": compare_s, "tolerance": 0},
        {"stage": "metrics_two_pass", "wall_s": two_pass_s,
         "metric_batch": METRIC_BATCH},
        {"stage": "metrics_fused", "wall_s": fused_s,
         "metric_batch": METRIC_BATCH},
    ], total_msgs, total_mb


def main(csv: bool = True, json_path: str = JSON_PATH) -> list[tuple]:
    stages, total_msgs, total_mb = run_stages()
    rows = []
    by_stage = {}
    for st in stages:
        msgs_s = total_msgs / st["wall_s"]
        mb_s = total_mb / st["wall_s"]
        st.update({"messages": total_msgs, "payload_mb": total_mb,
                   "msgs_per_s": msgs_s, "mb_per_s": mb_s})
        by_stage[st["stage"]] = st
        rows.append((f"aggregation_{st['stage']}",
                     st["wall_s"] * 1e6 / total_msgs,
                     f"{msgs_s:.0f} msg/s {mb_s:.1f} MB/s "
                     f"({N_SHARDS} shards x {MSGS_PER_SHARD} msgs)"))
    speedup = (by_stage["metrics_fused"]["msgs_per_s"]
               / by_stage["metrics_two_pass"]["msgs_per_s"])
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}")
        print(f"aggregation_fused_vs_two_pass_speedup,{speedup:.2f}x,"
              f"checksums bit-identical")
    if json_path:
        payload = {
            "bench": "aggregation",
            "shards": N_SHARDS, "msgs_per_shard": MSGS_PER_SHARD,
            "payload_bytes": PAYLOAD_BYTES, "topics": list(TOPICS),
            "fused_vs_two_pass_speedup": speedup,
            "results": stages,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows


def check(json_path: str = JSON_PATH) -> int:
    """CI gate: fail (exit 1) when the fused metrics stage is slower than
    the two-pass baseline of the same run."""
    with open(json_path) as f:
        payload = json.load(f)
    by_stage = {st["stage"]: st for st in payload["results"]}
    fused = by_stage["metrics_fused"]["msgs_per_s"]
    two_pass = by_stage["metrics_two_pass"]["msgs_per_s"]
    ratio = fused / two_pass
    print(f"fused {fused:.0f} msg/s vs two-pass {two_pass:.0f} msg/s "
          f"-> {ratio:.2f}x")
    if ratio < 1.0:
        print("FAIL: fused metrics stage is slower than the two-pass "
              "baseline", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    if "--check" in sys.argv:
        args = [a for a in sys.argv[1:] if a != "--check"]
        sys.exit(check(args[0] if args else JSON_PATH))
    main()
