"""Aggregation-layer throughput: k-way shard merge + jitted metric/checksum
reduction + golden comparison.

The verdict layer is driver-side work that runs once per scenario after the
fleet drains, so its throughput bounds how fast a regression suite can turn
shard outputs into pass/fail signals.  Three stages measured on a synthetic
fleet of shard output bags:

  * **merge**    — ``merge_bags``: timestamp-ordered k-way merge of all
    shard images into one bag with a rebuilt time/topic index,
  * **metrics**  — ``Aggregator.compute_metrics``: per-topic counts, gap
    percentiles and the jitted uint32 payload-checksum reduction over
    ``assemble_message_batch`` arrays,
  * **compare**  — ``Aggregator.compare`` of the merged bag against a
    golden copy of itself (exact mode — the regression-suite hot case).

Emits CSV rows plus machine-readable ``BENCH_aggregation.json``
(msgs/s and MB/s per stage) so the perf trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.aggregation
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.aggregation import Aggregator
from repro.core.bag import Bag, merge_bags

N_SHARDS = 8
MSGS_PER_SHARD = 2000
PAYLOAD_BYTES = 512
TOPICS = ("/det/camera", "/det/lidar")
REPEATS = 3

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         os.pardir, "BENCH_aggregation.json")


def _make_fleet_images() -> list[bytes]:
    """Shard output images with interleaved timestamps, so the merge has
    real k-way work to do (not a concatenation)."""
    rng = np.random.RandomState(11)
    images = []
    for s in range(N_SHARDS):
        bag = Bag.open_write(backend="memory", chunk_bytes=64 * 1024)
        for i in range(MSGS_PER_SHARD):
            bag.write(TOPICS[i % len(TOPICS)],
                      i * 1000 + s * (1000 // N_SHARDS),
                      rng.bytes(PAYLOAD_BYTES))
        bag.close()
        images.append(bag.chunked_file.image())
    return images


def _best_of(fn, repeats: int = REPEATS) -> tuple[float, object]:
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run_stages() -> list[dict]:
    images = _make_fleet_images()
    total_msgs = N_SHARDS * MSGS_PER_SHARD
    total_mb = total_msgs * PAYLOAD_BYTES / 1e6
    agg = Aggregator()

    merge_s, merged = _best_of(lambda: merge_bags(images))
    assert merged.num_messages == total_msgs

    # warm the jit cache outside the timed region (one-off tracing cost)
    agg.compute_metrics(merge_bags(images[:1]))
    metric_s, metrics = _best_of(lambda: agg.compute_metrics(merged))
    assert sum(m.count for m in metrics.values()) == total_msgs

    golden = Bag.open_read(backend="memory",
                           image=merged.chunked_file.image())
    compare_s, diffs = _best_of(
        lambda: agg.compare(merged, golden, actual_metrics=metrics))
    assert diffs == []

    return [
        {"stage": "merge", "wall_s": merge_s, "shards": N_SHARDS},
        {"stage": "metrics", "wall_s": metric_s,
         "metric_batch": agg.metric_batch},
        {"stage": "compare_golden", "wall_s": compare_s, "tolerance": 0},
    ], total_msgs, total_mb


def main(csv: bool = True, json_path: str = JSON_PATH) -> list[tuple]:
    stages, total_msgs, total_mb = run_stages()
    rows = []
    for st in stages:
        msgs_s = total_msgs / st["wall_s"]
        mb_s = total_mb / st["wall_s"]
        st.update({"messages": total_msgs, "payload_mb": total_mb,
                   "msgs_per_s": msgs_s, "mb_per_s": mb_s})
        rows.append((f"aggregation_{st['stage']}",
                     st["wall_s"] * 1e6 / total_msgs,
                     f"{msgs_s:.0f} msg/s {mb_s:.1f} MB/s "
                     f"({N_SHARDS} shards x {MSGS_PER_SHARD} msgs)"))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}")
    if json_path:
        payload = {
            "bench": "aggregation",
            "shards": N_SHARDS, "msgs_per_shard": MSGS_PER_SHARD,
            "payload_bytes": PAYLOAD_BYTES, "topics": list(TOPICS),
            "results": stages,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows


if __name__ == "__main__":
    main()
