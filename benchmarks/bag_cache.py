"""Paper Fig 6 (§4.1) — ROSBag cache performance.

"We compare the performance of ROS play (read) and ROS record (write) with
and without using in memory cache.  Small File Test: repeatedly read and
write [many] files 1 KB in size; Large File Test: [fewer] files 1 MB in
size."   Paper's machine: 12-core, 65 GB; claimed speedups ~3x write,
~5x read (large), ~10x (small).

This container has 1 core and a fast tmpfs-backed disk, so absolute
numbers differ; the *shape* of the result (memory cache >> disk, small
files benefiting most) is the reproduction target.  Disk writes include
fsync (the paper's platform persists bags); set REPRO_BAG_NO_FSYNC=1 to
measure page-cache-only disk I/O.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

from repro.core.bag import Bag

# scaled from the paper (1e6 x 1KB / 1e5 x 1MB) to single-core CI budgets
SMALL = {"count": 20_000, "size": 1024, "label": "small(1KB)"}
LARGE = {"count": 400, "size": 1 << 20, "label": "large(1MB)"}


def _write_bag(backend: str, path, count: int, size: int) -> float:
    payload = bytes(size)
    t0 = time.perf_counter()
    bag = Bag.open_write(path if backend == "disk" else None,
                         backend=backend)
    for i in range(count):
        bag.write("/data", i, payload)
    bag.close()
    return time.perf_counter() - t0


def _read_bag(backend: str, path, image, count: int) -> float:
    t0 = time.perf_counter()
    bag = Bag.open_read(path if backend == "disk" else None,
                        backend=backend, image=image)
    n = 0
    for msg in bag.read_messages():
        n += len(msg.data) and 1
    bag.close()
    assert n == count, (n, count)
    return time.perf_counter() - t0


def run(case: dict) -> dict:
    d = tempfile.mkdtemp(prefix="bagbench")
    try:
        path = os.path.join(d, "disk.bag")
        w_disk = _write_bag("disk", path, case["count"], case["size"])
        r_disk = _read_bag("disk", path, None, case["count"])

        # memory-backed (the paper's MemoryChunkedFile cache)
        t0 = time.perf_counter()
        mb = Bag.open_write(backend="memory")
        payload = bytes(case["size"])
        for i in range(case["count"]):
            mb.write("/data", i, payload)
        mb.close()
        w_mem = time.perf_counter() - t0
        image = mb.chunked_file.image()
        r_mem = _read_bag("memory", None, image, case["count"])
        return {
            "case": case["label"],
            "write_disk_s": w_disk, "write_mem_s": w_mem,
            "read_disk_s": r_disk, "read_mem_s": r_mem,
            "write_speedup": w_disk / w_mem,
            "read_speedup": r_disk / r_mem,
            "mb": case["count"] * case["size"] / 2**20,
        }
    finally:
        shutil.rmtree(d, ignore_errors=True)


def main(csv: bool = True) -> list[tuple]:
    rows = []
    for case in (SMALL, LARGE):
        r = run(case)
        rows.append(("bag_cache_write_" + r["case"],
                     r["write_mem_s"] / max(r["mb"], 1e-9) * 1e6,
                     f"write speedup {r['write_speedup']:.2f}x "
                     f"(disk {r['write_disk_s']:.3f}s mem "
                     f"{r['write_mem_s']:.3f}s)"))
        rows.append(("bag_cache_read_" + r["case"],
                     r["read_mem_s"] / max(r["mb"], 1e-9) * 1e6,
                     f"read speedup {r['read_speedup']:.2f}x "
                     f"(disk {r['read_disk_s']:.3f}s mem "
                     f"{r['read_mem_s']:.3f}s)"))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}")
    return rows


if __name__ == "__main__":
    main()
