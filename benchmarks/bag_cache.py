"""Paper Fig 6 (§4.1) — ROSBag cache performance, two levels deep.

**Level 1 — chunk cache (the paper's figure).**  "We compare the
performance of ROS play (read) and ROS record (write) with and without
using in memory cache.  Small File Test: repeatedly read and write
[many] files 1 KB in size; Large File Test: [fewer] files 1 MB in
size."   Paper's machine: 12-core, 65 GB; claimed speedups ~3x write,
~5x read (large), ~10x (small).  This container has 1 core and a fast
tmpfs-backed disk, so absolute numbers differ; the *shape* of the
result (memory cache >> disk, small files benefiting most) is the
reproduction target.  Disk writes include fsync (the paper's platform
persists bags); set REPRO_BAG_NO_FSYNC=1 to measure page-cache-only
disk I/O.

**Level 2 — result cache (the suite race).**  The same suite runs
twice against one content-addressed result cache (``repro.cache``):
cold (every scenario replays, entries written) then warm (every
scenario rehydrates, zero replay tasks scheduled).  User logic carries
a per-message ``latency_model_s`` so the cold run costs real seconds —
the regime the cache exists for.  Warm must be >= ``MIN_WARM_SPEEDUP``x
faster AND bit-identical: statuses, per-topic checksums, full metric
tuples and the merged output image are asserted equal, and every warm
verdict must carry ``cache == "hit"``.

Emits CSV rows plus machine-readable ``BENCH_bag_cache.json``.
``--check`` re-reads the JSON and gates speedup + parity (the CI
trip-wire); ``--warm-smoke DIR`` runs the suite twice against a
*persistent* cache dir and exits non-zero unless the second invocation
scores at least one hit — the shape CI uses to prove a cache restored
by ``actions/cache`` is actually being consumed across workflow runs.

    PYTHONPATH=src python -m benchmarks.bag_cache
    PYTHONPATH=src python -m benchmarks.bag_cache --check [JSON]
    PYTHONPATH=src python -m benchmarks.bag_cache --warm-smoke DIR
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import sys
import tempfile
import time

import numpy as np

from repro.core import Scenario, ScenarioSuite
from repro.core.bag import Bag

# scaled from the paper (1e6 x 1KB / 1e5 x 1MB) to single-core CI budgets
SMALL = {"count": 20_000, "size": 1024, "label": "small(1KB)"}
LARGE = {"count": 400, "size": 1 << 20, "label": "large(1MB)"}

# -- suite-race knobs ---------------------------------------------------------
SUITE_MSGS = 600             # per scenario bag
SUITE_PAYLOAD = 256
SUITE_LATENCY_S = 0.004      # per-message model cost -> cold ~2.4s/scenario
SUITE_TOPICS = ("/camera", "/lidar")
MIN_WARM_SPEEDUP = 5.0       # acceptance floor, gated by --check

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         os.pardir, "BENCH_bag_cache.json")


def _write_bag(backend: str, path, count: int, size: int) -> float:
    payload = bytes(size)
    t0 = time.perf_counter()
    bag = Bag.open_write(path if backend == "disk" else None,
                         backend=backend)
    for i in range(count):
        bag.write("/data", i, payload)
    bag.close()
    return time.perf_counter() - t0


def _read_bag(backend: str, path, image, count: int) -> float:
    t0 = time.perf_counter()
    bag = Bag.open_read(path if backend == "disk" else None,
                        backend=backend, image=image)
    n = 0
    for msg in bag.read_messages():
        n += len(msg.data) and 1
    bag.close()
    assert n == count, (n, count)
    return time.perf_counter() - t0


def run(case: dict) -> dict:
    d = tempfile.mkdtemp(prefix="bagbench")
    try:
        path = os.path.join(d, "disk.bag")
        w_disk = _write_bag("disk", path, case["count"], case["size"])
        r_disk = _read_bag("disk", path, None, case["count"])

        # memory-backed (the paper's MemoryChunkedFile cache)
        t0 = time.perf_counter()
        mb = Bag.open_write(backend="memory")
        payload = bytes(case["size"])
        for i in range(case["count"]):
            mb.write("/data", i, payload)
        mb.close()
        w_mem = time.perf_counter() - t0
        image = mb.chunked_file.image()
        r_mem = _read_bag("memory", None, image, case["count"])
        return {
            "case": case["label"],
            "write_disk_s": w_disk, "write_mem_s": w_mem,
            "read_disk_s": r_disk, "read_mem_s": r_mem,
            "write_speedup": w_disk / w_mem,
            "read_speedup": r_disk / r_mem,
            "mb": case["count"] * case["size"] / 2**20,
        }
    finally:
        shutil.rmtree(d, ignore_errors=True)


# -- level 2: result-cache suite race -----------------------------------------

def _slow_logic(msg):
    """Module-level so scenarios are cacheable (stable module:attr ref);
    the latency model on the Scenario — not a sleep here — prices it."""
    return ("/det" + msg.topic, msg.data[:16])


def _make_suite_bag(path: str, n: int = SUITE_MSGS) -> str:
    rng = np.random.RandomState(11)     # fixed seed: identical bag content
    bag = Bag.open_write(path, chunk_bytes=8 * 1024)
    for i in range(n):
        bag.write(SUITE_TOPICS[i % len(SUITE_TOPICS)], i * 1000,
                  rng.bytes(SUITE_PAYLOAD))
    bag.close()
    return path


def _suite_scenarios(bag_path: str,
                     latency_s: float = SUITE_LATENCY_S) -> list[Scenario]:
    return [
        Scenario("cached-perception", bag_path, _slow_logic,
                 latency_model_s=latency_s),
        Scenario("cached-planning", bag_path, _slow_logic,
                 topics=("/camera",), drop_rate=0.05, seed=13,
                 latency_model_s=latency_s),
    ]


def _snapshot(verdicts) -> dict:
    """Everything "bit-identical" means for the race: status, per-topic
    checksums, full metric tuples, counts, and the merged output image."""
    return {
        name: {
            "status": v.status,
            "checksums": {t: int(m.checksum)
                          for t, m in sorted(v.metrics.items())},
            "metrics": {t: (m.count, m.bytes_total, m.t_min, m.t_max,
                            m.gap_p50_ns, m.gap_p90_ns, m.gap_p99_ns)
                        for t, m in sorted(v.metrics.items())},
            "messages": (v.report.messages_in, v.report.messages_out,
                         v.report.messages_dropped),
            "output_sha": hashlib.sha256(
                v.report.output_image).hexdigest(),
        }
        for name, v in verdicts.items()
    }


def run_suite_race() -> dict:
    with tempfile.TemporaryDirectory(prefix="repro-cachebench-") as d:
        bag_path = _make_suite_bag(os.path.join(d, "drive.bag"))
        cache_dir = os.path.join(d, "result-cache")

        suite = ScenarioSuite(_suite_scenarios(bag_path), num_workers=2)
        t0 = time.perf_counter()
        cold_v = suite.run(cache=cache_dir, timeout=300)
        cold_s = time.perf_counter() - t0
        cold_stats = suite.last_cache_stats

        suite = ScenarioSuite(_suite_scenarios(bag_path), num_workers=2)
        t0 = time.perf_counter()
        warm_v = suite.run(cache=cache_dir, timeout=300)
        warm_s = time.perf_counter() - t0
        warm_stats = suite.last_cache_stats

    all_warm_hits = all(v.cache == "hit" for v in warm_v.values())
    verdicts_identical = _snapshot(cold_v) == _snapshot(warm_v)
    assert all_warm_hits, f"warm run missed the cache: {warm_stats}"
    assert verdicts_identical, "warm rehydration drifted from cold replay"
    return {
        "bench": "bag_cache_suite",
        "messages": SUITE_MSGS, "payload_bytes": SUITE_PAYLOAD,
        "latency_model_s": SUITE_LATENCY_S,
        "scenarios": sorted(warm_v),
        "cold_wall_s": cold_s, "warm_wall_s": warm_s,
        "warm_speedup": cold_s / warm_s,
        "min_warm_speedup": MIN_WARM_SPEEDUP,
        "cold_stats": cold_stats, "warm_stats": warm_stats,
        "all_warm_hits": all_warm_hits,
        "verdicts_identical": verdicts_identical,
        "checksums": {n: s["checksums"]
                      for n, s in _snapshot(warm_v).items()},
    }


def main(csv: bool = True, json_path: str = JSON_PATH) -> list[tuple]:
    rows = []
    fig6 = []
    for case in (SMALL, LARGE):
        r = run(case)
        fig6.append(r)
        rows.append(("bag_cache_write_" + r["case"],
                     r["write_mem_s"] / max(r["mb"], 1e-9) * 1e6,
                     f"write speedup {r['write_speedup']:.2f}x "
                     f"(disk {r['write_disk_s']:.3f}s mem "
                     f"{r['write_mem_s']:.3f}s)"))
        rows.append(("bag_cache_read_" + r["case"],
                     r["read_mem_s"] / max(r["mb"], 1e-9) * 1e6,
                     f"read speedup {r['read_speedup']:.2f}x "
                     f"(disk {r['read_disk_s']:.3f}s mem "
                     f"{r['read_mem_s']:.3f}s)"))
    race = run_suite_race()
    rows.append(("bag_cache_suite_cold", race["cold_wall_s"] * 1e6,
                 f"{race['cold_wall_s']:.3f}s replayed "
                 f"({race['cold_stats']['puts']} entries written)"))
    rows.append(("bag_cache_suite_warm", race["warm_wall_s"] * 1e6,
                 f"{race['warm_wall_s']:.3f}s rehydrated "
                 f"({race['warm_stats']['hits']} hits)"))
    rows.append(("bag_cache_suite_warm_speedup", race["warm_speedup"],
                 "verdicts + checksums + output image bit-identical"))
    if csv:
        for name, val, derived in rows[:-1]:
            print(f"{name},{val:.2f},{derived}")
        print(f"{rows[-1][0]},{rows[-1][1]:.2f}x,{rows[-1][2]}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"fig6": fig6, "suite_race": race}, f, indent=2)
            f.write("\n")
    return rows


def check(json_path: str = JSON_PATH) -> int:
    """CI gate: warm must be >= MIN_WARM_SPEEDUP x cold AND bit-identical."""
    with open(json_path) as f:
        race = json.load(f)["suite_race"]
    floor = race.get("min_warm_speedup", MIN_WARM_SPEEDUP)
    print(f"warm {race['warm_wall_s']:.3f}s vs cold "
          f"{race['cold_wall_s']:.3f}s -> {race['warm_speedup']:.1f}x "
          f"(floor {floor:.1f}x)")
    if not race.get("all_warm_hits"):
        print("FAIL: warm suite run did not hit the cache on every "
              "scenario", file=sys.stderr)
        return 1
    if not race.get("verdicts_identical"):
        print("FAIL: rehydrated verdicts are not bit-identical to the "
              "cold replay", file=sys.stderr)
        return 1
    if race["warm_speedup"] < floor:
        print(f"FAIL: warm speedup {race['warm_speedup']:.2f}x below the "
              f"{floor:.1f}x floor", file=sys.stderr)
        return 1
    return 0


def warm_smoke(cache_dir: str) -> int:
    """Run a tiny suite twice against a *persistent* cache dir; the
    second invocation must score >= 1 hit.  Bag content and scenario
    params are fixed, and keys are path-independent, so a dir restored
    by CI's ``actions/cache`` keeps hitting across workflow runs."""
    with tempfile.TemporaryDirectory(prefix="repro-cachesmoke-") as d:
        bag_path = _make_suite_bag(os.path.join(d, "drive.bag"), n=120)
        for attempt in (1, 2):
            suite = ScenarioSuite(
                _suite_scenarios(bag_path, latency_s=0.0), num_workers=2)
            suite.run(cache=cache_dir, timeout=120)
            print(f"warm-smoke run {attempt}: {suite.last_cache_stats}")
        hits = suite.last_cache_stats["hits"]
    if hits < 1:
        print("FAIL: second suite invocation scored zero cache hits",
              file=sys.stderr)
        return 1
    print(f"warm-smoke OK: {hits} hit(s) on second invocation")
    return 0


if __name__ == "__main__":
    if "--warm-smoke" in sys.argv:
        i = sys.argv.index("--warm-smoke")
        sys.exit(warm_smoke(sys.argv[i + 1]))
    if "--check" in sys.argv:
        args = [a for a in sys.argv[1:] if a != "--check"]
        sys.exit(check(args[0] if args else JSON_PATH))
    main()
