"""Zero-copy device path: message-path vs zero-copy vs zero-copy+jitted
forward (ISSUE 6 tentpole).

The same stream of DATA frames is consumed three ways, each folding the
identical per-topic metric state:

  * **message**   — ``decode_data`` materialises per-message ``Message``
    objects, ``assemble_message_batch`` re-packs them row by row, digests
    via ``record_digests_np`` (the pre-existing replay path),
  * **zerocopy**  — ``frame_to_batch`` reinterprets the frame's columnar
    body as the batch dict directly (payload matrix is a reshape *view*
    of the frame bytes for uniform aligned records), digests via the same
    numpy engine, folded with ``accumulate_topic_state_arrays``,
  * **device**    — ``frame_to_batch`` feeds a
    :class:`repro.perception.PerceptionStep` with ``metrics=True``: ONE
    jitted program runs the Pallas decode+digest sweep and the model
    forward with donated batch buffers; input digests come off the kernel
    digest plane (cross-engine bit-parity asserted).

All three runs must fold bit-identical per-topic input checksums
(asserted, untimed).  A second untimed phase runs a
``perception://<model>`` scenario suite twice (clean -> golden -> PASS)
and replays the same stream through the zero-copy face, asserting the
output-topic metrics are bit-identical to the suite verdict's — the
acceptance gate of the device path.

Emits CSV rows plus machine-readable ``BENCH_perception.json``.
``--check`` re-reads the JSON and exits non-zero if the zero-copy path
fell below ``MIN_RATIO``x the message path, or any bit-parity assertion
was not recorded — the CI gate.

    PYTHONPATH=src python -m benchmarks.perception [--check]
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import time
from typing import Optional

import numpy as np

from repro.core import Message, Scenario, ScenarioSuite
from repro.core.aggregation import (accumulate_topic_state,
                                    accumulate_topic_state_arrays,
                                    finalize_topic_state, record_digests_np)
from repro.data.pipeline import assemble_message_batch
from repro.net.wire import decode_data, encode_data, frame_to_batch

N_MSGS = 20000
PAYLOAD_BYTES = 256
TOPICS = ("/camera", "/lidar")
FRAME_BATCH = 512          # messages per DATA frame (device batch rows)
REPEATS = 3
MODEL = "qwen3-4b"
SUITE_MSGS = 1024          # verdict-phase stream (two full model sweeps)
SUITE_BATCH = 128
#: CI gate: the zero-copy frame->batch path must beat the per-message
#: decode+assemble path by at least this factor at 256 B payloads
MIN_RATIO = 1.3

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         os.pardir, "BENCH_perception.json")


def _make_messages(n: int = N_MSGS, seed: int = 13) -> list[Message]:
    rng = np.random.RandomState(seed)
    return [Message(TOPICS[i % len(TOPICS)], i * 1000,
                    rng.bytes(PAYLOAD_BYTES))
            for i in range(n)]


def _make_frames(msgs: list[Message],
                 batch: int = FRAME_BATCH) -> list[bytes]:
    return [encode_data(msgs[lo:lo + batch])
            for lo in range(0, len(msgs), batch)]


def _ts_low(ts: np.ndarray) -> np.ndarray:
    return (np.asarray(ts).astype(np.uint64)
            & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def _sums(state: dict) -> dict[str, int]:
    return {t: m.checksum for t, m in finalize_topic_state(state).items()}


def _run_message(frames: list[bytes],
                 verify: bool = False) -> tuple[float, Optional[dict]]:
    """Baseline: per-message objects, then per-row batch re-assembly."""
    state: dict = {}
    t0 = time.perf_counter()
    for body in frames:
        msgs = decode_data(body)
        arrays = assemble_message_batch(msgs)
        digests = record_digests_np(arrays["payload"], arrays["lengths"],
                                    _ts_low(arrays["timestamps"]))
        accumulate_topic_state(state, msgs, arrays, digests)
    wall = time.perf_counter() - t0
    return wall, _sums(state) if verify else None


def _run_zerocopy(frames: list[bytes],
                  verify: bool = False) -> tuple[float, Optional[dict]]:
    """Frame columns ARE the batch: no Message objects, no row copies."""
    state: dict = {}
    t0 = time.perf_counter()
    for body in frames:
        batch = frame_to_batch(body)
        digests = record_digests_np(batch["payload"], batch["lengths"],
                                    _ts_low(batch["timestamps"]))
        accumulate_topic_state_arrays(state, batch, digests)
    wall = time.perf_counter() - t0
    return wall, _sums(state) if verify else None


def _run_device(step, frames: list[bytes],
                verify: bool = False) -> tuple[float, Optional[dict]]:
    """Zero-copy feed into the fused decode->forward jit; input digests
    ride the Pallas digest plane of the same compiled program."""
    state: dict = {}
    t0 = time.perf_counter()
    for body in frames:
        batch = frame_to_batch(body)
        out = step.run_batch(batch)
        accumulate_topic_state_arrays(state, batch,
                                      out["input_record_digests"])
    wall = time.perf_counter() - t0
    return wall, _sums(state) if verify else None


def _best_of_pair(fa, fb, repeats: int = REPEATS):
    """Interleaved best-of (see benchmarks/pipeline.py): alternating
    repeats see the same clock/cache conditions, so drift never lands on
    only one contestant."""
    best_a = best_b = None
    for _ in range(repeats):
        ra = fa()
        if best_a is None or ra[0] < best_a[0]:
            best_a = ra
        rb = fb()
        if best_b is None or rb[0] < best_b[0]:
            best_b = rb
    return best_a, best_b


def _verdict_parity(tmpdir: str) -> dict:
    """Run a ``perception://`` suite twice (clean -> golden -> PASS) and a
    zero-copy replay of the same stream; output-topic metrics must be
    bit-identical across the Message-contract and columnar faces."""
    from repro.perception import get_step

    msgs = _make_messages(SUITE_MSGS, seed=29)
    bag_path = os.path.join(tmpdir, "suite.bag")
    from repro.core import Bag
    bag = Bag.open_write(bag_path, chunk_bytes=32 * 1024)
    for m in msgs:
        bag.write(m.topic, m.timestamp, m.data)
    bag.close()

    def scenario(golden: Optional[str] = None) -> Scenario:
        return Scenario("perception", bag_path,
                        user_logic="perception://" + MODEL,
                        batch_size=SUITE_BATCH, num_partitions=1,
                        golden_bag_path=golden)

    clean = ScenarioSuite([scenario()], num_workers=1).run(
        timeout=600)["perception"]
    assert clean.passed and not clean.vacuous
    golden = os.path.join(tmpdir, "golden.bag")
    with open(golden, "wb") as f:
        f.write(clean.report.output_image)
    rerun = ScenarioSuite([scenario(golden)], num_workers=1).run(
        timeout=600)["perception"]
    assert rerun.status == "PASS", rerun.summary()

    # zero-copy replay: same stream, same batch split, same cached step
    # the suite's logic ref resolves to — logits must be bit-identical
    step = get_step("perception://" + MODEL)
    state: dict = {}
    for body in _make_frames(msgs, SUITE_BATCH):
        out = step.run_batch(frame_to_batch(body))
        digests = record_digests_np(out["payload"], out["lengths"],
                                    _ts_low(out["timestamps"]))
        accumulate_topic_state_arrays(state, out, digests)
    zc = finalize_topic_state(state, sort=True)
    golden_metrics = rerun.metrics
    assert set(zc) == set(golden_metrics)
    for topic in zc:
        assert zc[topic] == golden_metrics[topic], topic
    return {
        "clean_status": clean.status, "golden_status": rerun.status,
        "output_checksums": {t: int(m.checksum) for t, m in zc.items()},
        "output_metrics_identical": True,
    }


def run_race() -> dict:
    from repro.perception import PerceptionStep

    msgs = _make_messages()
    frames = _make_frames(msgs)
    step = PerceptionStep(model=MODEL, metrics=True)

    # bit-parity verification first (untimed; also warms the jit trace):
    # three consumers, one digest algebra, identical folds
    _, msg_sums = _run_message(frames, verify=True)
    _, zc_sums = _run_zerocopy(frames, verify=True)
    _, dev_sums = _run_device(step, frames, verify=True)
    assert msg_sums == zc_sums, "zero-copy batch changed checksums"
    assert msg_sums == dev_sums, "kernel digest plane changed checksums"

    # the race proper: pure timed runs, interleaved best-of
    (msg_s, _), (zc_s, _) = _best_of_pair(
        lambda: _run_message(frames),
        lambda: _run_zerocopy(frames))
    dev_s = min(_run_device(step, frames)[0] for _ in range(REPEATS))

    with tempfile.TemporaryDirectory(prefix="repro-bench-") as d:
        verdicts = _verdict_parity(d)

    return {
        "bench": "perception", "model": MODEL,
        "messages": N_MSGS, "payload_bytes": PAYLOAD_BYTES,
        "frame_batch": FRAME_BATCH, "min_ratio": MIN_RATIO,
        "message_wall_s": msg_s, "zerocopy_wall_s": zc_s,
        "device_wall_s": dev_s,
        "message_msgs_per_s": N_MSGS / msg_s,
        "zerocopy_msgs_per_s": N_MSGS / zc_s,
        "device_msgs_per_s": N_MSGS / dev_s,
        "zerocopy_vs_message_ratio": msg_s / zc_s,
        "device_vs_message_ratio": msg_s / dev_s,
        "checksums_identical": True,
        "checksums": {t: int(c) for t, c in zc_sums.items()},
        **verdicts,
    }


def main(csv: bool = True, json_path: str = JSON_PATH) -> list[tuple]:
    payload = run_race()
    rows = [
        ("perception_message_path",
         payload["message_wall_s"] * 1e6 / N_MSGS,
         f"{payload['message_msgs_per_s']:.0f} msg/s "
         "(decode_data + assemble_message_batch)"),
        ("perception_zerocopy_path",
         payload["zerocopy_wall_s"] * 1e6 / N_MSGS,
         f"{payload['zerocopy_msgs_per_s']:.0f} msg/s (frame_to_batch)"),
        ("perception_device_path",
         payload["device_wall_s"] * 1e6 / N_MSGS,
         f"{payload['device_msgs_per_s']:.0f} msg/s "
         "(fused decode+digests+forward, donated buffers)"),
        ("perception_zerocopy_vs_message_ratio",
         payload["zerocopy_vs_message_ratio"],
         "checksums + suite verdicts bit-identical"),
    ]
    if csv:
        for name, val, derived in rows[:3]:
            print(f"{name},{val:.2f},{derived}")
        print(f"{rows[3][0]},{rows[3][1]:.2f}x,{rows[3][2]}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows


def check(json_path: str = JSON_PATH) -> int:
    """CI gate: fail (exit 1) when the zero-copy path regressed below
    ``MIN_RATIO``x the message path, or bit-parity was not upheld."""
    with open(json_path) as f:
        payload = json.load(f)
    ratio = payload["zerocopy_vs_message_ratio"]
    print(f"zerocopy {payload['zerocopy_msgs_per_s']:.0f} msg/s vs message "
          f"{payload['message_msgs_per_s']:.0f} msg/s -> {ratio:.2f}x "
          f"(gate {payload.get('min_ratio', MIN_RATIO)}x); device "
          f"{payload['device_msgs_per_s']:.0f} msg/s")
    if not payload.get("checksums_identical") \
            or not payload.get("output_metrics_identical") \
            or payload.get("golden_status") != "PASS":
        print("FAIL: device path is not bit-identical to the message path",
              file=sys.stderr)
        return 1
    if ratio < payload.get("min_ratio", MIN_RATIO):
        print("FAIL: zero-copy path regressed below the message-path "
              "speedup gate", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    if "--check" in sys.argv:
        args = [a for a in sys.argv[1:] if a != "--check"]
        sys.exit(check(args[0] if args else JSON_PATH))
    main()
