"""Bridged vs in-process message-pool throughput — the distributed
transport race (ISSUE 5 tentpole).

The paper's platform is multi-node: topic traffic crosses Spark workers
through the message pool.  This benchmark publishes the same stream twice
through the same subscriber set (a counting monitor + a queued recorder):

  * **inproc**  — straight onto one local ``MessageBus``,
  * **bridged** — onto a sender bus whose topics are bridged over a
    loopback TCP ``LaneTransport`` (credit-window flow control, batched
    DATA frames) into a ``RemoteBus`` endpoint that republishes into the
    receiver bus where the same subscribers live.

Both runs must record bit-identical per-topic output checksums
(asserted): the wire is a carrier, never a semantic change.  A second
phase runs a two-scenario export/import suite with the in-process and
cross-process carriers (``export_transport="inline"`` / ``"wire"``) and
asserts the verdicts, checksums *and merged output images* are
bit-identical — the acceptance gate of the distributed message pool.

Emits CSV rows plus machine-readable ``BENCH_transport.json``.
``--check`` re-reads the JSON and exits non-zero if the bridged path
fell below ``MIN_RATIO``x the in-process baseline on loopback, or if any
bit-parity assertion was not recorded — the CI gate.

    PYTHONPATH=src python -m benchmarks.transport [--check]
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import time
from typing import Optional

import numpy as np

from repro.core import (Aggregator, Bag, Message, MessageBus, MetricsTap,
                        RosRecord, Scenario, ScenarioSuite)
from repro.net import LaneTransport, RemoteBus

N_MSGS = 20000
PAYLOAD_BYTES = 256
TOPICS = ("/camera", "/lidar")
PUBLISH_BATCH = 64
FLUSH_BATCH = 512          # wire DATA frame size (messages)
WINDOW = 4096              # receiver credit window (messages)
REPEATS = 3
#: CI gate: bridged throughput must hold at least this fraction of the
#: in-process bus on loopback TCP
MIN_RATIO = 0.5

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         os.pardir, "BENCH_transport.json")


def _make_messages() -> list[Message]:
    rng = np.random.RandomState(11)
    return [Message(TOPICS[i % len(TOPICS)],
                    i * 1000 + int(rng.randint(500)),
                    rng.bytes(PAYLOAD_BYTES))
            for i in range(N_MSGS)]


def _attach_sinks(bus: MessageBus) -> tuple[RosRecord, dict]:
    """The stock partition sink set (see ``_run_scenario_partition``):
    a queued batch recorder plus a streaming :class:`MetricsTap` — what a
    replay consumer actually costs, on either side of a bridge."""
    out = Bag.open_write(backend="memory")
    rec = RosRecord(bus, out, topics=None, batch=True, mode="queued")
    rec.start()
    tap = MetricsTap(engine="numpy")
    bus.subscribe_batch(None, tap.on_batch, mode="queued")
    return rec, {"bag": out, "tap": tap}


def _checksums(sinks: dict) -> dict[str, int]:
    """Per-topic checksums from the streaming tap, cross-checked against a
    full re-sweep of the recorded bag image (outside any timed window)."""
    tapped = {t: m.checksum for t, m in sinks["tap"].finalize().items()}
    swept = Aggregator().compute_metrics(Bag.open_read(
        backend="memory", image=sinks["bag"].chunked_file.image()))
    assert tapped == {t: m.checksum for t, m in swept.items()}
    return tapped


def _publish(bus: MessageBus, msgs: list[Message]) -> None:
    for lo in range(0, len(msgs), PUBLISH_BATCH):
        bus.publish_batch(msgs[lo:lo + PUBLISH_BATCH])


def _run_inproc(msgs: list[Message],
                verify: bool = False) -> tuple[float, Optional[dict]]:
    bus = MessageBus()
    rec, sinks = _attach_sinks(bus)
    t0 = time.perf_counter()
    _publish(bus, msgs)
    bus.drain()
    wall = time.perf_counter() - t0
    rec.stop()
    bus.close()
    sinks["bag"].close()
    assert rec.messages_recorded == len(msgs)
    return wall, _checksums(sinks) if verify else None


def _run_bridged(msgs: list[Message],
                 verify: bool = False) -> tuple[float, Optional[dict], dict]:
    rx = MessageBus()
    rec, sinks = _attach_sinks(rx)
    ep = RemoteBus(bus=rx, window=WINDOW)
    addr = ep.start()
    tx = MessageBus()
    transport = LaneTransport.connect(addr, stream_id="bench",
                                      flush_batch=FLUSH_BATCH)
    bridge = tx.bridge(list(TOPICS), transport, batch=True)
    t0 = time.perf_counter()
    _publish(tx, msgs)
    tx.drain()            # local lanes flushed (everything reached the wire)
    bridge.drain()        # cross-wire barrier: remote bus fully drained
    wall = time.perf_counter() - t0
    rec.stop()
    bridge.close()
    ep.stop()
    tx.close()
    rx.close()
    sinks["bag"].close()
    assert rec.messages_recorded == len(msgs)
    stats = {"frames": transport.frames_sent,
             "wire_bytes": transport.bytes_sent,
             "credit_stalls": transport.credit_stalls}
    return wall, _checksums(sinks) if verify else None, stats


def _best_of_pair(fa, fb, repeats: int = REPEATS):
    """Interleaved best-of (see benchmarks/pipeline.py): alternating
    repeats see the same clock/cache conditions, so drift never lands on
    only one contestant."""
    best_a = best_b = None
    for _ in range(repeats):
        ra = fa()
        if best_a is None or ra[0] < best_a[0]:
            best_a = ra
        rb = fb()
        if best_b is None or rb[0] < best_b[0]:
            best_b = rb
    return best_a, best_b


def _prov_logic(msg):
    return ("/det" + msg.topic, msg.data[:24])


def _cons_logic(msg):
    return ("/score", bytes(reversed(msg.data)))


def _routing_parity(bag_a: str, bag_b: str) -> bool:
    """Run a provider->consumer suite with the in-process and the
    cross-process export carrier; verdicts, per-topic checksums and merged
    output images must be bit-identical."""
    def scenarios():
        return [
            Scenario("provider", bag_a, _prov_logic,
                     exports=("/det/camera", "/det/lidar")),
            Scenario("consumer", bag_b, _cons_logic,
                     imports=("/det/camera", "/det/lidar")),
        ]

    def run(mode: str):
        v = ScenarioSuite(scenarios(), num_workers=2,
                          export_transport=mode).run(timeout=300)
        return {n: (vv.status, vv.report.output_image,
                    {t: m.checksum for t, m in vv.metrics.items()})
                for n, vv in v.items()}

    inline, wire = run("inline"), run("wire")
    assert inline == wire, "export carrier changed results"
    return True


def _make_bag(path: str, seed: int) -> str:
    rng = np.random.RandomState(seed)
    bag = Bag.open_write(path, chunk_bytes=32 * 1024)
    for i in range(2000):
        bag.write(TOPICS[i % len(TOPICS)], i * 1000, rng.bytes(128))
    bag.close()
    return path


def run_race() -> dict:
    msgs = _make_messages()
    # bit-parity verification first (untimed, full checksum re-sweeps):
    # the wire must not move a byte
    _, in_sums = _run_inproc(msgs, verify=True)
    _, br_sums, _ = _run_bridged(msgs, verify=True)
    assert in_sums == br_sums, "bridged replay changed checksums"

    # the race proper: pure timed runs, interleaved best-of — no checksum
    # re-sweeps between timed segments to churn the allocator
    (in_s, _), (br_s, _, wire_stats) = _best_of_pair(
        lambda: _run_inproc(msgs),
        lambda: _run_bridged(msgs))

    with tempfile.TemporaryDirectory(prefix="repro-bench-") as d:
        routing_identical = _routing_parity(
            _make_bag(os.path.join(d, "a.bag"), 5),
            _make_bag(os.path.join(d, "b.bag"), 6))

    payload_total = N_MSGS * PAYLOAD_BYTES
    return {
        "bench": "transport",
        "messages": N_MSGS, "payload_bytes": PAYLOAD_BYTES,
        "publish_batch": PUBLISH_BATCH, "flush_batch": FLUSH_BATCH,
        "window": WINDOW, "min_ratio": MIN_RATIO,
        "inproc_wall_s": in_s, "bridged_wall_s": br_s,
        "inproc_msgs_per_s": N_MSGS / in_s,
        "bridged_msgs_per_s": N_MSGS / br_s,
        "inproc_bytes_per_s": payload_total / in_s,
        "bridged_bytes_per_s": payload_total / br_s,
        "bridged_vs_inproc_ratio": in_s / br_s,
        "wire_frames": wire_stats["frames"],
        "wire_bytes": wire_stats["wire_bytes"],
        "wire_credit_stalls": wire_stats["credit_stalls"],
        "checksums_identical": True,
        "routing_verdicts_identical": routing_identical,
        "checksums": {t: int(c) for t, c in br_sums.items()},
    }


def main(csv: bool = True, json_path: str = JSON_PATH) -> list[tuple]:
    payload = run_race()
    rows = [
        ("transport_inproc", payload["inproc_wall_s"] * 1e6 / N_MSGS,
         f"{payload['inproc_msgs_per_s']:.0f} msg/s "
         f"{payload['inproc_bytes_per_s'] / 1e6:.1f} MB/s (local bus)"),
        ("transport_bridged", payload["bridged_wall_s"] * 1e6 / N_MSGS,
         f"{payload['bridged_msgs_per_s']:.0f} msg/s "
         f"{payload['bridged_bytes_per_s'] / 1e6:.1f} MB/s "
         "(loopback TCP bridge)"),
        ("transport_bridged_vs_inproc_ratio",
         payload["bridged_vs_inproc_ratio"],
         "checksums + routing verdicts bit-identical"),
    ]
    if csv:
        for name, val, derived in rows[:2]:
            print(f"{name},{val:.2f},{derived}")
        print(f"{rows[2][0]},{rows[2][1]:.2f}x,{rows[2][2]}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows


def check(json_path: str = JSON_PATH) -> int:
    """CI gate: fail (exit 1) when the bridged path regressed below
    ``MIN_RATIO``x the in-process bus, or bit-parity was not upheld."""
    with open(json_path) as f:
        payload = json.load(f)
    ratio = payload["bridged_vs_inproc_ratio"]
    print(f"bridged {payload['bridged_msgs_per_s']:.0f} msg/s vs inproc "
          f"{payload['inproc_msgs_per_s']:.0f} msg/s -> {ratio:.2f}x "
          f"(gate {payload.get('min_ratio', MIN_RATIO)}x)")
    if not payload.get("checksums_identical") \
            or not payload.get("routing_verdicts_identical"):
        print("FAIL: bridged transport is not bit-identical to the "
              "in-process bus", file=sys.stderr)
        return 1
    if ratio < payload.get("min_ratio", MIN_RATIO):
        print("FAIL: bridged transport regressed below the loopback "
              "throughput gate", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    if "--check" in sys.argv:
        args = [a for a in sys.argv[1:] if a != "--check"]
        sys.exit(check(args[0] if args else JSON_PATH))
    main()
