"""Same-host zero-copy data plane race — shm vs the incumbents
(ISSUE 9 tentpole).

The paper's platform pays its biggest tax moving simulation data
between processes on one node.  This benchmark races both layers of the
shm data plane against the paths they replace, then proves the carriers
are semantically invisible:

  * **spill race** — a driver-side arg-spill roundtrip (producer hands
    an 8 MB blob to the carrier, consumer obtains a readable buffer,
    carrier storage reclaimed) through the recycled
    :class:`~repro.shm.SegmentPool` + zero-copy
    :func:`~repro.shm.map_segment` view vs the temp-file path
    (``mkstemp`` + write, open + read, unlink).  The pool is warmed
    first: steady-state spill reuses parked segments (already-faulted
    pages), which is exactly what a suite doing repeated spills sees.
  * **ring race** — per-tick export flushes (DATA frames of
    ``encode_data`` message batches) through a
    :class:`~repro.shm.ring.ShmRing` vs a loopback-TCP
    :class:`~repro.net.wire.FrameSocket`.  Send and recv alternate on
    one thread — the SPSC pattern measured as pure per-frame carrier
    cost, deterministic on a single-core host (no GIL-handoff noise).
    Payload checksums are verified in separate untimed passes: a CRC
    sweep inside the timed loop would dominate both carriers and hide
    the difference being measured.
  * **parity matrix** — a provider->consumer ScenarioSuite run on both
    backends across ``export_transport`` inline/wire/shm, and a
    spilling process-backend suite with shm spill on vs off: statuses,
    merged output images and per-topic checksums must be bit-identical
    everywhere (asserted).  The shm run must actually spill via shm
    (``shm_spills > 0``) so the parity claim is not vacuous.

Emits CSV rows plus machine-readable ``BENCH_shm.json``.  ``--check``
re-reads the JSON and exits non-zero when the shm spill fell below
``SPILL_MIN_RATIO``x the temp-file path, the ring below
``RING_MIN_RATIO``x loopback TCP, any bit-parity assertion was not
recorded, or the run leaked ``/dev/shm`` segments — the CI gate.

    PYTHONPATH=src python -m benchmarks.shm [--check]
"""

from __future__ import annotations

import json
import os
import socket
import sys
import tempfile
import time
import zlib
from typing import Optional

import numpy as np

from repro.core import (Bag, Message, ProcessBackend, Scenario,
                        ScenarioSuite)
from repro.net.wire import FrameSocket, T_DATA, encode_data
from repro.shm import SegmentPool, leaked_segments, map_segment
from repro.shm.ring import ShmRing

SPILL_BLOB_BYTES = 8 << 20          # one partition-image-sized blob
SPILL_ROUNDS = 16                   # roundtrips per timed sample
#: CI gate: recycled shm spill must beat the temp-file spill by this
SPILL_MIN_RATIO = 1.5

RING_FRAMES = 12000
RING_MSGS_PER_FRAME = 16            # a per-tick export flush
RING_PAYLOAD_BYTES = 64
RING_DISTINCT_BODIES = 64           # cycled, so encode cost stays setup
#: CI gate: the shm ring must beat loopback TCP by this per frame
RING_MIN_RATIO = 1.3

REPEATS = 3
TOPICS = ("/camera", "/lidar")

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         os.pardir, "BENCH_shm.json")


# -- spill race --------------------------------------------------------------

def _make_blob() -> bytes:
    return np.random.RandomState(7).bytes(SPILL_BLOB_BYTES)


def _spill_shm(pool: SegmentPool, blob: bytes,
               rounds: int = SPILL_ROUNDS) -> float:
    """put -> zero-copy view -> release; the mapping *is* the consumer's
    buffer, so the consume side touches it instead of copying it out."""
    t0 = time.perf_counter()
    for _ in range(rounds):
        handle = pool.put(blob)
        with map_segment(handle) as m:
            assert m.view[0] is not None and m.view[-1] is not None
        pool.release(handle)
    return time.perf_counter() - t0


def _spill_file(spill_dir: str, blob: bytes,
                rounds: int = SPILL_ROUNDS) -> float:
    """The incumbent: mkstemp + write out, open + read back, unlink."""
    t0 = time.perf_counter()
    for _ in range(rounds):
        fd, path = tempfile.mkstemp(dir=spill_dir, prefix="spill-")
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
        with open(path, "rb") as f:
            data = f.read()
        assert data[0] is not None and data[-1] is not None
        os.unlink(path)
    return time.perf_counter() - t0


def _spill_race(blob: bytes) -> dict:
    pool = SegmentPool()
    try:
        # bit-parity first, untimed: both carriers hand back the blob
        handle = pool.put(blob)
        with map_segment(handle) as m:
            shm_crc = zlib.crc32(m.view)
        pool.release(handle)
        with tempfile.TemporaryDirectory(prefix="repro-bench-spill-") as d:
            fd, path = tempfile.mkstemp(dir=d)
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            with open(path, "rb") as f:
                file_crc = zlib.crc32(f.read())
            os.unlink(path)
            src_crc = zlib.crc32(blob)
            assert shm_crc == file_crc == src_crc, \
                "spill carrier changed payload bytes"

            # warm both sides, then interleaved best-of
            _spill_shm(pool, blob, rounds=2)
            _spill_file(d, blob, rounds=2)
            best_shm = best_file = None
            for _ in range(REPEATS):
                s = _spill_shm(pool, blob)
                best_shm = s if best_shm is None else min(best_shm, s)
                f = _spill_file(d, blob)
                best_file = f if best_file is None else min(best_file, f)
        recycled = pool.recycled
    finally:
        pool.shutdown()
    return {"shm_s": best_shm, "file_s": best_file,
            "ratio": best_file / best_shm, "recycled": recycled,
            "crc": src_crc & 0xFFFFFFFF}


# -- ring race ---------------------------------------------------------------

def _make_bodies() -> list[bytes]:
    rng = np.random.RandomState(11)
    bodies = []
    for b in range(RING_DISTINCT_BODIES):
        msgs = [Message(TOPICS[i % len(TOPICS)],
                        (b * RING_MSGS_PER_FRAME + i) * 1000,
                        rng.bytes(RING_PAYLOAD_BYTES))
                for i in range(RING_MSGS_PER_FRAME)]
        bodies.append(encode_data(msgs))
    return bodies


def _run_ring(bodies: list[bytes], frames: int,
              verify: bool = False) -> tuple[float, int]:
    tx = ShmRing.create()
    rx = ShmRing.attach(tx.name)
    n = len(bodies)
    crc = 0
    t0 = time.perf_counter()
    for i in range(frames):
        tx.send_frame(T_DATA, bodies[i % n])
        ftype, body = rx.recv_frame()
        if verify:
            assert ftype == T_DATA
            crc = zlib.crc32(body, crc)
    wall = time.perf_counter() - t0
    rx.close(unlink=False)
    tx.close()
    return wall, crc & 0xFFFFFFFF


def _run_wire(bodies: list[bytes], frames: int,
              verify: bool = False) -> tuple[float, int]:
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    c = socket.create_connection(srv.getsockname())
    s, _ = srv.accept()
    srv.close()
    c.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    tx, rx = FrameSocket(c), FrameSocket(s)
    n = len(bodies)
    crc = 0
    t0 = time.perf_counter()
    for i in range(frames):
        tx.send_frame(T_DATA, bodies[i % n])
        ftype, body = rx.recv_frame()
        if verify:
            assert ftype == T_DATA
            crc = zlib.crc32(body, crc)
    wall = time.perf_counter() - t0
    tx.close()
    rx.close()
    return wall, crc & 0xFFFFFFFF


def _ring_race(bodies: list[bytes]) -> dict:
    # bit-parity first, untimed: one full cycle of distinct bodies with
    # a CRC sweep on both carriers must match the source exactly
    n = len(bodies)
    src_crc = 0
    for b in bodies:
        src_crc = zlib.crc32(b, src_crc)
    src_crc &= 0xFFFFFFFF
    _, ring_crc = _run_ring(bodies, n, verify=True)
    _, wire_crc = _run_wire(bodies, n, verify=True)
    assert ring_crc == wire_crc == src_crc, \
        "frame carrier changed payload bytes"

    best_ring = best_wire = None
    for _ in range(REPEATS):
        r, _ = _run_ring(bodies, RING_FRAMES)
        best_ring = r if best_ring is None else min(best_ring, r)
        w, _ = _run_wire(bodies, RING_FRAMES)
        best_wire = w if best_wire is None else min(best_wire, w)
    return {"shm_s": best_ring, "wire_s": best_wire,
            "ratio": best_wire / best_ring,
            "frame_bytes": len(bodies[0]), "crc": src_crc}


# -- parity matrix -----------------------------------------------------------

def _prov_logic(msg):
    return ("/det" + msg.topic, msg.data[:24])


def _cons_logic(msg):
    return ("/score", bytes(reversed(msg.data)))


def _make_bag(path: str, seed: int) -> str:
    rng = np.random.RandomState(seed)
    bag = Bag.open_write(path, chunk_bytes=32 * 1024)
    for i in range(2000):
        bag.write(TOPICS[i % len(TOPICS)], i * 1000, rng.bytes(128))
    bag.close()
    return path


def _suite_fingerprint(bag_a: str, bag_b: str, backend,
                       mode: str, capture: Optional[list] = None) -> dict:
    suite = ScenarioSuite(
        [Scenario("provider", bag_a, _prov_logic,
                  exports=("/det/camera", "/det/lidar")),
         Scenario("consumer", bag_b, _cons_logic,
                  imports=("/det/camera", "/det/lidar"))],
        num_workers=2, backend=backend, export_transport=mode,
        on_scheduler=(capture.append if capture is not None else None))
    verdicts = suite.run(timeout=300)
    return {n: (v.status, v.report.output_image,
                {t: m.checksum for t, m in v.metrics.items()})
            for n, v in verdicts.items()}


def _carrier_parity(bag_a: str, bag_b: str) -> bool:
    """Verdicts, merged output images and checksums must be
    bit-identical across both backends and all three export carriers."""
    results = {}
    for backend in ("thread", "process"):
        for mode in ("inline", "wire", "shm"):
            results[(backend, mode)] = _suite_fingerprint(
                bag_a, bag_b, backend, mode)
    baseline = results[("thread", "inline")]
    for key, got in results.items():
        assert got == baseline, f"export carrier changed results: {key}"
    return True


def _spill_parity(bag_a: str, bag_b: str) -> bool:
    """A spilling process-backend suite with shm spill on vs off: same
    bits out, and the shm run must actually have spilled via shm."""
    results = {}
    shm_spills = 0
    for shm in (False, True):
        captured: list = []
        backend = ProcessBackend(spill_bytes=1024, shm=shm)
        results[shm] = _suite_fingerprint(bag_a, bag_b, backend,
                                          "inline", capture=captured)
        if shm and captured:
            shm_spills = captured[0].stats.get("shm_spills", 0)
    assert results[False] == results[True], \
        "shm spill carrier changed results"
    assert shm_spills > 0, "shm parity run never spilled via shm"
    return True


# -- driver ------------------------------------------------------------------

def run_race() -> dict:
    spill = _spill_race(_make_blob())
    ring = _ring_race(_make_bodies())
    with tempfile.TemporaryDirectory(prefix="repro-bench-shm-") as d:
        bag_a = _make_bag(os.path.join(d, "a.bag"), 5)
        bag_b = _make_bag(os.path.join(d, "b.bag"), 6)
        carriers_identical = _carrier_parity(bag_a, bag_b)
        spills_identical = _spill_parity(bag_a, bag_b)
    leaks = leaked_segments()
    blob_mb = SPILL_BLOB_BYTES / 1e6
    return {
        "bench": "shm",
        "spill_blob_bytes": SPILL_BLOB_BYTES,
        "spill_rounds": SPILL_ROUNDS,
        "spill_min_ratio": SPILL_MIN_RATIO,
        "spill_shm_s": spill["shm_s"],
        "spill_file_s": spill["file_s"],
        "spill_shm_mb_per_s": SPILL_ROUNDS * blob_mb / spill["shm_s"],
        "spill_file_mb_per_s": SPILL_ROUNDS * blob_mb / spill["file_s"],
        "spill_shm_vs_file_ratio": spill["ratio"],
        "spill_segments_recycled": spill["recycled"],
        "ring_frames": RING_FRAMES,
        "ring_frame_bytes": ring["frame_bytes"],
        "ring_min_ratio": RING_MIN_RATIO,
        "ring_shm_s": ring["shm_s"],
        "ring_wire_s": ring["wire_s"],
        "ring_shm_frames_per_s": RING_FRAMES / ring["shm_s"],
        "ring_wire_frames_per_s": RING_FRAMES / ring["wire_s"],
        "ring_shm_vs_wire_ratio": ring["ratio"],
        "checksums_identical": True,
        "carrier_verdicts_identical": carriers_identical,
        "spill_verdicts_identical": spills_identical,
        "shm_leaks": leaks,
        "checksums": {"spill": spill["crc"], "ring": ring["crc"]},
    }


def main(csv: bool = True, json_path: str = JSON_PATH) -> list[tuple]:
    payload = run_race()
    rows = [
        ("shm_spill", payload["spill_shm_s"] * 1e3 / SPILL_ROUNDS,
         f"{payload['spill_shm_mb_per_s']:.0f} MB/s roundtrip "
         f"(recycled pool + zero-copy view)"),
        ("shm_spill_file", payload["spill_file_s"] * 1e3 / SPILL_ROUNDS,
         f"{payload['spill_file_mb_per_s']:.0f} MB/s roundtrip "
         "(temp-file spill)"),
        ("shm_spill_vs_file_ratio", payload["spill_shm_vs_file_ratio"],
         f"gate {SPILL_MIN_RATIO}x, payload bit-identical"),
        ("shm_ring", payload["ring_shm_s"] * 1e6 / RING_FRAMES,
         f"{payload['ring_shm_frames_per_s']:.0f} frames/s (shm ring)"),
        ("shm_ring_wire", payload["ring_wire_s"] * 1e6 / RING_FRAMES,
         f"{payload['ring_wire_frames_per_s']:.0f} frames/s "
         "(loopback TCP)"),
        ("shm_ring_vs_wire_ratio", payload["ring_shm_vs_wire_ratio"],
         f"gate {RING_MIN_RATIO}x, verdicts bit-identical on both "
         "backends"),
    ]
    if csv:
        print(f"{rows[0][0]},{rows[0][1]:.2f}ms,{rows[0][2]}")
        print(f"{rows[1][0]},{rows[1][1]:.2f}ms,{rows[1][2]}")
        print(f"{rows[2][0]},{rows[2][1]:.2f}x,{rows[2][2]}")
        print(f"{rows[3][0]},{rows[3][1]:.2f}us,{rows[3][2]}")
        print(f"{rows[4][0]},{rows[4][1]:.2f}us,{rows[4][2]}")
        print(f"{rows[5][0]},{rows[5][1]:.2f}x,{rows[5][2]}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows


def check(json_path: str = JSON_PATH) -> int:
    """CI gate: fail (exit 1) when either shm layer regressed below its
    ratio gate, bit-parity was not upheld, or segments leaked."""
    with open(json_path) as f:
        payload = json.load(f)
    spill_ratio = payload["spill_shm_vs_file_ratio"]
    ring_ratio = payload["ring_shm_vs_wire_ratio"]
    spill_gate = payload.get("spill_min_ratio", SPILL_MIN_RATIO)
    ring_gate = payload.get("ring_min_ratio", RING_MIN_RATIO)
    print(f"shm spill {payload['spill_shm_mb_per_s']:.0f} MB/s vs file "
          f"{payload['spill_file_mb_per_s']:.0f} MB/s -> "
          f"{spill_ratio:.2f}x (gate {spill_gate}x)")
    print(f"shm ring {payload['ring_shm_frames_per_s']:.0f} frames/s vs "
          f"wire {payload['ring_wire_frames_per_s']:.0f} frames/s -> "
          f"{ring_ratio:.2f}x (gate {ring_gate}x)")
    ok = True
    if not (payload.get("checksums_identical")
            and payload.get("carrier_verdicts_identical")
            and payload.get("spill_verdicts_identical")):
        print("FAIL: a shm carrier is not bit-identical to the path it "
              "replaces", file=sys.stderr)
        ok = False
    if payload.get("shm_leaks"):
        print(f"FAIL: leaked /dev/shm segments: {payload['shm_leaks']}",
              file=sys.stderr)
        ok = False
    if spill_ratio < spill_gate:
        print("FAIL: shm spill regressed below the temp-file gate",
              file=sys.stderr)
        ok = False
    if ring_ratio < ring_gate:
        print("FAIL: shm ring regressed below the loopback gate",
              file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    if "--check" in sys.argv:
        args = [a for a in sys.argv[1:] if a != "--check"]
        sys.exit(check(args[0] if args else JSON_PATH))
    main()
