"""Staged vs synchronous replay with a deliberately slow subscriber — the
queue-backed MessageBus pipeline race (ISSUE 4 tentpole).

The paper's platform decouples producers and consumers through the ROS
message pool so replay never waits on a slow node.  The seed bus delivered
synchronously: one slow subscriber (user logic, recorder, a safety
monitor) stalled ``RosPlay`` and the whole partition.  This benchmark
replays the same bag through the same subscriber set twice:

  * **sync**   — every subscription synchronous: bag read, user logic,
    the slow monitor and bag serialization alternate on one thread,
  * **staged** — queued subscriptions + double-buffered prefetch: the
    read → decode+logic → record stages overlap, the slow monitor drains
    on its own lane, and ``drain()`` re-synchronises at end of replay.

Both runs must deliver identical message counts and bit-identical
per-topic output checksums (asserted) — staging is an overlap
optimisation, not a semantic change.  A second phase runs a small
``ScenarioSuite`` in both modes and asserts the *verdicts* (and their
metric checksums) are bit-identical too.

A third phase prices the observability layer (ISSUE 10 satellite): the
same staged replay races untraced vs traced (``repro.obs.trace``
enabled, spans flowing at every seam), and a microbench prices the
disabled-tracer probe (``TRACER`` read + ``None`` check) directly.  A
small traced suite run also writes ``TRACE_pipeline.json`` (a
Perfetto-loadable flight recording) and ``METRICS_pipeline.json`` (the
suite metrics snapshot) — the CI benchmark artifacts.

Emits CSV rows plus machine-readable ``BENCH_pipeline.json``.
``--check`` re-reads the JSON and exits non-zero if staged replay
regressed below the synchronous baseline, if enabled tracing costs
more than ``TRACE_ENABLED_BUDGET`` (5%) of replay throughput, or if
the disabled probe prices above ``TRACE_DISABLED_BUDGET`` (0.5%) —
the CI gate.

    PYTHONPATH=src python -m benchmarks.pipeline [--check]
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import time

import numpy as np

from repro.core import (Aggregator, Bag, Message, MessageBus, RosPlay,
                        RosRecord, Scenario, ScenarioSuite)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as otrace

N_MSGS = 4000
PAYLOAD_BYTES = 256
TOPICS = ("/camera", "/lidar")
BATCH = 64
LOGIC_SLEEP_S = 0.003        # simulated perception step, per topic-batch
MONITOR_SLEEP_S = 0.003      # the deliberately slow subscriber, per batch
REPEATS = 3
QUEUE_DEPTH = 8

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir)
JSON_PATH = os.path.join(_ROOT, "BENCH_pipeline.json")
TRACE_PATH = os.path.join(_ROOT, "TRACE_pipeline.json")
METRICS_PATH = os.path.join(_ROOT, "METRICS_pipeline.json")

#: enabled tracing may cost at most this fraction of replay throughput
TRACE_ENABLED_BUDGET = 0.05
#: the disabled probe may cost at most this fraction of replay time
TRACE_DISABLED_BUDGET = 0.005
#: hot-path probes per replayed message (read + lane put/get + logic
#: tick + record + publish checks) — deliberately a high-side estimate
PROBES_PER_MSG = 10


def _make_bag(path: str) -> str:
    rng = np.random.RandomState(7)
    bag = Bag.open_write(path, chunk_bytes=32 * 1024)
    for i in range(N_MSGS):
        bag.write(TOPICS[i % len(TOPICS)], i * 1000 + int(rng.randint(500)),
                  rng.bytes(PAYLOAD_BYTES))
    bag.close()
    return path


def _replay(bag_path: str, staged: bool) -> tuple[float, dict, dict]:
    """One replay through logic + slow monitor + recorder; returns
    (wall_s, per-topic output checksums, delivery counts)."""
    mode = "queued" if staged else "sync"
    bus = MessageBus()
    out = Bag.open_write(backend="memory")
    rec = RosRecord(bus, out, topics=None, exclude_topics=list(TOPICS),
                    batch=True, mode=mode, queue_maxsize=QUEUE_DEPTH)
    counts = {"logic": 0, "monitor": 0}

    def logic(msgs):
        time.sleep(LOGIC_SLEEP_S)               # one model step per batch
        outs = [Message("/det" + m.topic, m.timestamp, m.data[:32])
                for m in msgs]
        bus.publish_batch(outs)
        counts["logic"] += len(msgs)

    def monitor(msgs):
        time.sleep(MONITOR_SLEEP_S)             # the laggard consumer
        counts["monitor"] += len(msgs)

    for t in TOPICS:
        bus.subscribe_batch(t, logic, mode=mode, maxsize=QUEUE_DEPTH,
                            group="logic")
    bus.subscribe_batch(None, monitor, mode=mode, maxsize=QUEUE_DEPTH)
    rec.start()
    src = Bag.open_read(bag_path)
    play = RosPlay(src, bus)
    t0 = time.perf_counter()
    n = play.run_batched(BATCH, prefetch=2 if staged else 0)
    bus.drain()
    rec.stop()
    wall = time.perf_counter() - t0
    bus.close()
    src.close()
    out.close()
    assert n == N_MSGS and rec.messages_recorded == N_MSGS
    metrics = Aggregator().compute_metrics(
        Bag.open_read(backend="memory", image=out.chunked_file.image()))
    return wall, {t: m.checksum for t, m in metrics.items()}, counts


def _best_of_pair(fa, fb, repeats: int = REPEATS):
    """Interleaved best-of (see benchmarks/aggregation.py): alternating
    repeats see the same clock/cache conditions, so drift never lands on
    only one contestant.  Each fn returns ``(wall_s, ...)`` — the wall it
    measured itself, replay-only (setup and the post-hoc checksum pass are
    excluded, so symmetric overhead can't dilute the ratio toward 1)."""
    best_a = best_b = None
    for _ in range(repeats):
        ra = fa()
        if best_a is None or ra[0] < best_a[0]:
            best_a = ra
        rb = fb()
        if best_b is None or rb[0] < best_b[0]:
            best_b = rb
    return best_a, best_b


def _det_logic(msg):
    return ("/det" + msg.topic, msg.data[:16])


def _det_batch_logic(msgs):
    return [("/det" + m.topic, m.timestamp, m.data[:16]) for m in msgs]


def _suite_parity(bag_path: str) -> bool:
    """Run a small suite in sync and staged modes; verdicts and metric
    checksums must be bit-identical."""
    def scenarios(staged: bool):
        return [
            Scenario("per-msg", bag_path, _det_logic, pipeline=staged,
                     latency_model_s=0.0001),
            Scenario("batched", bag_path, _det_batch_logic, batch_size=BATCH,
                     pipeline=staged, latency_model_s=0.0005),
        ]

    def run(staged: bool):
        v = ScenarioSuite(scenarios(staged), num_workers=2).run(timeout=300)
        return {n: (vv.status,
                    {t: m.checksum for t, m in vv.metrics.items()})
                for n, vv in v.items()}

    sync, staged = run(False), run(True)
    assert sync == staged, f"verdict/checksum drift: {sync} vs {staged}"
    return True


def _traced_replay(bag_path: str):
    """The staged replay with the tracer live — every seam emitting."""
    otrace.enable(root_name="bench")
    try:
        return _replay(bag_path, staged=True)
    finally:
        otrace.disable()


def _disabled_probe_ns(n: int = 1_000_000) -> float:
    """Price of ONE disabled-tracer probe (module attr read + ``None``
    check — the exact hot-path idiom), loop overhead subtracted."""
    assert otrace.TRACER is None
    t0 = time.perf_counter()
    for _ in range(n):
        tr = otrace.TRACER
        if tr is not None:
            raise AssertionError
    probed = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(n):
        pass
    empty = time.perf_counter() - t0
    return max(probed - empty, 0.0) * 1e9 / n


def _flight_record(bag_path: str) -> int:
    """One traced suite run writing the CI artifacts: the Perfetto
    flight recording and the suite metrics snapshot.  Returns the span
    count (sanity floor for the gate)."""
    scenarios = [
        Scenario("per-msg", bag_path, _det_logic, pipeline=True,
                 latency_model_s=0.0001),
        Scenario("batched", bag_path, _det_batch_logic, batch_size=BATCH,
                 pipeline=True, latency_model_s=0.0005),
    ]
    ScenarioSuite(scenarios, num_workers=2).run(timeout=300,
                                                trace=TRACE_PATH)
    with open(METRICS_PATH, "w") as f:
        json.dump(obs_metrics.snapshot(), f, indent=2, sort_keys=True)
        f.write("\n")
    with open(TRACE_PATH) as f:
        return sum(1 for e in json.load(f)["traceEvents"]
                   if e.get("ph") == "X")


def run_race() -> dict:
    with tempfile.TemporaryDirectory(prefix="repro-bench-") as d:
        bag_path = _make_bag(os.path.join(d, "drive.bag"))
        # warm both paths (jit-free, but fs cache + thread pools)
        _replay(bag_path, staged=False)
        _replay(bag_path, staged=True)
        (sync_s, sync_sums, sync_counts), \
            (staged_s, staged_sums, staged_counts) = _best_of_pair(
                lambda: _replay(bag_path, staged=False),
                lambda: _replay(bag_path, staged=True))

        # hard acceptance: overlap must not move a byte
        assert sync_sums == staged_sums, "staged replay changed checksums"
        assert sync_counts == staged_counts
        verdicts_identical = _suite_parity(bag_path)

        # observability pricing: untraced vs traced staged replay
        # (interleaved best-of, same discipline as the main race), and
        # tracing must not move a byte either
        (plain_s, plain_sums, _), (traced_s, traced_sums, _) = \
            _best_of_pair(lambda: _replay(bag_path, staged=True),
                          lambda: _traced_replay(bag_path))
        assert plain_sums == traced_sums, "tracing changed checksums"
        probe_ns = _disabled_probe_ns()
        trace_spans = _flight_record(bag_path)

    # overhead fractions the gate prices: enabled = wall inflation of
    # the traced run; disabled = measured probe cost x probes/message
    # over the untraced per-message budget
    enabled_overhead = traced_s / plain_s - 1.0
    disabled_overhead = (probe_ns * PROBES_PER_MSG) \
        / (plain_s * 1e9 / N_MSGS)

    return {
        "bench": "pipeline",
        "messages": N_MSGS, "payload_bytes": PAYLOAD_BYTES,
        "batch_size": BATCH, "queue_depth": QUEUE_DEPTH,
        "logic_sleep_s": LOGIC_SLEEP_S, "monitor_sleep_s": MONITOR_SLEEP_S,
        "sync_wall_s": sync_s, "staged_wall_s": staged_s,
        "sync_msgs_per_s": N_MSGS / sync_s,
        "staged_msgs_per_s": N_MSGS / staged_s,
        "staged_vs_sync_speedup": sync_s / staged_s,
        "checksums_identical": True,
        "suite_verdicts_identical": verdicts_identical,
        "checksums": {t: int(c) for t, c in staged_sums.items()},
        "untraced_wall_s": plain_s, "traced_wall_s": traced_s,
        "trace_enabled_overhead": enabled_overhead,
        "trace_disabled_probe_ns": probe_ns,
        "trace_disabled_overhead": disabled_overhead,
        "trace_checksums_identical": True,
        "trace_spans": trace_spans,
    }


def main(csv: bool = True, json_path: str = JSON_PATH) -> list[tuple]:
    payload = run_race()
    rows = [
        ("pipeline_sync", payload["sync_wall_s"] * 1e6 / N_MSGS,
         f"{payload['sync_msgs_per_s']:.0f} msg/s (slow subscriber inline)"),
        ("pipeline_staged", payload["staged_wall_s"] * 1e6 / N_MSGS,
         f"{payload['staged_msgs_per_s']:.0f} msg/s (read/logic/record "
         "overlap)"),
        ("pipeline_staged_vs_sync_speedup",
         payload["staged_vs_sync_speedup"],
         "checksums + suite verdicts bit-identical"),
        ("pipeline_trace_enabled_overhead",
         payload["trace_enabled_overhead"] * 100,
         f"% wall inflation with spans live ({payload['trace_spans']} "
         "spans in TRACE_pipeline.json)"),
        ("pipeline_trace_disabled_probe",
         payload["trace_disabled_probe_ns"],
         f"ns/probe -> {payload['trace_disabled_overhead'] * 100:.4f}% "
         "of replay at "
         f"{PROBES_PER_MSG} probes/msg"),
    ]
    if csv:
        for name, val, derived in (rows[0], rows[1], rows[3], rows[4]):
            print(f"{name},{val:.2f},{derived}")
        print(f"{rows[2][0]},{rows[2][1]:.2f}x,{rows[2][2]}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    return rows


def check(json_path: str = JSON_PATH) -> int:
    """CI gate: fail (exit 1) when staged replay is slower than the
    synchronous baseline of the same run."""
    with open(json_path) as f:
        payload = json.load(f)
    ratio = payload["staged_vs_sync_speedup"]
    print(f"staged {payload['staged_msgs_per_s']:.0f} msg/s vs sync "
          f"{payload['sync_msgs_per_s']:.0f} msg/s -> {ratio:.2f}x")
    if not payload.get("checksums_identical") \
            or not payload.get("suite_verdicts_identical"):
        print("FAIL: staged replay is not bit-identical to synchronous",
              file=sys.stderr)
        return 1
    if ratio < 1.0:
        print("FAIL: staged replay regressed below the synchronous "
              "baseline", file=sys.stderr)
        return 1
    enabled = payload.get("trace_enabled_overhead")
    disabled = payload.get("trace_disabled_overhead")
    if enabled is not None:
        print(f"tracing: enabled {enabled * 100:+.2f}% wall, disabled "
              f"probe {payload['trace_disabled_probe_ns']:.1f} ns "
              f"({disabled * 100:.4f}% of replay), "
              f"{payload.get('trace_spans', 0)} spans recorded")
        if not payload.get("trace_checksums_identical"):
            print("FAIL: traced replay is not bit-identical to untraced",
                  file=sys.stderr)
            return 1
        if enabled > TRACE_ENABLED_BUDGET:
            print(f"FAIL: enabled tracing costs {enabled * 100:.2f}% "
                  f"(> {TRACE_ENABLED_BUDGET * 100:.0f}%) of replay "
                  "throughput", file=sys.stderr)
            return 1
        if disabled > TRACE_DISABLED_BUDGET:
            print(f"FAIL: disabled-tracer probe costs "
                  f"{disabled * 100:.4f}% "
                  f"(> {TRACE_DISABLED_BUDGET * 100:.1f}%) of replay",
                  file=sys.stderr)
            return 1
        if not payload.get("trace_spans"):
            print("FAIL: traced suite run recorded no spans",
                  file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    if "--check" in sys.argv:
        args = [a for a in sys.argv[1:] if a != "--check"]
        sys.exit(check(args[0] if args else JSON_PATH))
    main()
