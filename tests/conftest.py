"""Shared test fixtures.

The session-scoped autouse fixture below is the data-plane acceptance
trip-wire: zero leaked ``/dev/shm`` segments after every test session,
including injected worker-crash and degraded-suite paths.  Only names
under our ``reproshm-`` prefix count — foreign segments on the host are
not ours to judge.
"""

import pytest

from repro.shm import leaked_segments


@pytest.fixture(autouse=True, scope="session")
def _no_leaked_shm_segments():
    yield
    leaks = leaked_segments()
    assert not leaks, f"test session leaked /dev/shm segments: {leaks}"
