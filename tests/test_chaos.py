"""Chaos engineering + graceful degradation (fault injection PR).

Covers: the seeded ChaosPlan mechanics (glob targets, at/count firing
windows, the fired ledger, per-firing deterministic RNG), the
install/uninstall registry, every injection seam that terminates in the
scenario layer (worker crash tolerated, lane stall tolerated, poison
user logic degrading), the suite's ``on_error="degrade"`` contract —
exactly the poisoned scenarios (plus routing-DAG downstream with cause
lineage) come back ERROR while every survivor stays bit-identical — the
scheduler's quarantine mode and per-task deadlines, and the
ProcessBackend shutdown escalation (a wedged worker cannot hang the
driver's exit).
"""

import json
import multiprocessing
import time

import numpy as np
import pytest

from repro import chaos
from repro.core import Bag, Scenario, ScenarioSuite, Scheduler, WorkerError

TOPICS = ("/camera", "/lidar")


def _make_bag(path, n=240, payload=48, seed=0):
    rng = np.random.RandomState(seed)
    b = Bag.open_write(path, chunk_bytes=4096)
    for i in range(n):
        b.write(TOPICS[i % len(TOPICS)], i * 1000 + int(rng.randint(400)),
                rng.bytes(payload))
    b.close()
    return path


@pytest.fixture
def bag_path(tmp_path):
    return _make_bag(str(tmp_path / "drive.bag"))


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """A test that forgets to uninstall must not poison its neighbours."""
    yield
    chaos.uninstall()


def _logic(msg):
    return ("/det" + msg.topic, msg.data[:8])


def _prov_logic(msg):
    return ("/fused", msg.data[:4])


def _cons_logic(msg):
    return ("/score", bytes(reversed(msg.data)))


def _snap(verdicts):
    return {n: (v.status, v.report.output_image,
                {t: m.checksum for t, m in v.metrics.items()})
            for n, v in verdicts.items()}


# -- plan mechanics ---------------------------------------------------------


def test_plan_target_glob_and_firing_window():
    plan = chaos.ChaosPlan([
        chaos.Fault("logic_raise", target="scn-*", at=1, count=2),
    ])
    # at=1, count=2: fires on matching probes 1 and 2, not 0 or 3+
    assert plan.probe("logic_raise", "scn-a") is None
    assert plan.probe("logic_raise", "other") is None   # no match, no burn
    assert plan.probe("logic_raise", "scn-b") is not None
    assert plan.probe("logic_raise", "scn-a") is not None
    assert plan.probe("logic_raise", "scn-a") is None
    assert plan.fired_count("logic_raise") == 2
    assert [f.key for f in plan.fired] == ["scn-b", "scn-a"]


def test_plan_counts_are_per_fault_and_seam_scoped():
    plan = chaos.ChaosPlan([
        chaos.Fault("worker_crash", target="w0", count=1),
        chaos.Fault("lane_stall", target="*", count=1),
    ])
    assert plan.probe("worker_crash", "w1") is None
    assert plan.probe("lane_stall", "logic") is not None
    assert plan.probe("worker_crash", "w0") is not None
    assert plan.probe("worker_crash", "w0") is None     # count exhausted
    assert plan.fired_count() == 2
    assert plan.fired_count("worker_crash") == 1


def test_plan_rng_is_deterministic_per_firing():
    def draws():
        plan = chaos.ChaosPlan(
            [chaos.Fault("wire_corrupt", count=None)], seed=42)
        out = []
        for _ in range(3):
            assert plan.probe("wire_corrupt", "s1") is not None
            out.append(plan.rng("wire_corrupt", "s1").randrange(1 << 30))
        return out
    a, b = draws(), draws()
    assert a == b                       # same seed + history -> same draws
    assert len(set(a)) == 3             # successive firings decorrelate


def test_fault_validation():
    with pytest.raises(ValueError):
        chaos.Fault("nonsense_seam")
    with pytest.raises(ValueError):
        chaos.Fault("lane_stall", at=-1)
    with pytest.raises(ValueError):
        chaos.Fault("lane_stall", count=0)


def test_install_registry():
    assert chaos.active_plan() is None
    assert chaos.probe("logic_raise", "x") is None      # no plan: never fires
    plan = chaos.ChaosPlan([chaos.Fault("logic_raise", count=None)])
    chaos.install(plan)
    assert chaos.active_plan() is plan
    assert chaos.probe("logic_raise", "x") is not None
    chaos.uninstall()
    assert chaos.active_plan() is None


# -- suite degradation (the tentpole contract) ------------------------------


def _suite(bag, **kw):
    kw.setdefault("num_workers", 3)
    kw.setdefault("on_error", "degrade")
    kw.setdefault("scheduler_kwargs", {"max_attempts": 2})
    return ScenarioSuite([
        Scenario("clean-a", bag, _logic),
        Scenario("victim", bag, _logic),
        Scenario("clean-b", bag, _logic, drop_rate=0.25, seed=9),
    ], **kw)


def test_degrade_exact_error_set_and_bit_identical_survivors(bag_path,
                                                             tmp_path):
    clean = _snap(_suite(bag_path).run(timeout=60))
    assert all(s[0] == "PASS" for s in clean.values())

    chaos.install(chaos.ChaosPlan(
        [chaos.Fault("logic_raise", target="victim", count=None)], seed=1))
    log = str(tmp_path / "verdicts.jsonl")
    try:
        verdicts = _suite(bag_path).run(timeout=60, verdict_log=log)
    finally:
        chaos.uninstall()

    assert verdicts["victim"].status == "ERROR"
    assert not verdicts["victim"].passed          # ERROR is falsy like FAIL
    assert "injected user-logic failure" in verdicts["victim"].error
    hurt = _snap(verdicts)
    for name in ("clean-a", "clean-b"):           # survivors untouched
        assert hurt[name] == clean[name]

    # the failure model is persisted: JSONL row + manifest status
    recs = {json.loads(l)["scenario"]: json.loads(l) for l in open(log)}
    assert recs["victim"]["status"] == "ERROR"
    assert "injected user-logic failure" in recs["victim"]["error"]
    assert recs["clean-a"]["error"] is None
    man = json.load(open(log + ".manifest.json"))
    assert man["scenarios"]["victim"]["status"] == "ERROR"
    assert man["passed"] is False


def test_degrade_cascades_through_routing_dag(bag_path):
    scns = [
        Scenario("provider", bag_path, _prov_logic, exports=("/fused",)),
        Scenario("downstream", bag_path, _cons_logic, imports=("/fused",)),
        Scenario("bystander", bag_path, _logic),
    ]
    chaos.install(chaos.ChaosPlan(
        [chaos.Fault("logic_raise", target="provider", count=None)], seed=2))
    try:
        v = ScenarioSuite(scns, num_workers=3, on_error="degrade",
                          scheduler_kwargs={"max_attempts": 2},
                          ).run(timeout=60)
    finally:
        chaos.uninstall()
    assert v["provider"].status == "ERROR"
    assert v["downstream"].status == "ERROR"
    assert "upstream scenario 'provider' errored" in v["downstream"].error
    assert "injected user-logic failure" in v["downstream"].error
    assert v["bystander"].status == "PASS"


def test_on_error_raise_keeps_historical_semantics(bag_path):
    chaos.install(chaos.ChaosPlan(
        [chaos.Fault("logic_raise", target="victim", count=None)], seed=1))
    try:
        with pytest.raises(WorkerError):
            _suite(bag_path, on_error="raise").run(timeout=60)
    finally:
        chaos.uninstall()


def test_on_error_validated():
    with pytest.raises(ValueError):
        ScenarioSuite([], on_error="explode")


def test_worker_crash_is_tolerated_not_degraded(bag_path):
    """An injected node loss is the scheduler's bread and butter: the task
    is recomputed elsewhere and every verdict stays green."""
    clean = _snap(_suite(bag_path).run(timeout=60))
    chaos.install(chaos.ChaosPlan(
        [chaos.Fault("worker_crash", target="w0", count=1)], seed=3))
    try:
        suite = _suite(bag_path,
                       scheduler_kwargs={"max_attempts": 3,
                                         "heartbeat_timeout": 0.3})
        verdicts = suite.run(timeout=60)
        plan = chaos.active_plan()
        assert plan.fired_count("worker_crash") == 1
    finally:
        chaos.uninstall()
    assert _snap(verdicts) == clean
    assert verdicts["clean-a"].report.scheduler_stats["worker_deaths"] >= 1


def test_lane_stall_slows_but_never_moves_a_byte(bag_path):
    # staged (queued-lane) replay: the sync shape has no lanes to stall
    def suite():
        return ScenarioSuite(
            [Scenario("piped", bag_path, _logic, pipeline=True),
             Scenario("piped-drop", bag_path, _logic, pipeline=True,
                      drop_rate=0.25, seed=9)],
            num_workers=2, on_error="degrade",
            scheduler_kwargs={"max_attempts": 2})

    clean = _snap(suite().run(timeout=60))
    chaos.install(chaos.ChaosPlan(
        [chaos.Fault("lane_stall", target="*", at=0, count=30,
                     param=0.001)], seed=4))
    try:
        verdicts = suite().run(timeout=120)
        assert chaos.active_plan().fired_count("lane_stall") > 0
    finally:
        chaos.uninstall()
    assert _snap(verdicts) == clean


# -- scheduler quarantine + deadlines ---------------------------------------


def test_quarantine_surrenders_poison_keeps_job():
    def poison():
        raise ValueError("always fails")

    failed = []
    with Scheduler(num_workers=2, max_attempts=2, speculation=False,
                   quarantine=True) as s:
        bad = s.submit(poison)
        good = [s.submit(lambda x: x * 2, i) for i in range(10)]
        res = s.run(timeout=30,
                    on_task_failed=lambda tid, e: failed.append((tid, e)))
    assert sorted(res.keys()) == sorted(good)         # job completed
    assert [tid for tid, _ in failed] == [bad]
    assert "always fails" in str(failed[0][1])
    assert s.stats["tasks_failed"] == 1


def test_deadline_retries_wedged_attempt():
    state = {"n": 0}

    def wedged_once(x):
        state["n"] += 1
        if state["n"] == 1:
            time.sleep(1.5)           # first attempt blows the deadline
        return x

    with Scheduler(num_workers=2, speculation=False,
                   task_deadline_s=0.3) as s:
        s.submit(wedged_once, 5)
        res = s.run(timeout=30)
    assert list(res.values()) == [5]
    assert s.stats["deadline_retries"] >= 1


def test_deadline_plus_quarantine_degrades_forever_wedged_task():
    def forever(x):
        time.sleep(30)
        return x

    failed = []
    t0 = time.monotonic()
    with Scheduler(num_workers=2, max_attempts=2, speculation=False,
                   quarantine=True, task_deadline_s=0.2) as s:
        s.submit(forever, 1)
        ok = s.submit(lambda: "fine")
        res = s.run(timeout=30,
                    on_task_failed=lambda tid, e: failed.append(str(e)))
    assert res[ok] == "fine"
    assert len(failed) == 1 and "deadline" in failed[0]
    # the driver loop converged on deadline sweeps, long before the 30 s
    # sleeps would have unwound
    assert time.monotonic() - t0 < 20


# -- ProcessBackend shutdown escalation -------------------------------------


def _stuck_task():
    time.sleep(60)


def test_process_shutdown_escalates_on_wedged_worker():
    """A worker wedged inside user code ignores the sentinel; shutdown must
    escalate (terminate, then kill) and return promptly instead of hanging
    the driver for the full join timeout x workers."""
    from repro.core import ProcessBackend

    be = ProcessBackend()
    s = Scheduler(num_workers=2, backend=be, speculation=False)
    try:
        s.submit(_stuck_task)
        s.submit(_stuck_task)
        time.sleep(1.0)               # let both workers enter the sleep
    finally:
        t0 = time.monotonic()
        s.shutdown()
        wall = time.monotonic() - t0
    assert wall < 15.0, f"shutdown took {wall:.1f}s"
    deadline = time.monotonic() + 5.0
    while multiprocessing.active_children() and time.monotonic() < deadline:
        time.sleep(0.05)
    assert not multiprocessing.active_children()


# -- shm data-plane crash safety --------------------------------------------


def test_worker_crash_mid_transfer_leaves_no_shm_orphans(bag_path):
    """A worker killed while shm result-spill segments are in flight
    cannot leak /dev/shm past the backend's shutdown sweep — the chaos
    seam of the zero-copy data plane's crash-safety contract."""
    from repro.core import ProcessBackend
    from repro.shm import leaked_segments, shm_available
    if not shm_available():
        pytest.skip("no usable POSIX shared memory here")
    backend = ProcessBackend(spill_bytes=512)
    chaos.install(chaos.ChaosPlan(
        [chaos.Fault("worker_crash", target="w0", count=1)], seed=7))
    try:
        v = ScenarioSuite(
            [Scenario("a", bag_path, "tests.test_chaos:_logic"),
             Scenario("b", bag_path, "tests.test_chaos:_logic",
                      drop_rate=0.25, seed=9)],
            num_workers=2, backend=backend,
            # the crashed process is caught immediately via is_alive();
            # a short beat window would misread a starved-but-healthy
            # sibling as dead under loaded single-core CI
            scheduler_kwargs={"max_attempts": 3,
                              "heartbeat_timeout": 30.0}).run(timeout=120)
    finally:
        chaos.uninstall()
    assert all(vv.passed for vv in v.values())
    # the fork inherited the plan, so the firing ledger lives (and dies)
    # in the crashed child; the driver sees the death itself
    assert v["a"].report.scheduler_stats["worker_deaths"] >= 1
    assert backend.spill_leaks() == []
    assert leaked_segments() == []


def test_degrade_reclaims_shm_spills_like_files(bag_path):
    """``on_error="degrade"`` reclaims shm arg-spills on the error path
    exactly like temp files: every spilled SegmentHandle is released and
    nothing survives shutdown."""
    from repro.core import ProcessBackend
    from repro.shm import SegmentHandle, leaked_segments, shm_available
    if not shm_available():
        pytest.skip("no usable POSIX shared memory here")
    backend = ProcessBackend(spill_bytes=512)
    spilled, reclaimed = [], []
    orig_spill, orig_reclaim = backend.spill_arg, backend.reclaim_spill

    def spill_arg(data):
        ref = orig_spill(data)
        spilled.append(ref)
        return ref

    def reclaim_spill(ref):
        reclaimed.append(ref)
        orig_reclaim(ref)

    backend.spill_arg = spill_arg
    backend.reclaim_spill = reclaim_spill
    chaos.install(chaos.ChaosPlan(
        [chaos.Fault("logic_raise", target="victim", count=None)], seed=5))
    try:
        v = ScenarioSuite(
            [Scenario("victim", bag_path, "tests.test_chaos:_logic"),
             Scenario("clean", bag_path, "tests.test_chaos:_logic")],
            num_workers=2, backend=backend, on_error="degrade",
            scheduler_kwargs={"max_attempts": 2,
                              "heartbeat_timeout": 30.0}).run(timeout=120)
    finally:
        chaos.uninstall()
    assert v["victim"].status == "ERROR"
    assert v["clean"].status == "PASS"
    assert spilled, "expected shm arg spills with a 512-byte threshold"
    assert all(isinstance(r, SegmentHandle) for r in spilled)
    key = lambda h: (h.name, h.generation)  # noqa: E731
    assert sorted(reclaimed, key=key) == sorted(spilled, key=key)
    assert backend.spill_leaks() == []
    assert leaked_segments() == []
