"""Scenario engine tests: ScenarioSuite over heterogeneous scenarios on both
executor backends, batched vs per-message replay equivalence, batch bus
semantics, fault/latency profiles, logic refs, and the MemoryChunkedFile
image-after-close regression.

User-logic functions are module-level so they cross the process-backend
pickle boundary.
"""

import numpy as np
import pytest

from repro.core import (Bag, DistributedSimulation, MemoryChunkedFile,
                        Message, MessageBus, RosPlay, Scenario, ScenarioSuite,
                        resolve_logic_ref)

TOPICS = ("/camera", "/lidar", "/imu")


def _make_bag(path, n=600, topics=TOPICS):
    b = Bag.open_write(path, chunk_bytes=4096)
    rng = np.random.RandomState(0)
    # round-robin topics with jittered timestamps so time order != write order
    for i in range(n):
        t = topics[i % len(topics)]
        ts = i * 1000 + int(rng.randint(0, 500))
        b.write(t, ts, bytes([i % 256]) * 64)
    b.close()
    return path


def det_logic(msg):
    return ("/det" + msg.topic, msg.data[:4])


def det_batch_logic(msgs):
    return [("/det" + m.topic, m.timestamp, m.data[:4]) for m in msgs]


@pytest.fixture
def bag_path(tmp_path):
    return _make_bag(str(tmp_path / "drive.bag"))


# -- ScenarioSuite ----------------------------------------------------------


@pytest.mark.parametrize("backend", ["thread", "process"])
def test_suite_heterogeneous_scenarios_one_scheduler(bag_path, backend):
    """Acceptance: >= 3 heterogeneous scenarios (topic filter / time window /
    latency+batched) through one Scheduler call, both backends, per-scenario
    verdicts wrapping full reports."""
    suite = ScenarioSuite([
        Scenario("cam-only", bag_path, det_logic, topics=("/camera",)),
        Scenario("window", bag_path, det_logic, start=100_000, end=300_000),
        Scenario("batched-latency", bag_path, det_batch_logic,
                 batch_size=64, latency_model_s=0.0005),
    ], num_workers=3, backend=backend)
    verdicts = suite.run(timeout=120)
    assert set(verdicts) == {"cam-only", "window", "batched-latency"}

    cam = verdicts["cam-only"].report
    assert cam.messages_in == 200          # 600 msgs round-robin 3 topics
    assert cam.messages_out == 200
    src = Bag.open_read(bag_path)
    in_window = sum(1 for m in src.read_messages(start=100_000, end=300_000))
    src.close()
    assert verdicts["window"].report.messages_in == in_window > 0
    batched = verdicts["batched-latency"].report
    assert batched.messages_in == 600 == batched.messages_out
    assert batched.batch_size == 64
    for v in verdicts.values():
        assert v.passed and not v.vacuous      # no goldens -> plain PASS
        r = v.report
        assert r.backend == backend
        assert r.wall_time_s > 0
        assert r.partitions >= 1
        assert r.output_image is not None
        # replay partitions + the scenario's scheduled aggregation task
        assert r.scheduler_stats["tasks_done"] >= r.partitions + 1
        assert sum(m.count for m in r.metrics.values()) == r.messages_out


def test_suite_rejects_duplicate_names(bag_path):
    with pytest.raises(ValueError):
        ScenarioSuite([Scenario("a", bag_path, det_logic),
                       Scenario("a", bag_path, det_logic)])


def test_suite_merged_output_replayable(bag_path):
    rep = ScenarioSuite([Scenario("all", bag_path, det_logic)],
                        num_workers=2).run()["all"].report
    out = rep.open_output_bag()
    total = 0
    last = -1
    for m in out.read_messages():
        assert m.topic.startswith("/det/")
        assert m.timestamp >= last          # merged bag is time-ordered
        last = m.timestamp
        total += 1
    assert total == 600


def test_partition_images_are_not_retained(bag_path):
    """The seed-era per-partition image list (and its deprecated
    ``output_images`` accessor) is gone: the driver keeps exactly one
    merged image per scenario, and it is complete."""
    rep = ScenarioSuite([Scenario("all", bag_path, det_logic)],
                        num_workers=2).run()["all"].report
    assert not hasattr(rep, "partition_images")
    assert not hasattr(rep, "output_images")
    assert rep.open_output_bag().num_messages == 600


def test_drop_rate_fault_profile(bag_path):
    verdicts = ScenarioSuite([
        Scenario("all-dropped", bag_path, det_logic, drop_rate=1.0),
        Scenario("half-dropped", bag_path, det_logic, drop_rate=0.5, seed=3),
    ], num_workers=2).run()
    assert verdicts["all-dropped"].report.messages_dropped == 600
    assert verdicts["all-dropped"].report.messages_out == 0
    half = verdicts["half-dropped"].report
    assert half.messages_dropped + half.messages_out == 600
    assert 150 < half.messages_dropped < 450       # ~Binomial(600, .5)


def test_drop_rate_deterministic(bag_path):
    r1 = ScenarioSuite([Scenario("d", bag_path, det_logic, drop_rate=0.3,
                                 seed=11)], num_workers=2).run()
    r2 = ScenarioSuite([Scenario("d", bag_path, det_logic, drop_rate=0.3,
                                 seed=11)], num_workers=2).run()
    assert (r1["d"].report.messages_dropped
            == r2["d"].report.messages_dropped)


def test_batched_equals_per_message_outputs(bag_path):
    """The vectorized replay path must produce the same output set as the
    per-message path — batching is an optimisation, not a semantic change."""
    verdicts = ScenarioSuite([
        Scenario("permsg", bag_path, det_logic),
        Scenario("batched", bag_path, det_batch_logic, batch_size=32),
    ], num_workers=2).run()

    def outputs(rep):
        return sorted((m.topic, m.timestamp, m.data)
                      for m in rep.open_output_bag().read_messages())

    assert (outputs(verdicts["permsg"].report)
            == outputs(verdicts["batched"].report))
    # and the aggregation checksums agree without any message pairing
    pm = verdicts["permsg"].report.metrics
    bm = verdicts["batched"].report.metrics
    assert {t: m.checksum for t, m in pm.items()} \
        == {t: m.checksum for t, m in bm.items()}


def test_logic_ref_resolution(bag_path):
    assert resolve_logic_ref(det_logic) is det_logic
    assert resolve_logic_ref(f"{__name__}:det_logic") is det_logic
    with pytest.raises(ValueError):
        resolve_logic_ref("no_colon_ref")
    rep = DistributedSimulation(bag_path, f"{__name__}:det_logic",
                                num_workers=2).run()
    assert rep.messages_out == 600


def test_distributed_simulation_is_thin_suite_wrapper(bag_path):
    rep = DistributedSimulation(bag_path, det_logic, num_workers=4).run()
    assert rep.messages_in == 600 == rep.messages_out
    assert rep.partitions == 4
    assert rep.scenario == "sim"
    assert rep.backend == "thread"


def test_distributed_simulation_batched_process_backend(bag_path):
    rep = DistributedSimulation(
        bag_path, f"{__name__}:det_batch_logic", num_workers=2,
        batch_size=50, backend="process").run(timeout=120)
    assert rep.messages_in == 600 == rep.messages_out
    assert rep.backend == "process"


def test_suite_fault_injection_hook(bag_path):
    """on_scheduler lets harnesses kill/add workers mid-suite; lineage-based
    recompute must still deliver every message."""
    def chaos(sched):
        sched.kill_worker("w0")
        sched.add_worker("elastic")

    verdicts = ScenarioSuite(
        [Scenario("all", bag_path, det_logic, num_partitions=6)],
        num_workers=2, scheduler_kwargs={"heartbeat_timeout": 0.3},
        on_scheduler=chaos).run(timeout=120)
    assert verdicts["all"].report.messages_in == 600


# -- fleet sharding ---------------------------------------------------------


def _make_fleet(tmp_path, n_shards=3, n=150):
    """Shard bags with interleaved timestamp ranges, so a correct merge
    must actually interleave across shards (not just concatenate)."""
    paths = []
    for s in range(n_shards):
        p = str(tmp_path / f"shard{s}.bag")
        b = Bag.open_write(p, chunk_bytes=2048)
        for i in range(n):
            b.write("/camera" if i % 2 else "/lidar",
                    i * 10_000 + s * 37, bytes([(s * n + i) % 256]) * 32)
        b.close()
        paths.append(p)
    return paths


@pytest.mark.parametrize("backend", ["thread", "process"])
def test_multi_shard_scenario_merges_time_ordered(tmp_path, backend):
    """Acceptance: a >= 3-bag fleet scenario merges every shard's outputs
    into ONE timestamp-ordered bag, on both backends."""
    shards = _make_fleet(tmp_path, n_shards=3, n=150)
    logic = f"{__name__}:det_logic"
    v = ScenarioSuite(
        [Scenario("fleet", bag_paths=shards, user_logic=logic,
                  num_partitions=2)],
        num_workers=2, backend=backend).run(timeout=120)["fleet"]
    rep = v.report
    assert rep.shards == 3
    assert rep.partitions == 6                   # 2 per shard
    assert rep.messages_in == 450 == rep.messages_out
    stamps = [m.timestamp for m in rep.open_output_bag().read_messages()]
    assert len(stamps) == 450
    assert stamps == sorted(stamps)
    # outputs from every shard are present (payload bytes are shard-coded)
    seen = {m.data[0] for m in rep.open_output_bag().read_messages()}
    assert seen & set(range(0, 150)) and seen & set(range(150, 256))


def test_scenario_requires_exactly_one_bag_source(bag_path):
    with pytest.raises(ValueError):
        Scenario("both", bag_path=bag_path, bag_paths=(bag_path,),
                 user_logic=det_logic)
    with pytest.raises(ValueError):
        Scenario("neither", user_logic=det_logic)
    with pytest.raises(ValueError):
        Scenario("no-logic", bag_path=bag_path)
    fleet = Scenario("list-ok", bag_paths=[bag_path], user_logic=det_logic)
    assert fleet.bag_paths == (bag_path,)        # normalized to tuple
    assert fleet.shard_paths == (bag_path,)


# -- scheduled aggregation --------------------------------------------------


def slow_logic(msg):
    import time
    time.sleep(0.002)
    return ("/det" + msg.topic, msg.data[:4])


def test_aggregation_tasks_overlap_replay(bag_path):
    """Acceptance (ISSUE 3): per-scenario aggregation runs as ordinary
    scheduler tasks, so a finished scenario's merge+metrics start while
    other scenarios' replay tasks are still in flight — not serially on
    the driver after the drain."""
    grabbed = {}
    suite = ScenarioSuite([
        Scenario("fast", bag_path, det_logic, num_partitions=2),
        Scenario("slow", bag_path, f"{__name__}:slow_logic",
                 num_partitions=4),
    ], num_workers=3, on_scheduler=lambda s: grabbed.update(sched=s))
    verdicts = suite.run(timeout=120)
    assert all(v.passed for v in verdicts.values())

    sched = grabbed["sched"]
    agg_tasks = [t for t in sched._tasks.values()
                 if t.lineage[:1] == ("aggregate",)]
    replay_tasks = [t for t in sched._tasks.values()
                    if t.lineage[:1] == ("scenario",)]
    assert len(agg_tasks) == 2          # one per scenario, on the pool
    assert all(t.finished_at is not None for t in agg_tasks)
    first_agg_start = min(min(t.started_at.values()) for t in agg_tasks)
    last_replay_end = max(t.finished_at for t in replay_tasks)
    assert first_agg_start < last_replay_end, \
        "aggregation did not overlap in-flight replay work"
    # aggregation results were consumed and released by the driver
    assert all(t.result is None for t in agg_tasks)


def test_aggregate_stage_has_own_speculation_bucket(bag_path):
    """Aggregate tasks carry lineage ("aggregate", scenario): their
    durations must not pollute the replay stage's straggler medians."""
    grabbed = {}
    ScenarioSuite([Scenario("s", bag_path, det_logic, num_partitions=3)],
                  num_workers=2,
                  on_scheduler=lambda s: grabbed.update(sched=s)).run()
    sched = grabbed["sched"]
    keys = set(sched._done_durations)
    assert ("scenario", "s") in keys
    assert ("aggregate", "s") in keys


def test_process_backend_downgrades_jax_engine_aggregator(bag_path, tmp_path):
    """A jax-engine Aggregator must not be forked into process workers
    (jax init in a forked child of a jax-loaded driver can deadlock);
    the suite ships a bit-identical numpy-engine copy instead."""
    from repro.core import Aggregator
    golden = str(tmp_path / "g.bag")
    clean = ScenarioSuite([Scenario("s", bag_path, det_logic)],
                          num_workers=2).run()["s"]
    with open(golden, "wb") as f:
        f.write(clean.report.output_image)
    v = ScenarioSuite([Scenario("s", bag_path, det_logic,
                                golden_bag_path=golden)],
                      num_workers=2, backend="process",
                      aggregator=Aggregator(engine="jax")).run(
                          timeout=90)["s"]
    assert v.passed and v.status == "PASS"


def test_process_backend_spills_large_results(bag_path):
    """Partition bag images above the spill threshold ride a temp file,
    not the result pipe — and the suite's outputs are unchanged."""
    from repro.core import ProcessBackend
    backend = ProcessBackend(spill_bytes=1024)    # every image spills
    v = ScenarioSuite([Scenario("all", bag_path, det_logic)],
                      num_workers=2, backend=backend).run(timeout=120)["all"]
    assert v.passed
    assert v.report.messages_out == 600
    assert v.report.open_output_bag().num_messages == 600
    assert backend.spills >= 1


# -- empty-selection scenarios ----------------------------------------------


@pytest.mark.parametrize("kw", [
    {"topics": ("/absent",)},
    {"start": 10**15, "end": 2 * 10**15},
    {"end": -1},
])
def test_empty_selection_yields_vacuous_pass(bag_path, kw):
    """Regression: a topic filter / time window matching zero messages must
    produce a clean zero-message report and a PASS-vacuous verdict — no
    degenerate partition plan, no tasks."""
    v = ScenarioSuite([Scenario("empty", bag_path, det_logic, **kw)],
                      num_workers=2).run()["empty"]
    assert v.passed and v.vacuous
    assert v.status == "PASS(vacuous)"
    rep = v.report
    assert rep.partitions == 0
    assert rep.messages_in == 0 == rep.messages_out
    assert rep.metrics == {}
    assert rep.open_output_bag().num_messages == 0
    assert rep.scheduler_stats["tasks_done"] == 0


def test_empty_selection_fails_against_nonempty_golden(bag_path, tmp_path):
    """An empty selection is only vacuously green when nothing was
    expected: a golden bag that demands output must flip it to FAIL."""
    golden = str(tmp_path / "golden.bag")
    b = Bag.open_write(golden)
    b.write("/det/camera", 1, b"x")
    b.close()
    v = ScenarioSuite([Scenario("empty", bag_path, det_logic,
                                topics=("/absent",),
                                golden_bag_path=golden)]).run()["empty"]
    assert not v.passed and not v.vacuous
    assert any(d.detail == "topic missing from output" for d in v.diffs)


# -- batched bus / playback semantics ---------------------------------------


def test_publish_batch_per_topic_grouping_and_fallback(bag_path):
    bus = MessageBus()
    per_msg, batches, mixed = [], [], []
    bus.subscribe("/camera", per_msg.append)
    bus.subscribe_batch("/camera", batches.append)
    bus.subscribe_batch(None, mixed.append)
    msgs = [Message("/camera", 1, b"a"), Message("/lidar", 2, b"b"),
            Message("/camera", 3, b"c")]
    n = bus.publish_batch(msgs)
    assert n == 3 and bus.published == 3
    # per-message subscribers see each message individually
    assert [m.timestamp for m in per_msg] == [1, 3]
    # per-topic batch subscribers get the batch split by topic
    assert len(batches) == 1
    assert [m.timestamp for m in batches[0]] == [1, 3]
    # all-topic batch subscribers get the whole mixed batch
    assert len(mixed) == 1 and len(mixed[0]) == 3


def test_run_batched_is_time_ordered_and_complete(bag_path):
    bus = MessageBus()
    seen = []
    for t in TOPICS:
        bus.subscribe_batch(t, seen.extend)
    n = RosPlay(Bag.open_read(bag_path), bus).run_batched(37)
    assert n == 600 == len(seen)
    # per-topic groups of each micro-batch preserve global time order
    # within a topic
    by_topic = {}
    for m in seen:
        by_topic.setdefault(m.topic, []).append(m.timestamp)
    for ts in by_topic.values():
        assert ts == sorted(ts)


def test_run_batched_mixed_order(bag_path):
    bus = MessageBus()
    stamps = []
    bus.subscribe_batch(None, lambda b: stamps.extend(m.timestamp for m in b))
    RosPlay(Bag.open_read(bag_path), bus).run_batched(64)
    assert stamps == sorted(stamps)       # global timestamp order


def test_rosplay_time_window(bag_path):
    bus = MessageBus()
    stamps = []
    bus.subscribe(None, lambda m: stamps.append(m.timestamp))
    RosPlay(Bag.open_read(bag_path), bus, start=100_000, end=300_000).run()
    assert stamps and all(100_000 <= t < 300_000 for t in stamps)


# -- MemoryChunkedFile close-safety regression ------------------------------


def test_memory_bag_image_after_close_regression():
    """_run_partition reads the output image after out_bag.close(); the image
    must be captured at close time and stay identical afterwards."""
    bag = Bag.open_write(backend="memory", chunk_bytes=512)
    for i in range(100):
        bag.write("/t", i, bytes([i]) * 40)
    bag.close()
    img1 = bag.chunked_file.image()
    img2 = bag.chunked_file.image()
    assert img1 == img2
    rb = Bag.open_read(backend="memory", image=img1)
    assert rb.num_messages == 100
    assert [m.timestamp for m in rb.read_messages()] == list(range(100))


def test_memory_bag_write_after_close_raises():
    cf = MemoryChunkedFile()
    cf.write_chunk(b"payload", 1)
    cf.close()
    with pytest.raises(RuntimeError):
        cf.write_chunk(b"more", 1)
    with pytest.raises(RuntimeError):
        cf.write_blob(b"blob")
    cf.close()                             # idempotent
