"""Cross-partition topic routing through the distributed message pool
(ISSUE 5): Scenario.exports/imports, wire-vs-inline carrier parity, chained
DAGs, routing validation, and spill-file lifecycle.
"""

import os
import threading

import numpy as np
import pytest

from repro.core import Bag, ProcessBackend, Scenario, ScenarioSuite

TOPICS = ("/camera", "/lidar")


def _make_bag(path, n=240, payload=64, seed=0):
    rng = np.random.RandomState(seed)
    b = Bag.open_write(path, chunk_bytes=4096)
    for i in range(n):
        b.write(TOPICS[i % len(TOPICS)], i * 1000 + int(rng.randint(400)),
                rng.bytes(payload))
    b.close()
    return path


def prov_logic(msg):
    return ("/det" + msg.topic, msg.data[:16])


def cons_logic(msg):
    return ("/score", bytes(reversed(msg.data)))


def relay_logic(msg):
    return ("/final", msg.data[:8])


def big_logic(msg):
    return ("/bulk", msg.data * 64)


def boom_logic(msg):
    raise RuntimeError("consumer exploded")


@pytest.fixture
def bags(tmp_path):
    return (_make_bag(str(tmp_path / "a.bag"), seed=1),
            _make_bag(str(tmp_path / "b.bag"), seed=2))


def _fingerprint(verdicts):
    return {n: (v.status, v.report.output_image,
                {t: m.checksum for t, m in v.metrics.items()},
                v.report.messages_in, v.report.messages_out)
            for n, v in verdicts.items()}


def _pair(bags, **kw):
    a, b = bags
    return [
        Scenario("prov", a, "tests.test_core_routing:prov_logic",
                 exports=("/det/camera", "/det/lidar"), **kw),
        Scenario("cons", b, "tests.test_core_routing:cons_logic",
                 imports=("/det/camera", "/det/lidar"), **kw),
    ]


# -- carrier / backend parity ------------------------------------------------


def test_routing_inline_deterministic_and_imports_counted(bags):
    v = ScenarioSuite(_pair(bags), num_workers=3,
                      export_transport="inline").run(timeout=120)
    assert v["prov"].passed and v["cons"].passed
    # consumer replayed its own bag plus both exported det topics
    assert v["cons"].report.messages_in == 240 + 240
    assert v["cons"].report.messages_out == 480
    assert set(v["cons"].metrics) == {"/score"}
    # one extra partition: the import replay
    assert v["cons"].report.partitions == v["prov"].report.partitions + 1
    again = ScenarioSuite(_pair(bags), num_workers=3,
                          export_transport="inline").run(timeout=120)
    assert _fingerprint(v) == _fingerprint(again)


def test_routing_wire_matches_inline_thread_backend(bags):
    inline = ScenarioSuite(_pair(bags), num_workers=3,
                           export_transport="inline").run(timeout=120)
    wire = ScenarioSuite(_pair(bags), num_workers=3,
                         export_transport="wire").run(timeout=120)
    assert _fingerprint(inline) == _fingerprint(wire)


def test_routing_wire_matches_inline_process_backend(bags):
    fps = {}
    for carrier in ("inline", "wire"):
        v = ScenarioSuite(_pair(bags), num_workers=2, backend="process",
                          export_transport=carrier).run(timeout=180)
        fps[carrier] = _fingerprint(v)
    assert fps["inline"] == fps["wire"]


def test_routing_parity_with_fault_profiles(bags):
    """Drop RNG + latency + batching: the carrier still may not move a
    byte — import partitions draw the same RNG sequence either way."""
    def scenarios():
        a, b = bags
        return [
            Scenario("prov", a, "tests.test_core_routing:prov_logic",
                     exports=("/det/camera", "/det/lidar"),
                     drop_rate=0.2, seed=7),
            Scenario("cons", b, "tests.test_core_routing:cons_logic",
                     imports=("/det/camera", "/det/lidar"),
                     drop_rate=0.1, seed=9, latency_model_s=0.0001),
        ]
    inline = ScenarioSuite(scenarios(), num_workers=3,
                           export_transport="inline").run(timeout=120)
    wire = ScenarioSuite(scenarios(), num_workers=3,
                         export_transport="wire").run(timeout=120)
    assert _fingerprint(inline) == _fingerprint(wire)
    assert inline["cons"].report.messages_dropped > 0


def test_chained_routing_dag(bags):
    """A -> B -> C: B's import-partition outputs are themselves exported
    downstream, identically on both carriers."""
    a, b = bags

    def scenarios():
        return [
            Scenario("A", a, "tests.test_core_routing:prov_logic",
                     exports=("/det/camera", "/det/lidar")),
            Scenario("B", b, "tests.test_core_routing:cons_logic",
                     imports=("/det/camera", "/det/lidar"),
                     exports=("/score",)),
            Scenario("C", a, "tests.test_core_routing:relay_logic",
                     topics=("/camera",), imports=("/score",)),
        ]
    inline = ScenarioSuite(scenarios(), num_workers=3,
                           export_transport="inline").run(timeout=180)
    wire = ScenarioSuite(scenarios(), num_workers=3,
                         export_transport="wire").run(timeout=180)
    assert _fingerprint(inline) == _fingerprint(wire)
    # C saw its /camera selection (120) plus B's 480 /score messages
    assert inline["C"].report.messages_in == 120 + 480


def test_unconsumed_exports_are_free(bags):
    """Exports nobody imports don't change results or cost a capture."""
    a, b = bags
    with_exports = ScenarioSuite(
        [Scenario("solo", a, "tests.test_core_routing:prov_logic",
                  exports=("/det/camera",))],
        num_workers=2).run(timeout=60)
    without = ScenarioSuite(
        [Scenario("solo", a, "tests.test_core_routing:prov_logic")],
        num_workers=2).run(timeout=60)
    assert _fingerprint(with_exports) == _fingerprint(without)


# -- routing validation ------------------------------------------------------


def test_import_without_exporter_rejected(bags):
    a, _ = bags
    suite = ScenarioSuite(
        [Scenario("x", a, "tests.test_core_routing:cons_logic",
                  imports=("/nope",))])
    with pytest.raises(ValueError, match="no scenario exports"):
        suite.run(timeout=30)


def test_duplicate_exporter_rejected(bags):
    a, b = bags
    suite = ScenarioSuite([
        Scenario("p1", a, "tests.test_core_routing:prov_logic",
                 exports=("/det/camera",)),
        Scenario("p2", b, "tests.test_core_routing:prov_logic",
                 exports=("/det/camera",)),
    ])
    with pytest.raises(ValueError, match="one exporter"):
        suite.run(timeout=30)


def test_routing_cycle_rejected(bags):
    a, b = bags
    suite = ScenarioSuite([
        Scenario("x", a, "tests.test_core_routing:prov_logic",
                 exports=("/t1",), imports=("/t2",)),
        Scenario("y", b, "tests.test_core_routing:prov_logic",
                 exports=("/t2",), imports=("/t1",)),
    ])
    with pytest.raises(ValueError, match="cycle"):
        suite.run(timeout=30)


def test_self_import_and_overlap_rejected(bags):
    a, _ = bags
    with pytest.raises(ValueError, match="both imported and exported"):
        Scenario("x", a, "tests.test_core_routing:prov_logic",
                 exports=("/t",), imports=("/t",))
    suite = ScenarioSuite([
        Scenario("x", a, "tests.test_core_routing:prov_logic",
                 exports=("/t1",), imports=("/t2",)),
        Scenario("y", a, "tests.test_core_routing:prov_logic",
                 exports=("/t2",)),
    ])
    # DAG: fine — now a true self-import via suite must fail at Scenario
    suite.run(timeout=60)


def test_unknown_export_transport_rejected(bags):
    with pytest.raises(ValueError, match="export_transport"):
        ScenarioSuite(_pair(bags), export_transport="carrier-pigeon")


# -- pruned/empty edges ------------------------------------------------------


def test_pruned_exporter_yields_empty_import_stream(bags):
    """A provider whose selection matches nothing still unblocks its
    importers (with an empty stream) instead of deadlocking the suite."""
    a, b = bags
    v = ScenarioSuite([
        Scenario("prov", a, "tests.test_core_routing:prov_logic",
                 topics=("/absent",), exports=("/det/camera",)),
        Scenario("cons", b, "tests.test_core_routing:cons_logic",
                 imports=("/det/camera",)),
    ], num_workers=2).run(timeout=60)
    assert v["prov"].status == "PASS(vacuous)"
    assert v["cons"].passed
    assert v["cons"].report.messages_in == 240       # only its own bag


# -- spill lifecycle ---------------------------------------------------------


def _tracking_backend(spill_bytes=512):
    # shm=False pins the temp-file carrier: these tests assert str paths
    # and os.path.exists; the shm carrier has its own tests/test_shm.py
    backend = ProcessBackend(spill_bytes=spill_bytes, shm=False)
    spilled, reclaimed = [], []
    orig_spill, orig_reclaim = backend.spill_arg, backend.reclaim_spill

    def spill_arg(data):
        path = orig_spill(data)
        spilled.append(path)
        return path

    def reclaim_spill(path):
        reclaimed.append(path)
        orig_reclaim(path)

    backend.spill_arg = spill_arg
    backend.reclaim_spill = reclaim_spill
    return backend, spilled, reclaimed


def test_spills_reclaimed_eagerly_on_suite_completion(bags):
    """Every driver-side spill (partition images for aggregation, import
    streams) is reclaimed by the suite itself — not left to the
    shutdown-time directory reap."""
    backend, spilled, reclaimed = _tracking_backend()
    v = ScenarioSuite(_pair(bags), num_workers=2, backend=backend,
                      export_transport="wire").run(timeout=180)
    assert all(vv.passed for vv in v.values())
    assert spilled, "expected driver-side spills with a 512-byte threshold"
    assert sorted(reclaimed) == sorted(spilled)
    for p in spilled:
        assert not os.path.exists(p)


def test_spills_reclaimed_on_error_path(bags):
    """A suite that fails mid-flight still reclaims what it spilled —
    long CI runs must not grow the temp dir through crashes."""
    from repro.core.scheduler import WorkerError
    a, b = bags
    backend, spilled, reclaimed = _tracking_backend()
    suite = ScenarioSuite([
        Scenario("prov", a, "tests.test_core_routing:big_logic",
                 exports=("/bulk",)),
        # empty bag selection: the only task that runs boom_logic is the
        # import partition, which exists only after prov's stream spilled
        Scenario("cons", b, "tests.test_core_routing:boom_logic",
                 topics=("/absent",), imports=("/bulk",)),
    ], num_workers=2, backend=backend,
        scheduler_kwargs={"max_attempts": 1}, export_transport="wire")
    with pytest.raises(WorkerError):
        suite.run(timeout=180)
    assert spilled, "import stream should have spilled"
    assert sorted(reclaimed) == sorted(spilled)
    for p in spilled:
        assert not os.path.exists(p)


def test_reclaim_spill_roundtrip_and_tolerance():
    backend = ProcessBackend(spill_bytes=64, shm=False)
    path = backend.spill_arg(b"y" * 256)
    assert os.path.exists(path)
    backend.reclaim_spill(path)
    assert not os.path.exists(path)
    backend.reclaim_spill(path)         # second reclaim is a no-op
    backend.shutdown()
