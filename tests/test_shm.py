"""Same-host zero-copy data plane (shm PR): segment pool mechanics,
recycling + generation staleness, the SPSC frame ring, HELLO ring
negotiation, and the ProcessBackend shm spill integration.

Crash-safety under injected faults lives in tests/test_chaos.py; the
session-wide zero-leak assertion lives in tests/conftest.py.
"""

import os

import numpy as np
import pytest

from repro.core import (Bag, Message, MessageBus, ProcessBackend,
                        RosRecord, Scenario, ScenarioSuite, Scheduler)
from repro.net import LaneTransport, RemoteBus
from repro.net.wire import T_DATA, WireError
from repro.shm import (SegmentError, SegmentHandle, SegmentPool,
                       attach_segment, leaked_segments, map_segment,
                       new_prefix, read_segment, shm_available,
                       sweep_segments, unlink_segment, write_segment)
from repro.shm.ring import ShmRing

pytestmark = pytest.mark.skipif(
    not shm_available(), reason="no usable POSIX shared memory here")

TOPICS = ("/camera", "/lidar")


# -- stateless segment helpers ----------------------------------------------


def test_write_read_roundtrip_and_unlink():
    prefix = new_prefix("t")
    handle = write_segment(prefix, b"payload-bytes", generation=7)
    assert isinstance(handle, SegmentHandle)
    assert handle.generation == 7 and handle.size == 13
    assert read_segment(handle) == b"payload-bytes"
    assert leaked_segments(prefix) == [handle.name]
    assert read_segment(handle, unlink=True) == b"payload-bytes"
    assert leaked_segments(prefix) == []
    unlink_segment(handle)                  # idempotent on a gone name


def test_attach_validates_generation_and_absence():
    prefix = new_prefix("t")
    handle = write_segment(prefix, b"x" * 64, generation=3)
    stale = SegmentHandle(handle.name, generation=2, size=64)
    with pytest.raises(SegmentError):
        attach_segment(stale)               # ESTALE: wrong generation
    wrong_len = SegmentHandle(handle.name, generation=3, size=63)
    with pytest.raises(SegmentError):
        attach_segment(wrong_len)
    unlink_segment(handle)
    with pytest.raises(SegmentError):
        attach_segment(handle)              # ENOENT: segment gone


def test_map_segment_is_a_zero_copy_view():
    prefix = new_prefix("t")
    handle = write_segment(prefix, bytes(range(256)))
    with map_segment(handle) as m:
        assert isinstance(m.view, memoryview)
        assert len(m.view) == 256 and m.view[255] == 255
        assert bytes(m.view[:4]) == bytes(range(4))
    unlink_segment(handle)


def test_sweep_refuses_foreign_prefix_and_reaps_ours():
    with pytest.raises(ValueError):
        sweep_segments("psm_")              # not ours to judge
    prefix = new_prefix("t")
    handles = [write_segment(prefix, bytes(16)) for _ in range(3)]
    assert len(leaked_segments(prefix)) == 3
    assert sweep_segments(prefix) == 3
    assert leaked_segments(prefix) == []
    for h in handles:
        with pytest.raises(SegmentError):
            read_segment(h)


# -- segment pool ------------------------------------------------------------


def test_pool_refcounts_and_shutdown():
    pool = SegmentPool()
    solo = pool.put(b"a" * 128)
    shared = pool.put(b"b" * 128, refs=2)
    assert pool.read(shared) == b"b" * 128
    pool.release(shared)
    assert shared in pool.live()            # one ref still out
    pool.release(shared)
    assert shared not in pool.live()
    assert pool.read(solo, release=True) == b"a" * 128
    assert pool.live() == []
    pool.shutdown()
    assert leaked_segments(pool.prefix) == []
    pool.shutdown()                         # idempotent
    with pytest.raises(SegmentError):
        pool.put(b"closed")


def test_pool_recycles_released_segments_with_fresh_generation():
    pool = SegmentPool()
    first = pool.put(b"x" * (2 << 20))
    pool.release(first)
    # the mapping parks on the free-list; same-size re-put reuses it
    second = pool.put(b"y" * (2 << 20))
    assert second.name == first.name
    assert second.generation != first.generation
    assert pool.recycled == 1
    # the stale handle is rejected, the new one reads the new payload
    with pytest.raises(SegmentError):
        read_segment(first)
    assert read_segment(second)[:1] == b"y"
    # a stale double-release must not unlink the live recycled segment
    pool.release(first)
    assert read_segment(second)[:1] == b"y"
    pool.shutdown()
    assert leaked_segments(pool.prefix) == []


def test_pool_does_not_hoard_oversized_segments():
    pool = SegmentPool()
    big = pool.put(b"x" * (2 << 20))
    pool.release(big)
    tiny = pool.put(b"y" * 64)              # 2 MB cap >> 4x payload: no reuse
    assert tiny.name != big.name
    assert pool.recycled == 0
    pool.shutdown()
    assert leaked_segments(pool.prefix) == []


def test_pool_adopts_worker_segments():
    pool = SegmentPool()
    handle = write_segment(pool.prefix, b"worker-made", generation=0)
    pool.adopt(handle)
    assert handle in pool.live()
    pool.release(handle)                    # adopted: unlinked, not parked
    with pytest.raises(SegmentError):
        read_segment(handle)
    pool.shutdown()


def test_pool_shutdown_sweeps_crash_orphans():
    pool = SegmentPool()
    # a worker died with its result segment unreported: nothing adopted
    orphan = write_segment(pool.prefix, b"orphaned-result")
    assert leaked_segments(pool.prefix) == [orphan.name]
    assert pool.shutdown() >= 1
    assert leaked_segments(pool.prefix) == []


# -- SPSC frame ring ---------------------------------------------------------


def test_ring_roundtrip_and_zero_copy_view():
    tx = ShmRing.create()
    rx = ShmRing.attach(tx.name)
    tx.send_frame(T_DATA, b"frame-zero")
    ftype, body = rx.recv_frame()
    assert ftype == T_DATA and isinstance(body, memoryview)
    assert bytes(body) == b"frame-zero"
    tx.close_write()
    assert rx.recv_frame() == (None, b"")   # clean EOF after drain
    rx.close(unlink=False)
    tx.close()
    assert leaked_segments() == []


def test_ring_wraps_without_corrupting_frames():
    tx = ShmRing.create(capacity=1 << 16)
    rx = ShmRing.attach(tx.name)
    for i in range(300):                    # many laps around a 64 KB ring
        payload = bytes([i & 0xFF]) * (900 + (i % 7))
        tx.send_frame(T_DATA, payload)
        ftype, body = rx.recv_frame()
        assert ftype == T_DATA and bytes(body) == payload
    rx.close(unlink=False)
    tx.close()


def test_ring_rejects_oversized_frames():
    tx = ShmRing.create(capacity=1 << 16)
    with pytest.raises(WireError):
        tx.send_frame(T_DATA, b"x" * (1 << 15))   # > capacity/2 - 16
    tx.close()


def test_ring_send_into_closed_ring_raises():
    tx = ShmRing.create()
    rx = ShmRing.attach(tx.name)
    tx.close_write()
    with pytest.raises(OSError):
        tx.send_frame(T_DATA, b"late")
    rx.close(unlink=False)
    tx.close()


# -- HELLO ring negotiation --------------------------------------------------


def _bridged_roundtrip(shm: bool) -> tuple[str, int]:
    rx = MessageBus()
    out = Bag.open_write(backend="memory")
    rec = RosRecord(rx, out, topics=None, batch=True, mode="queued")
    rec.start()
    ep = RemoteBus(bus=rx, window=512)
    addr = ep.start()
    tx = MessageBus()
    transport = LaneTransport.connect(addr, stream_id="t", flush_batch=32,
                                      shm=shm)
    bridge = tx.bridge(list(TOPICS), transport, batch=True)
    rng = np.random.RandomState(3)
    msgs = [Message(TOPICS[i % 2], i * 1000, rng.bytes(96))
            for i in range(400)]
    for lo in range(0, len(msgs), 50):
        tx.publish_batch(msgs[lo:lo + 50])
    tx.drain()
    bridge.drain()
    rec.stop()
    carrier = transport.carrier
    recorded = rec.messages_recorded
    bridge.close()
    ep.stop()
    tx.close()
    rx.close()
    out.close()
    return carrier, recorded


def test_lane_transport_negotiates_shm_carrier():
    carrier, recorded = _bridged_roundtrip(shm=True)
    assert carrier == "shm" and recorded == 400
    assert leaked_segments() == []          # rings reaped on stop


def test_lane_transport_stays_on_wire_when_asked():
    carrier, recorded = _bridged_roundtrip(shm=False)
    assert carrier == "wire" and recorded == 400


# -- ProcessBackend spill integration ----------------------------------------


def _big_result(n):
    return os.urandom(1) * 0 + bytes(n)     # n zero bytes, picklable


def test_process_backend_result_spill_rides_shm():
    with Scheduler(num_workers=2, backend=ProcessBackend(
            spill_bytes=4096), speculation=False) as sched:
        for _ in range(4):
            sched.submit(_big_result, 64 * 1024)
        results = sched.run(timeout=120)
    assert all(len(v) == 64 * 1024 for v in results.values())
    assert sched.stats["shm_spills"] >= 4
    assert sched.stats["shm_spill_bytes"] > 4 * 64 * 1024
    assert sched.backend.spill_leaks() == []


def test_process_backend_arg_spill_returns_handle_and_reclaims():
    backend = ProcessBackend(spill_bytes=1024)
    try:
        ref = backend.spill_arg(b"z" * 8192)
        assert isinstance(ref, SegmentHandle)
        assert read_segment(ref) == b"z" * 8192
        backend.reclaim_spill(ref)
        backend.reclaim_spill(ref)          # double reclaim tolerated
        assert backend.spill_leaks() == []
    finally:
        backend.shutdown()
    assert backend.spill_leaks() == []


def test_shm_disabled_backend_never_touches_dev_shm(tmp_path):
    backend = ProcessBackend(spill_bytes=64, shm=False)
    try:
        ref = backend.spill_arg(b"q" * 256)
        assert isinstance(ref, str) and os.path.exists(ref)
        assert backend.spill_leaks() == [ref]
        backend.reclaim_spill(ref)
        assert backend.spill_leaks() == []
    finally:
        backend.shutdown()


def test_spill_dir_not_created_when_nothing_spills():
    backend = ProcessBackend(spill_bytes=1 << 30, shm=False)
    try:
        with Scheduler(num_workers=1, backend=backend,
                       speculation=False) as sched:
            sched.submit(_big_result, 128)
            sched.run(timeout=60)
    finally:
        backend.shutdown()                  # second shutdown: idempotent
    assert backend.spill_leaks() == []


def test_suite_on_process_backend_prefers_shm_transport(tmp_path):
    rng = np.random.RandomState(5)
    bag = Bag.open_write(str(tmp_path / "a.bag"), chunk_bytes=4096)
    for i in range(240):
        bag.write(TOPICS[i % 2], i * 1000, rng.bytes(64))
    bag.close()
    verdicts = ScenarioSuite(
        [Scenario("prov", str(tmp_path / "a.bag"),
                  "tests.test_shm:_prov_logic", exports=("/det/camera",)),
         Scenario("cons", str(tmp_path / "a.bag"),
                  "tests.test_shm:_cons_logic", imports=("/det/camera",))],
        num_workers=2, backend="process", export_transport="auto",
        # jit warm-up in freshly forked workers can hold the GIL past the
        # default beat window on a loaded single-core box; crashes are
        # still caught immediately via is_alive()
        scheduler_kwargs={"heartbeat_timeout": 30.0}).run(timeout=300)
    assert all(v.passed for v in verdicts.values())
    assert verdicts["prov"].transport == "shm"
    assert leaked_segments() == []


def _prov_logic(msg):
    if msg.topic == "/camera":
        return ("/det/camera", msg.data[:16])
    return None


def _cons_logic(msg):
    return ("/score", bytes(reversed(msg.data)))
