"""Unit tests for the numeric building blocks: chunked attention vs naive
softmax, MoE dispatch vs dense oracle, SSM scan vs recurrence, M-RoPE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_config
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.layers import apply_mrope, apply_rope, init_table


def naive_attention(q, k, v, causal=True, window=0):
    B, Sq, H, hd = q.shape
    _, Sk, KV, vd = v.shape
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                   k.astype(jnp.float32)) * hd ** -0.5
    qpos = jnp.arange(Sq) + (Sk - Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None] <= qpos[:, None]
    if window:
        mask &= kpos[None] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", w, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, vd)


@pytest.mark.parametrize("Sq,Sk,H,KV,window,chunk", [
    (16, 16, 4, 4, 0, 8),
    (32, 32, 8, 2, 0, 8),
    (32, 32, 4, 1, 12, 16),
    (8, 24, 4, 2, 0, 7),       # cross-size + non-divisible chunk
    (33, 33, 4, 2, 0, 8),      # ragged
])
def test_chunked_attention_matches_naive(Sq, Sk, H, KV, window, chunk):
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    B, hd = 2, 16
    q = jax.random.normal(kq, (B, Sq, H, hd))
    k = jax.random.normal(kk, (B, Sk, KV, hd))
    v = jax.random.normal(kv, (B, Sk, KV, hd))
    got = A.chunked_attention(q, k, v, causal=True, window=window,
                              chunk=chunk)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_chunked_attention_noncausal():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (2, 8, 4, 16))
    k = jax.random.normal(key, (2, 40, 4, 16))
    v = jax.random.normal(key, (2, 40, 4, 16))
    got = A.chunked_attention(q, k, v, causal=False, chunk=16)
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_moe_dispatch_matches_dense_oracle():
    """With capacity >> tokens nothing drops, so scatter dispatch must equal
    the dense run-every-expert oracle exactly."""
    cfg = tiny_config("granite-moe-1b-a400m").replace(
        moe_capacity_factor=64.0)   # no drops
    key = jax.random.PRNGKey(0)
    p = init_table(key, MOE.moe_table(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    got = MOE.moe_forward(cfg, p, x)
    want = MOE.moe_forward_dense_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_bounded():
    """With tight capacity some tokens drop, but output stays finite and
    dropped tokens contribute zero (residual carries them)."""
    cfg = tiny_config("granite-moe-1b-a400m").replace(
        moe_capacity_factor=0.5)
    p = init_table(jax.random.PRNGKey(0), MOE.moe_table(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    out = MOE.moe_forward(cfg, p, x)
    assert bool(jnp.isfinite(out).all())


def test_ssm_scan_matches_stepwise_decode():
    """Chunked associative scan == token-by-token recurrence."""
    cfg = tiny_config("falcon-mamba-7b")
    p = init_table(jax.random.PRNGKey(0), SSM.ssm_table(cfg))
    B, S = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    y_scan, final = SSM.ssm_forward(cfg, p, x, block=8)

    cache = SSM.ssm_empty_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(S):
        yt, cache = SSM.ssm_decode(cfg, p, x[:, t:t + 1], cache)
        ys.append(yt[:, 0])
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final.state),
                               np.asarray(cache.state), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final.conv),
                               np.asarray(cache.conv), rtol=1e-5, atol=1e-5)


def test_ssm_block_size_invariance():
    cfg = tiny_config("falcon-mamba-7b")
    p = init_table(jax.random.PRNGKey(0), SSM.ssm_table(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 37, cfg.d_model))
    y1, f1 = SSM.ssm_forward(cfg, p, x, block=4)
    y2, f2 = SSM.ssm_forward(cfg, p, x, block=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(f1.state), np.asarray(f2.state),
                               rtol=2e-4, atol=2e-4)


def test_mrope_equals_rope_when_positions_agree():
    """With t==h==w position ids, M-RoPE degenerates to plain RoPE."""
    B, S, H, hd = 2, 12, 4, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    pos3 = jnp.broadcast_to(pos[..., None], (B, S, 3))
    got = apply_mrope(x, pos3, 10_000.0, (2, 3, 3))
    want = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_rope_rotation_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 32))
    pos = jnp.arange(8, dtype=jnp.int32)[None]
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
