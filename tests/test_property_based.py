"""Hypothesis property tests for the bag, binpipe, and kernel layers.

Kept in their own module so a missing ``hypothesis`` (a dev dependency, see
requirements-dev.txt) skips only the property tests — the example-based
coverage in test_core_bag.py / test_core_binpipe.py / test_kernels.py still
runs.
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="dev dependency (see requirements-dev.txt); property tests skipped")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import (Bag, decode, deserialize, encode, frame, serialize,
                        unframe)

# -- bag round-trip (the invariant the whole platform rests on) -------------


@settings(max_examples=30, deadline=None)
@given(st.lists(
    st.tuples(st.sampled_from(["/a", "/b", "/c"]),
              st.integers(min_value=0, max_value=2**40),
              st.binary(min_size=0, max_size=300)),
    min_size=0, max_size=60))
def test_property_bag_roundtrip_memory(msgs):
    b = Bag.open_write(backend="memory", chunk_bytes=256)
    for t, ts, d in msgs:
        b.write(t, ts, d)
    b.close()
    r = Bag.open_read(backend="memory", image=b.chunked_file.image())
    got = [(m.topic, m.timestamp, m.data) for m in r.read_messages()]
    assert got == msgs
    assert r.num_messages == len(msgs)


# -- binpipe stage round-trips ----------------------------------------------

_field = st.one_of(
    st.binary(max_size=200),
    st.text(max_size=50),
    st.integers(min_value=-2**62, max_value=2**62),
    st.floats(allow_nan=False, allow_infinity=False, width=64),
    hnp.arrays(dtype=st.sampled_from([np.uint8, np.int32, np.float32]),
               shape=hnp.array_shapes(max_dims=3, max_side=8)),
)


def _eq(a, b):
    if isinstance(a, np.ndarray):
        return isinstance(b, np.ndarray) and a.dtype == b.dtype \
            and a.shape == b.shape \
            and np.array_equal(a, b, equal_nan=a.dtype.kind == "f")
    return a == b


@settings(max_examples=50, deadline=None)
@given(st.lists(_field, max_size=8))
def test_property_encode_decode(fields):
    got = decode(encode(fields))
    assert len(got) == len(fields)
    assert all(_eq(a, b) for a, b in zip(fields, got))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.binary(max_size=500), max_size=20))
def test_property_serialize_roundtrip(records):
    assert deserialize(serialize(records)) == records


@settings(max_examples=50, deadline=None)
@given(st.lists(st.binary(min_size=0, max_size=700), min_size=0, max_size=20),
       st.sampled_from([1, 8, 128]))
def test_property_frame_roundtrip(records, align):
    payload, offsets, lengths = frame(records, align=align)
    assert unframe(payload, offsets, lengths) == records
    # alignment invariant: every record starts on an `align` boundary
    assert all(o % align == 0 for o in offsets.tolist())
    assert payload.dtype == np.uint8


# -- sensor decode kernel ---------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 12), st.integers(1, 600), st.integers(0, 3))
def test_property_sensor_decode_roundtrip(R, Nb, seed):
    """Dequantize(quantize(x)) recovers x up to scale quantisation."""
    import jax.numpy as jnp

    from repro.kernels import ops
    rng = np.random.RandomState(seed)
    payload = jnp.asarray(rng.randint(0, 256, (R, Nb), np.uint8))
    scale = jnp.ones((R,), jnp.float32)
    zp = jnp.zeros((R,), jnp.float32)
    lengths = jnp.full((R,), Nb, jnp.int32)
    got = ops.decode_records(payload, scale, zp, lengths)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(payload, np.float32))


# -- wire frame integrity (CRC trailer) -------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.tuples(st.sampled_from(["/a", "/b"]),
                       st.integers(min_value=0, max_value=2**40),
                       st.binary(max_size=64)),
             min_size=0, max_size=20))
def test_property_wire_frame_roundtrip(msgs):
    """An untampered frame round-trips byte-exactly through the CRC-trailed
    codec over a real socket pair."""
    import socket

    from repro.core import Message
    from repro.net import wire

    wanted = [Message(t, ts, d) for t, ts, d in msgs]
    a, b = socket.socketpair()
    fa, fb = wire.FrameSocket(a), wire.FrameSocket(b)
    fa.send_frame(wire.T_DATA, wire.encode_data(wanted))
    ftype, body = fb.recv_frame()
    assert ftype == wire.T_DATA
    assert wire.decode_data(bytes(body)) == wanted
    fa.close()
    fb.close()


@settings(max_examples=60, deadline=None)
@given(
    st.lists(st.tuples(st.sampled_from(["/a", "/b"]),
                       st.integers(min_value=0, max_value=2**40),
                       st.binary(max_size=64)),
             min_size=0, max_size=20),
    st.sampled_from(["data", "hello"]),
    st.data())
def test_property_mutated_wire_frames_never_deliver(msgs, kind, data):
    """Any single bit flip or truncation of an encoded DATA/HELLO frame is
    rejected (WireError) or reads as a clean between-frames EOF — never a
    hang (the closed writer bounds the read) and never corrupt bytes
    surfaced as a valid frame."""
    import socket

    from repro.core import Message
    from repro.net import wire

    if kind == "data":
        ftype = wire.T_DATA
        body = bytes(wire.encode_data(
            [Message(t, ts, d) for t, ts, d in msgs]))
    else:
        ftype = wire.T_HELLO
        body = b"prop-stream"
    frame = bytearray(
        wire._FRAME_HDR.pack(len(body), ftype) + body
        + wire._U32.pack(wire.frame_crc(ftype, body)))
    if data.draw(st.booleans(), label="truncate"):
        frame = frame[:data.draw(st.integers(0, len(frame) - 1),
                                 label="cut")]
    else:
        pos = data.draw(st.integers(0, len(frame) - 1), label="pos")
        frame[pos] ^= 1 << data.draw(st.integers(0, 7), label="bit")
    a, b = socket.socketpair()
    fb = wire.FrameSocket(b)
    a.sendall(bytes(frame))
    a.close()
    try:
        got_type, got = fb.recv_frame()
    except wire.WireError:
        pass
    else:
        # a zero-byte truncation is the one clean outcome
        assert got_type is None and got == b""
    finally:
        fb.close()
