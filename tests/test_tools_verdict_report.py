"""Verdict-history trending tool (ISSUE 5 satellite): drift flags, strict
exit codes, and end-to-end operation on a real ScenarioSuite verdict log.
"""

import json

import numpy as np
import pytest

from repro.tools.verdict_report import analyze, load_records, main


def _rec(scenario, status="PASS", passed=True, checksums=None, wall=0.1,
         out=10, into=10):
    return {"scenario": scenario, "status": status, "passed": passed,
            "checksums": checksums or {}, "wall_time_s": wall,
            "messages_out": out, "messages_in": into}


def test_no_flags_on_stable_history():
    recs = [_rec("a", checksums={"/x": 1}), _rec("a", checksums={"/x": 1})]
    report = analyze(recs)
    assert report["flags"] == []
    assert report["scenarios"]["a"]["runs"] == 2


def test_checksum_drift_between_passing_runs_flagged():
    recs = [_rec("a", checksums={"/x": 1, "/y": 2}),
            _rec("a", checksums={"/x": 1, "/y": 3})]
    flags = analyze(recs)["flags"]
    assert [f["flag"] for f in flags] == ["CHECKSUM-DRIFT"]
    assert "/y" in flags[0]["detail"]


def test_topic_appearing_or_disappearing_flagged():
    recs = [_rec("a", checksums={"/x": 1}),
            _rec("a", checksums={"/x": 1, "/new": 9})]
    assert any(f["flag"] == "CHECKSUM-DRIFT" and "appeared" in f["detail"]
               for f in analyze(recs)["flags"])


def test_failing_run_does_not_double_flag_checksums():
    """A FAIL is loud already: checksum comparison only applies between
    passing runs, but the status flip itself is flagged."""
    recs = [_rec("a", checksums={"/x": 1}),
            _rec("a", status="FAIL", passed=False, checksums={"/x": 2})]
    flags = analyze(recs)["flags"]
    assert [f["flag"] for f in flags] == ["STATUS-FLIP"]


def test_count_drift_flagged():
    recs = [_rec("a", out=10), _rec("a", out=12)]
    assert any(f["flag"] == "COUNT-DRIFT"
               for f in analyze(recs)["flags"])


def test_walltime_regression_flagged_and_floored():
    recs = [_rec("a", wall=0.2), _rec("a", wall=0.21), _rec("a", wall=0.9)]
    assert any(f["flag"] == "WALLTIME" for f in analyze(recs)["flags"])
    # sub-noise runs never flag, however large the ratio
    tiny = [_rec("b", wall=0.001), _rec("b", wall=0.01)]
    assert analyze(tiny)["flags"] == []


def test_single_run_never_flags():
    assert analyze([_rec("a")])["flags"] == []


def test_error_verdicts_get_own_section_not_drift_flags():
    recs = [_rec("a", checksums={"/x": 1}),
            _rec("a", status="ERROR", passed=False, checksums={},
                 wall=0.0) | {"error": "upstream scenario 'p' errored"}]
    report = analyze(recs)
    assert report["errors"] == [
        {"scenario": "a", "error": "upstream scenario 'p' errored",
         "runs": 2}]
    # the ERROR surfaces as a STATUS-FLIP but never as checksum/count
    # drift — an errored run produced nothing comparable
    assert [f["flag"] for f in report["flags"]] == ["STATUS-FLIP"]


def test_error_runs_excluded_from_walltime_trending():
    # an ERROR run fails fast; its near-zero wall must not poison the
    # baseline median for the next real run, nor flag itself
    recs = [_rec("a", wall=0.2), _rec("a", wall=0.2),
            _rec("a", status="ERROR", passed=False, wall=0.001),
            _rec("a", wall=0.21)]
    report = analyze(recs)
    assert not any(f["flag"] == "WALLTIME" for f in report["flags"])
    assert report["scenarios"]["a"]["wall_baseline_s"] == pytest.approx(0.2)


def test_strict_trips_on_current_error_without_flags(tmp_path, capsys):
    log = tmp_path / "verdicts.jsonl"
    log.write_text(json.dumps(
        _rec("a", status="ERROR", passed=False)
        | {"error": "injected user-logic failure"}) + "\n")
    assert main([str(log)]) == 0                # informational by default
    assert main([str(log), "--strict"]) == 1    # a degraded suite is red
    assert "[ERROR] a: injected user-logic failure" in capsys.readouterr().out


def test_cli_strict_exit_codes(tmp_path, capsys):
    log = tmp_path / "verdicts.jsonl"
    stable = [_rec("a", checksums={"/x": 1})] * 2
    with open(log, "w") as f:
        for r in stable:
            f.write(json.dumps(r) + "\n")
    assert main([str(log), "--strict"]) == 0
    drift = stable + [_rec("a", checksums={"/x": 2})]
    with open(log, "w") as f:
        for r in drift:
            f.write(json.dumps(r) + "\n")
    assert main([str(log)]) == 0                # informational by default
    assert main([str(log), "--strict"]) == 1
    out = capsys.readouterr().out
    assert "CHECKSUM-DRIFT" in out
    json_out = tmp_path / "report.json"
    main([str(log), "--json", str(json_out)])
    saved = json.loads(json_out.read_text())
    assert saved["flags"]


def test_bad_jsonl_rejected(tmp_path):
    log = tmp_path / "broken.jsonl"
    log.write_text('{"scenario": "a"}\nnot-json\n')
    with pytest.raises(ValueError, match="broken.jsonl:2"):
        load_records(str(log))


def test_end_to_end_with_real_verdict_log(tmp_path):
    """Two real suite runs with changed logic output: the tool flags the
    checksum drift a plain PASS/PASS history would hide."""
    from repro.core import Bag, Scenario, ScenarioSuite
    bag = str(tmp_path / "drive.bag")
    b = Bag.open_write(bag, chunk_bytes=4096)
    rng = np.random.RandomState(0)
    for i in range(120):
        b.write("/camera", i * 1000, rng.bytes(32))
    b.close()
    log = str(tmp_path / "verdicts.jsonl")

    def run(tag):
        sc = Scenario("s", bag,
                      "tests.test_tools_verdict_report:" + tag)
        ScenarioSuite([sc], num_workers=2).run(timeout=60, verdict_log=log)

    run("logic_v1")
    run("logic_v1")
    assert main([log, "--strict"]) == 0
    run("logic_v2")                     # silently different outputs
    assert main([log, "--strict"]) == 1


def logic_v1(msg):
    return ("/out", msg.data[:8])


def logic_v2(msg):
    return ("/out", msg.data[:9])
