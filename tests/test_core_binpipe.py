"""BinPipedRDD stage tests: encode/decode, serialize/deserialize, frame,
and map() applying user logic; hypothesis round-trips live in
test_property_based.py."""

import pytest

from repro.core import BinaryPartition, decode, encode, unframe


def test_encode_rejects_unknown_type():
    with pytest.raises(TypeError):
        encode([object()])


def test_partition_map_applies_user_logic():
    recs = [encode([f"/t{i}", i, bytes([i]) * 4]) for i in range(10)]
    part = BinaryPartition(recs, lineage=("test",))
    out = part.map(lambda f: [f[0], f[1] * 2, f[2][:1]])
    assert len(out) == 10
    assert out.lineage == ("test", "map")
    f = decode(out.records[3])
    assert f == ["/t3", 6, bytes([3])]


def test_partition_stream_roundtrip():
    recs = [encode([i, b"x" * i]) for i in range(20)]
    part = BinaryPartition(recs)
    back = BinaryPartition.from_stream(part.to_stream())
    assert back.records == recs


def test_partition_to_arrays_is_framed():
    recs = [b"a" * 5, b"b" * 200]
    payload, offsets, lengths = BinaryPartition(list(recs)).to_arrays()
    assert unframe(payload, offsets, lengths) == recs
