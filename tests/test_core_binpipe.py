"""BinPipedRDD stage tests: encode/decode, serialize/deserialize, frame —
each stage round-trips (property-based), and map() applies user logic."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import (BinaryPartition, decode, deserialize, encode, frame,
                        serialize, unframe)

_field = st.one_of(
    st.binary(max_size=200),
    st.text(max_size=50),
    st.integers(min_value=-2**62, max_value=2**62),
    st.floats(allow_nan=False, allow_infinity=False, width=64),
    hnp.arrays(dtype=st.sampled_from([np.uint8, np.int32, np.float32]),
               shape=hnp.array_shapes(max_dims=3, max_side=8)),
)


def _eq(a, b):
    if isinstance(a, np.ndarray):
        return isinstance(b, np.ndarray) and a.dtype == b.dtype \
            and a.shape == b.shape \
            and np.array_equal(a, b, equal_nan=a.dtype.kind == "f")
    return a == b


@settings(max_examples=50, deadline=None)
@given(st.lists(_field, max_size=8))
def test_property_encode_decode(fields):
    got = decode(encode(fields))
    assert len(got) == len(fields)
    assert all(_eq(a, b) for a, b in zip(fields, got))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.binary(max_size=500), max_size=20))
def test_property_serialize_roundtrip(records):
    assert deserialize(serialize(records)) == records


@settings(max_examples=50, deadline=None)
@given(st.lists(st.binary(min_size=0, max_size=700), min_size=0, max_size=20),
       st.sampled_from([1, 8, 128]))
def test_property_frame_roundtrip(records, align):
    payload, offsets, lengths = frame(records, align=align)
    assert unframe(payload, offsets, lengths) == records
    # alignment invariant: every record starts on an `align` boundary
    assert all(o % align == 0 for o in offsets.tolist())
    assert payload.dtype == np.uint8


def test_encode_rejects_unknown_type():
    with pytest.raises(TypeError):
        encode([object()])


def test_partition_map_applies_user_logic():
    recs = [encode([f"/t{i}", i, bytes([i]) * 4]) for i in range(10)]
    part = BinaryPartition(recs, lineage=("test",))
    out = part.map(lambda f: [f[0], f[1] * 2, f[2][:1]])
    assert len(out) == 10
    assert out.lineage == ("test", "map")
    f = decode(out.records[3])
    assert f == ["/t3", 6, bytes([3])]


def test_partition_stream_roundtrip():
    recs = [encode([i, b"x" * i]) for i in range(20)]
    part = BinaryPartition(recs)
    back = BinaryPartition.from_stream(part.to_stream())
    assert back.records == recs


def test_partition_to_arrays_is_framed():
    recs = [b"a" * 5, b"b" * 200]
    payload, offsets, lengths = BinaryPartition(list(recs)).to_arrays()
    assert unframe(payload, offsets, lengths) == recs
