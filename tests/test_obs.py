"""Observability layer tests: the span tracer (zero-cost when disabled,
ring-buffered when enabled), cross-process trace stitching through the
executor result path, the wire-frame context annotation, the metrics
registry (aggregation, reset-in-place, worker-delta absorption, the
deprecated counter shims), Perfetto export + stage breakdown, and the
``trace_report`` / ``verdict_report`` CLI faces.

User-logic functions are module-level so they cross the process-backend
pickle boundary.
"""

import json
import socket
import threading

import numpy as np
import pytest

from repro import chaos
from repro.core import Bag, Scenario, ScenarioSuite
from repro.obs import export as oexport
from repro.obs import metrics as ometrics
from repro.obs import trace as otrace

TOPICS = ("/camera", "/lidar")


def _make_bag(path, n=240, seed=0):
    b = Bag.open_write(path, chunk_bytes=4096)
    rng = np.random.RandomState(seed)
    for i in range(n):
        b.write(TOPICS[i % len(TOPICS)], i * 1000 + int(rng.randint(400)),
                bytes([i % 256]) * 48)
    b.close()
    return path


@pytest.fixture
def bag_path(tmp_path):
    return _make_bag(str(tmp_path / "drive.bag"))


@pytest.fixture(autouse=True)
def _tracer_off():
    """Every test starts and ends with the tracer disabled — a leaked
    tracer would silently slow (and cross-contaminate) the session."""
    otrace.disable()
    yield
    otrace.disable()


def det_logic(msg):
    return ("/det" + msg.topic, msg.data[:4])


def prov_logic(msg):
    return ("/det" + msg.topic, msg.data[:4])


def cons_logic(msg):
    if msg.topic.startswith("/det"):
        return ("/seen" + msg.topic, msg.data[:2])
    return None


# -- tracer unit behaviour ----------------------------------------------------


def test_disabled_tracer_is_none_and_span_noops():
    assert otrace.TRACER is None and not otrace.enabled()
    with otrace.span("x", "suite") as slot:
        assert slot is None
    assert otrace.get_tracer() is None


def test_begin_end_drain_roundtrip():
    tr = otrace.enable(root_name="t")
    slot = tr.begin("work", "logic", attrs={"n": 3})
    tr.end(slot)
    records = tr.drain_all()
    names = {r[2] for r in records}
    assert names == {"t", "work"}
    work = next(r for r in records if r[2] == "work")
    sid, parent, name, cat, t0, t1, pid, tid, attrs = work
    assert parent == tr.root_id and cat == "logic"
    assert 0 < t0 <= t1 and attrs == {"n": 3}
    assert pid == tr.pid and tid == threading.get_ident()


def test_ambient_context_nests_and_ctx_propagates():
    tr = otrace.enable()
    with tr.span("outer", "suite") as outer:
        assert tr.ctx() == outer[0]
        with tr.span("inner", "suite") as inner:
            assert inner[1] == outer[0]     # parent = enclosing span
    assert tr.ctx() == tr.root_id           # stack unwound
    recs = {r[2]: r for r in tr.drain_all()}
    assert recs["inner"][1] == recs["outer"][0]
    assert recs["outer"][1] == tr.root_id


def test_ring_wrap_counts_drops_not_raises():
    tr = otrace.enable(capacity=8)
    for i in range(40):
        tr.instant(f"s{i}", "suite")
    assert tr.dropped >= 30
    records = tr.drain_all()
    assert 0 < len(records) <= 9            # ring + closed root


def test_span_ids_unique_across_threads():
    tr = otrace.enable()
    seen = []

    def work():
        for _ in range(50):
            seen.append(tr.instant("x", "suite"))

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(seen) == len(set(seen)) == 200


def test_task_bracket_thread_mode_keeps_driver_tracer():
    tr = otrace.enable()
    ctx = tr.instant("dispatch", "sched")
    slot = otrace.task_begin(ctx, attrs={"task": 1})
    assert otrace.TRACER is tr              # no replacement in-process
    shipped = otrace.task_end(slot)
    assert shipped == []                    # records stay local
    recs = {r[2]: r for r in tr.drain_all()}
    assert recs["task.run"][1] == ctx


def test_ingest_stitches_foreign_records():
    tr = otrace.enable()
    foreign = (999_000_001, tr.root_id, "task.run", "sched",
               100, 200, 4242, 1, None)
    otrace.ingest([foreign])
    records = tr.drain_all()
    assert foreign in records


# -- wire context annotation --------------------------------------------------


def test_frame_ctx_annotation_roundtrip():
    from repro.net.wire import T_DATA, FrameSocket
    a, b = socket.socketpair()
    fa, fb = FrameSocket(a), FrameSocket(b)
    try:
        fa.send_frame(T_DATA, b"payload", trace_ctx=123456789)
        ftype, body = fb.recv_frame()
        assert ftype == T_DATA and bytes(body) == b"payload"
        assert fb.last_trace_ctx == 123456789
        fa.send_frame(T_DATA, b"plain")
        ftype, body = fb.recv_frame()
        assert ftype == T_DATA and bytes(body) == b"plain"
        assert fb.last_trace_ctx is None    # annotation is per-frame
    finally:
        fa.close()
        fb.close()


# -- metrics registry ---------------------------------------------------------


def test_metric_primitives_and_reset():
    s = ometrics.Scope("t")
    c, g, h = s.counter("c"), s.gauge("g"), s.histogram("h")
    c.inc()
    c.inc(4)
    g.set(7)
    g.set(3)
    h.observe(10)
    h.observe(2)
    snap = s.snapshot()
    assert snap["c"] == 5
    assert snap["g"] == {"value": 3, "max": 7}
    assert snap["h"]["count"] == 2 and snap["h"]["mean"] == 6.0
    s.snapshot(reset=True)
    # reset happens IN PLACE: cached refs keep working afterwards
    c.inc()
    assert s.snapshot() == {"c": 1, "g": {"value": 0, "max": 0},
                            "h": {"count": 0, "total": 0, "min": None,
                                  "max": None, "mean": None}}


def test_registry_aggregates_same_named_scopes_and_absorbs():
    reg = ometrics.Registry()
    a, b = reg.scope("pool"), reg.scope("pool")
    a.counter("puts").inc(2)
    b.counter("puts").inc(3)
    reg.absorb({"pool": {"puts": 10}, "worker": {"steps": 1}})
    snap = reg.snapshot()
    assert snap["pool"]["puts"] == 15
    assert snap["worker"]["steps"] == 1


def test_registry_scopes_are_weak():
    reg = ometrics.Registry()
    s = reg.scope("gone")
    s.counter("x").inc()
    assert reg.snapshot()["gone"]["x"] == 1
    del s
    assert "gone" not in reg.snapshot()


def test_result_cache_counter_shims(tmp_path):
    from repro.cache import ResultCache
    cache = ResultCache(str(tmp_path / "store"))
    assert cache.load("0" * 64) is None
    assert (cache.hits, cache.misses) == (0, 1)
    assert cache.stats == {"hits": 0, "misses": 1, "puts": 0,
                           "put_errors": 0}


def test_scheduler_stats_is_registry_backed(bag_path):
    suite = ScenarioSuite([Scenario("s", bag_path, det_logic,
                                    num_partitions=2)], num_workers=2)
    v = suite.run(timeout=60)
    stats = v["s"].report.scheduler_stats
    assert stats["tasks_done"] >= 3         # 2 partitions + aggregate
    assert stats["retries"] == 0 and "spills" in stats


# -- export + stage breakdown -------------------------------------------------


def _rec(sid, parent, name, cat, t0, t1, pid=1, tid=1, attrs=None):
    return (sid, parent, name, cat, t0, t1, pid, tid, attrs)


def test_to_events_roundtrip_and_incomplete(tmp_path):
    records = [
        _rec(1, 0, "root", "suite", 1000, 9000),
        _rec(2, 1, "open", "lane", 2000, 0),        # never closed
    ]
    path = str(tmp_path / "trace.json")
    assert oexport.write_trace(path, records, driver_pid=1) == 2
    doc = json.load(open(path))
    events = doc["traceEvents"]
    x = [e for e in events if e["ph"] == "X"]
    assert {e["name"] for e in x} == {"root", "open"}
    assert [e for e in x if e["name"] == "open"][0]["args"]["incomplete"]
    back = oexport.events_to_records(events)
    assert sorted(r[0] for r in back) == [1, 2]
    assert {r[2]: r[1] for r in back} == {"root": 0, "open": 1}


def test_stage_breakdown_attribution_and_dedup():
    ms = 1_000_000
    records = [
        _rec(1, 0, "suite.run", "suite", 1, 100 * ms),
        _rec(2, 1, "sched.task", "sched", 1, 90 * ms,
             attrs={"stage": ["scenario", "s1"]}),
        _rec(3, 2, "play.read", "play", 1, 10 * ms + 1),
        # the logic lane's burst span ...
        _rec(4, 2, "lane.deliver", "lane", 10 * ms, 50 * ms,
             attrs={"lane": "logic"}),
        # ... encloses chunked logic spans: only the lane bills "logic"
        _rec(5, 4, "logic.step", "logic", 11 * ms, 49 * ms),
        _rec(6, 2, "lane.deliver", "lane", 10 * ms, 30 * ms,
             attrs={"lane": "record-1"}),
        # suite-level span with no sched.task ancestor
        _rec(7, 1, "cache.load", "cache", 1, 5 * ms + 1),
        # jitted decode+forward bills its own stage
        _rec(8, 2, "perception.step", "logic", 50 * ms, 70 * ms),
    ]
    bd = oexport.stage_breakdown(records)
    assert bd["s1"] == {"read": 10 * ms, "logic": 40 * ms,
                        "record": 20 * ms, "decode": 20 * ms}
    assert bd["_suite"] == {"cache": 5 * ms}


# -- end-to-end: traced suite runs -------------------------------------------


def _ids_and_parents(events):
    x = [e for e in events if e.get("ph") == "X"]
    ids = {e["args"]["id"] for e in x}
    return x, ids


def test_traced_thread_suite_single_rooted_timeline(bag_path, tmp_path):
    trace_path = str(tmp_path / "trace.json")
    suite = ScenarioSuite(
        [Scenario("s1", bag_path, det_logic, num_partitions=2),
         Scenario("piped", bag_path, det_logic, pipeline=True,
                  latency_model_s=0.0001)],
        num_workers=2)
    verdicts = suite.run(timeout=120, trace=trace_path)
    assert all(v.passed for v in verdicts.values())
    assert not otrace.enabled()             # run() tears its tracer down

    events = json.load(open(trace_path))["traceEvents"]
    x, ids = _ids_and_parents(events)
    assert len(x) > 10
    by_id = {e["args"]["id"]: e for e in x}
    roots = [e for e in x if e["args"]["parent"] == 0]
    assert len(roots) == 1                  # single rooted timeline
    for e in x:                             # every span reaches the root
        cur, hops = e, 0
        while cur["args"]["parent"] != 0:
            assert cur["args"]["parent"] in ids, \
                f"orphan span {cur['name']}"
            cur = by_id[cur["args"]["parent"]]
            hops += 1
            assert hops < 50
    cats = {e["cat"] for e in x}
    assert {"suite", "sched", "play", "logic", "lane"} <= cats


def test_traced_run_is_bit_identical(bag_path, tmp_path):
    def sums(**kw):
        v = ScenarioSuite([Scenario("s", bag_path, det_logic,
                                    num_partitions=2)],
                          num_workers=2).run(timeout=60, **kw)
        return {t: m.checksum for t, m in v["s"].metrics.items()}

    assert sums() == sums(trace=str(tmp_path / "t.json"))


def test_traced_process_suite_stitches_worker_spans(bags_pair, tmp_path):
    """The acceptance shape: process backend + wire export + cache, one
    trace covering scheduler/lane/transport/cache/logic/play seams, every
    worker-side span stitched under a driver-side parent."""
    import os
    trace_path = str(tmp_path / "trace.json")
    suite = ScenarioSuite(
        [Scenario("prov", bags_pair[0], "tests.test_obs:prov_logic",
                  exports=("/det/camera", "/det/lidar")),
         Scenario("cons", bags_pair[1], "tests.test_obs:cons_logic",
                  imports=("/det/camera", "/det/lidar"))],
        num_workers=2, backend="process", export_transport="wire")
    verdicts = suite.run(timeout=180, trace=trace_path,
                         cache=str(tmp_path / "cache"))
    assert all(v.passed for v in verdicts.values())

    events = json.load(open(trace_path))["traceEvents"]
    x, ids = _ids_and_parents(events)
    by_id = {e["args"]["id"]: e for e in x}
    driver_pid = os.getpid()
    worker = [e for e in x if e["pid"] != driver_pid]
    assert worker, "no worker-side spans shipped home"
    for e in worker:                        # driver-side ancestor exists
        cur, hops = e, 0
        while cur["pid"] != driver_pid:
            parent = cur["args"]["parent"]
            assert parent in ids, f"orphan worker span {cur['name']}"
            cur = by_id[parent]
            hops += 1
            assert hops < 50
    for e in x:                             # and no orphans anywhere
        assert e["args"]["parent"] == 0 or e["args"]["parent"] in ids
    cats = {e["cat"] for e in x}
    assert {"suite", "sched", "play", "logic", "lane", "transport",
            "cache"} <= cats

    # warm re-run: hits rehydrate, trace still written and parseable
    verdicts2 = ScenarioSuite(
        [Scenario("prov", bags_pair[0], "tests.test_obs:prov_logic",
                  exports=("/det/camera", "/det/lidar")),
         Scenario("cons", bags_pair[1], "tests.test_obs:cons_logic",
                  imports=("/det/camera", "/det/lidar"))],
        num_workers=2, backend="process",
        export_transport="wire").run(timeout=180, trace=trace_path,
                                     cache=str(tmp_path / "cache"))
    assert {v.cache for v in verdicts2.values()} == {"hit"}
    cats2 = {e["cat"]
             for e in json.load(open(trace_path))["traceEvents"]
             if e.get("ph") == "X"}
    assert "cache" in cats2


@pytest.fixture
def bags_pair(tmp_path):
    return (_make_bag(str(tmp_path / "a.bag"), seed=1),
            _make_bag(str(tmp_path / "b.bag"), seed=2))


def test_worker_crash_leaves_parseable_partial_trace(bag_path, tmp_path):
    trace_path = str(tmp_path / "trace.json")
    chaos.install(chaos.ChaosPlan(
        [chaos.Fault("worker_crash", target="w0", count=1)], seed=3))
    try:
        suite = ScenarioSuite(
            [Scenario("s", bag_path, "tests.test_obs:det_logic",
                      num_partitions=3)],
            num_workers=2, backend="process",
            scheduler_kwargs={"max_attempts": 3,
                              "heartbeat_timeout": 0.3})
        verdicts = suite.run(timeout=120, trace=trace_path)
        assert verdicts["s"].passed
    finally:
        chaos.uninstall()
    events = json.load(open(trace_path))["traceEvents"]
    x, ids = _ids_and_parents(events)
    assert x                                # partial trace, never empty
    for e in x:                             # crash loses spans, not links
        assert e["args"]["parent"] == 0 or e["args"]["parent"] in ids
    assert any(e["name"] == "sched.worker_death" for e in x)


def test_crash_mid_suite_still_writes_flight_recording(bag_path, tmp_path):
    trace_path = str(tmp_path / "trace.json")

    def boom(msg):
        raise RuntimeError("logic exploded")

    suite = ScenarioSuite(
        [Scenario("s", bag_path, boom, num_partitions=2)],
        num_workers=2, scheduler_kwargs={"max_attempts": 2})
    with pytest.raises(RuntimeError):
        suite.run(timeout=60, trace=trace_path)
    assert not otrace.enabled()
    events = json.load(open(trace_path))["traceEvents"]
    assert any(e.get("ph") == "X" for e in events)
    assert any(e.get("name") == "sched.retry" for e in events)


# -- CLI faces ---------------------------------------------------------------


def test_trace_report_cli(bag_path, tmp_path, capsys):
    from repro.tools import trace_report
    trace_path = str(tmp_path / "trace.json")
    ScenarioSuite([Scenario("s1", bag_path, det_logic,
                            num_partitions=2)],
                  num_workers=2).run(timeout=60, trace=trace_path)
    out_json = str(tmp_path / "report.json")
    assert trace_report.main([trace_path, "--strict",
                              "--json", out_json]) == 0
    printed = capsys.readouterr().out
    assert "spans across" in printed and "s1" in printed
    report = json.load(open(out_json))
    assert report["spans"] > 0 and not report["orphans"]
    assert "s1" in report["scenarios"]

    empty = str(tmp_path / "empty.json")
    json.dump({"traceEvents": []}, open(empty, "w"))
    assert trace_report.main([empty, "--strict"]) == 1
    capsys.readouterr()


def test_verdict_report_stage_trending_and_metrics(tmp_path, capsys):
    from repro.tools import verdict_report
    base = {"status": "PASS", "passed": True, "vacuous": False,
            "checksums": {}, "cache": None}
    runs = [dict(base, scenario="s", wall_time_s=1.0, unix_time=i,
                 stages={"read": 100_000_000, "logic": 1_000_000_000})
            for i in range(3)]
    # wall flat, but the logic stage tripled — must still flag
    runs.append(dict(base, scenario="s", wall_time_s=1.0, unix_time=3,
                     stages={"read": 100_000_000,
                             "logic": 3_000_000_000}))
    log = str(tmp_path / "v.jsonl")
    with open(log, "w") as f:
        for r in runs:
            f.write(json.dumps(r) + "\n")
    manifest = {"metrics": {"scheduler": {"tasks_done": 7},
                            "cache": {"hits": 2,
                                      "depth": {"value": 3, "max": 9}}}}
    mpath = log + ".manifest.json"
    json.dump(manifest, open(mpath, "w"))

    rc = verdict_report.main([log, "--metrics", "--strict"])
    printed = capsys.readouterr().out
    assert rc == 1
    assert "stage logic" in printed
    assert "stage read" not in printed      # the flat stage stays quiet
    assert "tasks_done=7" in printed and "depth=3" in printed
